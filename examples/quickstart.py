"""Quickstart: dehaze a synthetic hazy clip with the component framework.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video

# 1. A synthetic foggy clip with ground truth (Eq. 1 physics).
video = generate_haze_video(HazeVideoSpec(height=120, width=160,
                                          n_frames=16, a_noise=0.0))
print(f"clip: {video.hazy.shape}, true A ~ {video.A.mean(axis=0).round(3)}")

# 2. Configure the paper's pipeline: DCP transmission estimator, guided
#    refinement, cross-frame atmospheric-light normalization (§3.3).
cfg = DehazeConfig(algorithm="dcp", update_period=4, lam=0.05)
step = jax.jit(make_dehaze_step(cfg))

# 3. One jitted step processes a batch of frames through all three
#    components; the AtmoState carries the shared A between batches.
state = init_atmo_state()
frames = jnp.asarray(video.hazy[:8])
out = step(frames, jnp.arange(8, dtype=jnp.int32), state)
out2 = step(jnp.asarray(video.hazy[8:]),
            jnp.arange(8, 16, dtype=jnp.int32), out.state)

dehazed = np.concatenate([np.asarray(out.frames), np.asarray(out2.frames)])
err_before = np.abs(video.hazy - video.clear).mean()
err_after = np.abs(dehazed - video.clear).mean()
print(f"L1 error vs ground truth: hazy={err_before:.4f} -> "
      f"dehazed={err_after:.4f}")
print(f"estimated A after 16 frames: {np.asarray(out2.state.A).round(3)} "
      f"(true {video.A[-1].round(3)})")
assert err_after < err_before
print("OK")
