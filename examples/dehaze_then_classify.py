"""Dehazing as a pre-processing component for video analytics (the paper's
motivating use case §1): hazy frames → dehazer → ViT backbone.

The dehazer and the classifier are just two components in the same stream;
this is why the framework treats the assigned vision backbones as
first-class architectures (DESIGN.md §4).

Run:  PYTHONPATH=src python examples/dehaze_then_classify.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgreg
from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video
from repro.models import common as cm
from repro.models import vit as V

# Hazy input stream.
video = generate_haze_video(HazeVideoSpec(height=64, width=64, n_frames=8,
                                          a_noise=0.0))
frames = jnp.asarray(video.hazy)

# Component 1-3: the dehazer.
dehaze = jax.jit(make_dehaze_step(DehazeConfig(algorithm="dcp",
                                               gf_radius=8)))
out = dehaze(frames, jnp.arange(8, dtype=jnp.int32), init_atmo_state())

# Component 4: a ViT backbone (reduced config for CPU).
cfg = cfgreg.get_module("vit-l16").smoke_config()
params = cm.init_params(jax.random.key(0), V.vit_param_table(cfg))
classify = jax.jit(V.make_forward(cfg))

def resize(x, res):
    return jax.image.resize(x, (x.shape[0], res, res, 3), "bilinear")

logits_hazy = classify(params, resize(frames, cfg.img_res))
logits_clean = classify(params, resize(out.frames, cfg.img_res))

# The dehazed features should be closer to the ground-truth-clear features
# than the hazy ones — dehazing reduces the domain gap for the backbone.
logits_gt = classify(params, resize(jnp.asarray(video.clear), cfg.img_res))
gap_hazy = float(jnp.abs(logits_hazy - logits_gt).mean())
gap_dehazed = float(jnp.abs(logits_clean - logits_gt).mean())
print(f"feature gap vs clear-scene logits: hazy={gap_hazy:.4f} "
      f"dehazed={gap_dehazed:.4f}")
assert gap_dehazed < gap_hazy
print("dehazing shrinks the backbone's domain gap — OK")
