"""Diffusion serving: a DDIM sampling loop on a reduced DiT, batched
requests through the stream monitor (out-of-order completion, ordered
emission) — the paper's layer-5 pattern applied to a diffusion workload
(DESIGN.md §4).

Run:  PYTHONPATH=src python examples/sample_dit.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgreg
from repro.models import common as cm
from repro.models import dit as D
from repro.stream import Monitor

cfg = cfgreg.get_module("dit-l2").smoke_config()
params = cm.init_params(jax.random.key(0), D.dit_param_table(cfg))
sample_step = jax.jit(D.make_sample_step(cfg, guidance=2.0))

B, STEPS = 4, 8
lat = cfg.latent_res
rng = jax.random.key(1)
z = jax.random.normal(rng, (B, lat, lat, 4))
y = jnp.arange(B) % cfg.n_classes

ts = jnp.linspace(999, 1, STEPS + 1).astype(jnp.int32)
t0 = time.perf_counter()
for i in range(STEPS):
    t = jnp.full((B,), ts[i])
    t_next = jnp.full((B,), ts[i + 1])
    z = sample_step(params, z, t, t_next, y)
jax.block_until_ready(z)
dt = time.perf_counter() - t0
assert not bool(jnp.isnan(z).any())
print(f"sampled {B} latents x {STEPS} DDIM steps in {dt:.2f}s "
      f"({B * STEPS / dt:.1f} denoise-steps/s); latent std "
      f"{float(z.std()):.3f}")

# Requests complete out of order (different step counts); the monitor
# (paper §3.2 layer 5) restores submission order at the sink.
emitted = []
mon = Monitor(lambda rid, _: emitted.append(rid), timeout_s=5.0)
for rid in reversed(range(6)):          # worst case: reverse completion
    mon.put(rid, None)
    mon.poll()
mon.close()
mon.drain()
assert emitted == list(range(6))
print("ordered emission of out-of-order completions — OK")
