"""Train a ~100M-param LM for a few hundred steps on synthetic tokens
(deliverable b: end-to-end training driver, CPU-sized).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import TokenStream, prefetch_to_device
from repro.models import common as cm
from repro.models import transformer as T
from repro.models.steps import make_train_step
from repro.optim import adamw_init, cosine_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--layers", type=int, default=12)
ap.add_argument("--d-model", type=int, default=768)
args = ap.parse_args()

# Default: ~92M params (12L x 768d, llama3-family shape, GQA 2:1).
cfg = T.LMConfig(name="lm-100m", n_layers=args.layers, d_model=args.d_model,
                 n_heads=args.d_model // 64, n_kv_heads=args.d_model // 128,
                 head_dim=64, d_ff=3 * args.d_model, vocab=512,
                 dtype="float32", kv_block=128, remat=False)
table = T.lm_param_table(cfg)
params = cm.init_params(jax.random.key(0), table)
print(f"params: {cm.param_count(table) / 1e6:.1f}M")

step = jax.jit(make_train_step(
    T.make_loss_fn(cfg), cosine_schedule(1e-3, 10, args.steps)))
opt = adamw_init(params)

data = prefetch_to_device(iter(TokenStream(args.batch, args.seq, cfg.vocab)),
                          size=2)
t0 = time.perf_counter()
first = None
for i in range(args.steps):
    params, opt, m = step(params, opt, next(data))
    if first is None:
        first = float(m["nll"])
    if (i + 1) % 20 == 0:
        toks = args.batch * args.seq * (i + 1)
        dt = time.perf_counter() - t0
        print(f"step {i + 1}: nll={float(m['nll']):.4f} "
              f"lr={float(m['lr']):.2e} tok/s={toks / dt:,.0f}", flush=True)
print(f"nll {first:.3f} -> {float(m['nll']):.3f} "
      f"in {time.perf_counter() - t0:.1f}s")
assert float(m["nll"]) < first * 0.7, "loss must drop on the Markov stream"
print("OK")
