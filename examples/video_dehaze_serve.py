"""End-to-end serving driver (deliverable b): the paper's five-layer
network — spout → parallel workers → monitor — over a live synthetic
stream, with straggler mitigation and restart-safe stream state.

Run:  PYTHONPATH=src python examples/video_dehaze_serve.py
"""
import numpy as np

from repro.core import DehazeConfig
from repro.data import HazeVideoSpec, generate_haze_video
from repro.stream import ElasticServer, StreamRequest, StreamStateStore

video = generate_haze_video(HazeVideoSpec(height=120, width=160,
                                          n_frames=48, a_noise=0.0))

cfg = DehazeConfig(algorithm="cap", update_period=8, lam=0.05)
server = ElasticServer(cfg, n_workers=3, batch=8, timeout_s=0.02)

# --- serve the first half ---------------------------------------------------
emitted = []
rep1 = server.serve(iter(video.hazy[:24]),
                    sink=lambda fid, f: emitted.append(fid))
print(f"chunk 1: {rep1.frames} frames @ {rep1.fps:.1f} fps "
      f"(skipped {rep1.skipped})")

# --- simulate a crash + restart: stream state survives ------------------------
snapshot = server.store.to_pytree()           # checkpointable pytree
restarted = ElasticServer(cfg, n_workers=2, batch=8)
restarted.store = StreamStateStore.from_pytree(snapshot)
print(f"restarted at cursor {restarted.store.cursor('default')} with "
      f"A = {np.asarray(restarted.store.get('default').A).round(3)}")

rep2 = restarted.serve(iter(video.hazy[24:]),
                       sink=lambda fid, f: emitted.append(fid))
print(f"chunk 2: {rep2.frames} frames @ {rep2.fps:.1f} fps")

assert emitted == sorted(emitted), "monitor must emit in order"
assert restarted.store.cursor("default") == 48
print(f"emitted {len(emitted)} ordered frames across a restart — OK")

# --- multi-tenant: 4 cameras continuously batched over 2 device lanes --------
# Each stream keeps its own coherent A trajectory (one lane row of the
# lane-batched AtmoState); with fewer lanes than streams the scheduler
# queues the surplus and reuses lanes as streams end.
cameras = [generate_haze_video(HazeVideoSpec(
    height=120, width=160, n_frames=16 + 8 * i, seed=10 + i, a_noise=0.0,
    a_base=(0.72 + 0.05 * i,) * 3)) for i in range(4)]

fleet = ElasticServer(cfg, batch=8, timeout_s=0.02)
mrep = fleet.serve_many([StreamRequest(f"cam{i}", iter(v.hazy))
                         for i, v in enumerate(cameras)], n_lanes=2)
print(f"fleet: {mrep.frames} frames from {mrep.admissions} streams over "
      f"{mrep.n_lanes} lanes in {mrep.ticks} ticks "
      f"@ {mrep.aggregate_fps:.1f} aggregate fps")
for sid in sorted(mrep.per_stream):
    r = mrep.per_stream[sid]
    print(f"  {sid}: {r.frames} frames, skipped {r.skipped}, "
          f"A = {np.asarray(fleet.store.get(sid).A).round(3)}")
assert mrep.frames == sum(16 + 8 * i for i in range(4))
