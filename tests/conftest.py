# NOTE: deliberately NO XLA_FLAGS / device-count manipulation here — smoke
# tests and benches must see the real single CPU device. Multi-device tests
# spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def ramp_frames(seed, *lead, h, w):
    """Tie-stable differential-test frames: a seeded permutation gray ramp
    (all pixel levels distinct, separation 1/prod(shape)) with fixed
    per-channel scales (1.0, 0.9, 0.8), shaped ``lead + (h, w, 3)``.

    THE shared recipe for comparing top-k/argmin selections across
    *separately compiled* programs (fused kernel vs oracle, lane-native vs
    vmapped): both premaps (DCP ``min_c scale_c·g/A_c`` and CAP
    ``w0 + w1·g + w2·s``) are strictly monotone in the ramp for any
    atmospheric light, distinct t values sit orders of magnitude above
    cross-program FMA round-off, and every exact t tie is a min-filter
    plateau *copy* — resolved by flat index identically in both programs.
    Uniform random frames do hit coincidental 1-ulp boundary ties, which
    are legitimate cross-path behavior, not bugs. The channel scales keep
    R/G/B distinct at every pixel so channel mix-ups in a candidate
    gather or the EMA still show.
    """
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    n = int(np.prod(lead)) * h * w
    g = (r.permutation(n).reshape(*lead, h, w) + 1.0) / (n + 1.0)
    rgb = np.stack([g, 0.9 * g, 0.8 * g], axis=-1)
    return jnp.asarray(rgb.astype(np.float32))
