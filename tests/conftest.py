# NOTE: deliberately NO XLA_FLAGS / device-count manipulation here — smoke
# tests and benches must see the real single CPU device. Multi-device tests
# spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
