"""Unit tests for the HLO cost parser (launch/hlocost) — the measurement
backbone of the roofline table — plus the jaxpr FLOP walker, calibrated
against hand-computed counts and against XLA itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import flops as flops_mod
from repro.launch import hlocost


# --- jaxpr walker --------------------------------------------------------------

def test_traced_flops_matmul():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    got = flops_mod.traced_flops(f, a, b)
    assert got == 2 * 64 * 128 * 32


def test_traced_flops_scan_multiplies_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    got = flops_mod.traced_flops(f, x, ws)
    assert got >= 5 * 2 * 8 * 16 * 16          # 5 scan iterations


def test_traced_flops_conv():
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.ShapeDtypeStruct((1, 10, 10, 4), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 4, 8), jnp.float32)
    got = flops_mod.traced_flops(f, x, w)
    assert got == 2 * (8 * 8 * 8) * 9 * 4      # 2*out*k_spatial*cin


# --- HLO text parser -----------------------------------------------------------

HLO_SAMPLE = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[4,8]<=[32], to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlocost_while_trip_counts():
    cost = hlocost.cost_from_hlo_text(HLO_SAMPLE)
    # dot: 2*8*8*8 = 1024 flops x 10 trips
    assert cost.flops == 10 * 1024
    # all-reduce: 8x8 f32 = 256B, group size 8 -> 2*(7/8)*256 x 10
    want_ar = 10 * 2 * (7 / 8) * 256
    np.testing.assert_allclose(cost.collective_bytes["all-reduce"], want_ar)


def test_hlocost_collective_derating_kinds():
    hlo = """\
HloModule t

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16]{1,0} parameter(0)
  %ag = f32[16,16]{1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %cp = f32[16,16]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
  ROOT %o = f32[16,16]{1,0} add(%cp, %a)
}
"""
    cost = hlocost.cost_from_hlo_text(hlo)
    b = 16 * 16 * 4
    np.testing.assert_allclose(cost.collective_bytes["all-gather"],
                               b * 15 / 16)
    np.testing.assert_allclose(cost.collective_bytes["collective-permute"], b)
    # traffic: ag(in+out) + cp(in+out) + add(2 in + out)
    assert cost.traffic_bytes == pytest.approx(b * 2 + b * 2 + b * 3)


def test_hlocost_dus_counts_update_region_only():
    hlo = """\
HloModule t

ENTRY %main (big: f32[1024,64], upd: f32[1,64]) -> f32[1024,64] {
  %big = f32[1024,64]{1,0} parameter(0)
  %upd = f32[1,64]{1,0} parameter(1)
  %i = s32[] constant(5)
  ROOT %d = f32[1024,64]{1,0} dynamic-update-slice(%big, %upd, %i, %i)
}
"""
    cost = hlocost.cost_from_hlo_text(hlo)
    assert cost.traffic_bytes == 2 * 1 * 64 * 4   # update read+write only


def test_hlocost_matches_xla_on_simple_program():
    """End-to-end: parse a real compiled module and cross-check against
    XLA's own cost analysis (no loops -> both agree on FLOPs)."""
    f = jax.jit(lambda a, b: jax.nn.relu(a @ b))
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    compiled = f.lower(a, b).compile()
    got = hlocost.cost_from_hlo_text(compiled.as_text())
    xla = compiled.cost_analysis()
    if isinstance(xla, (list, tuple)):      # older jax returns [dict]
        xla = xla[0]
    assert got.flops == pytest.approx(float(xla["flops"]), rel=0.01)
