"""Regression tests for targeted bugfixes (no hypothesis dependency).

Covers: empty-batch EMA state round-trips (spout tail / elastic drain),
``resolve_mode`` rejecting unknown ``REPRO_KERNEL_MODE`` values instead of
silently taking the compiled-Pallas branch, and the fused megakernel's
``frames_per_block`` degrading to the largest dividing tile instead of 1.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ema_scan, ema_scan_associative, init_atmo_state
from repro.core.normalize import AtmoState
from repro.kernels import ops
from repro.kernels.fused import _resolve_frames_per_block


# --- empty-batch EMA state round-trip ----------------------------------------

@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_empty_batch_preserves_uninitialized_state(scan):
    """A zero-length batch must NOT flip ``initialized``: the next real
    first frame has to *replace* the white-light bootstrap placeholder, not
    EMA-blend with it."""
    state = init_atmo_state()
    empty = jnp.zeros((0, 3), jnp.float32)
    ids = jnp.zeros((0,), jnp.int32)
    a_seq, out = scan(empty, ids, state, period=4, lam=0.3)
    assert a_seq.shape == (0, 3)
    assert not bool(out.initialized)
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(state.A))
    assert int(out.last_update) == int(state.last_update)

    # The frame after the drain still bootstraps: A == candidate exactly.
    cand = jnp.asarray([[0.5, 0.6, 0.7]], jnp.float32)
    a_seq, out2 = scan(cand, jnp.asarray([12], jnp.int32), out,
                       period=4, lam=0.3)
    np.testing.assert_array_equal(np.asarray(a_seq[0]), np.asarray(cand[0]))
    assert bool(out2.initialized) and int(out2.last_update) == 12


@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_empty_batch_preserves_warm_state(scan):
    state = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(7, jnp.int32),
                      initialized=jnp.asarray(True))
    a_seq, out = scan(jnp.zeros((0, 3), jnp.float32),
                      jnp.zeros((0,), jnp.int32), state, period=4, lam=0.3)
    assert a_seq.shape == (0, 3)
    assert bool(out.initialized)
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(state.A))
    assert int(out.last_update) == 7


# --- resolve_mode env validation ---------------------------------------------

@pytest.mark.parametrize("bad", ["Pallas", "refs", "INTERPRET", "xla"])
def test_resolve_mode_rejects_unknown_env(monkeypatch, bad):
    """Unknown REPRO_KERNEL_MODE values used to fall through every dispatch
    wrapper's ``m == "ref"`` check into the compiled-Pallas branch."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", bad)
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        ops.resolve_mode("auto")
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        ops.dark_channel(jnp.zeros((1, 8, 8, 3)), 1)


def test_resolve_mode_rejects_unknown_argument():
    with pytest.raises(ValueError, match="unknown kernel mode"):
        ops.resolve_mode("fastest")


@pytest.mark.parametrize("env,expected", [
    ("ref", "ref"), ("pallas", "pallas"), ("interpret", "interpret"),
    ("fused", "ref"),       # pipeline-level mode -> default substrate (CPU)
    ("auto", "ref"),        # explicit "auto" == unset
])
def test_resolve_mode_accepts_known_env(monkeypatch, env, expected):
    monkeypatch.setenv("REPRO_KERNEL_MODE", env)
    assert ops.resolve_mode("auto") == expected
    assert ops.resolve_mode("fused") in ("ref", "pallas", "interpret")


def test_resolve_mode_explicit_arg_still_resolves(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    assert ops.resolve_mode("ref") == "ref"
    assert ops.resolve_mode("interpret") == "interpret"
    assert ops.resolve_mode("fused") in ("ref", "pallas")


# --- frames_per_block largest-divisor degradation ----------------------------

@pytest.mark.parametrize("batch,requested,expected", [
    (4, 3, 2),    # non-divisor rounds DOWN to the largest divisor, not to 1
    (6, 4, 3),
    (12, 5, 4),
    (5, 4, 1),    # prime batch: only 1 divides
    (4, 9, 4),    # over-request clamps to the batch
    (4, 0, 1),    # unset/registry-default
    (4, -1, 1),
])
def test_frames_per_block_largest_divisor(batch, requested, expected):
    assert _resolve_frames_per_block(batch, requested) == expected


def test_non_divisor_tile_stays_exact():
    """Requested tile 3 over a batch of 8 runs 2-frame blocks; the EMA grid
    carry must stay exact across the resulting block boundaries."""
    r = np.random.default_rng(3)
    img = jnp.asarray(r.random((8, 12, 16, 3), np.float32))
    ids = jnp.arange(8, dtype=jnp.int32)
    s = init_atmo_state()
    kw = dict(radius=2, omega=0.95, refine=False, gf_radius=2, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=3, lam=0.2)
    got = ops.fused_dehaze(img, ids, s.A, s.last_update, s.initialized,
                           frames_per_block=3, mode="interpret", **kw)
    want = ops.fused_dehaze(img, ids, s.A, s.last_update, s.initialized,
                            mode="ref", **kw)
    for g, w in zip(got[:3], want[:3]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=1e-5)
    assert int(got[4]) == int(want[4])
