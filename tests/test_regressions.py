"""Regression tests for targeted bugfixes (no hypothesis dependency).

Covers: empty-batch EMA state round-trips (spout tail / elastic drain),
``resolve_mode`` rejecting unknown ``REPRO_KERNEL_MODE`` values instead of
silently taking the compiled-Pallas branch, the fused megakernel's
``frames_per_block`` degrading to the largest dividing tile instead of 1,
spout tail padding being tagged ``frame_id = -1`` and masked out of
the EMA recurrence (it used to carry *future real* ids, double-advancing
the coherence state when the real frames with those ids arrived),
``tuning.autotune`` refusing to persist the built-in DEFAULTS as a
measured winner when every candidate raises, the serving stack defaulting
every deadline comparison to one monotonic clock (scheduler/fleet/
``serve_many`` used wall-clock ``time.time`` while the Monitor used
``time.monotonic`` — an NTP step could evict lanes or reorder EDF
admission spuriously), and ``LaneAutoscaler`` warm-up failures being
surfaced (logged, retried once, reported) instead of silently never
offering the rung.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ema_scan, ema_scan_associative, init_atmo_state
from repro.core.normalize import AtmoState
from repro.kernels import ops
from repro.kernels.fused import _resolve_frames_per_block
from repro.stream import Spout


# --- empty-batch EMA state round-trip ----------------------------------------

@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_empty_batch_preserves_uninitialized_state(scan):
    """A zero-length batch must NOT flip ``initialized``: the next real
    first frame has to *replace* the white-light bootstrap placeholder, not
    EMA-blend with it."""
    state = init_atmo_state()
    empty = jnp.zeros((0, 3), jnp.float32)
    ids = jnp.zeros((0,), jnp.int32)
    a_seq, out = scan(empty, ids, state, period=4, lam=0.3)
    assert a_seq.shape == (0, 3)
    assert not bool(out.initialized)
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(state.A))
    assert int(out.last_update) == int(state.last_update)

    # The frame after the drain still bootstraps: A == candidate exactly.
    cand = jnp.asarray([[0.5, 0.6, 0.7]], jnp.float32)
    a_seq, out2 = scan(cand, jnp.asarray([12], jnp.int32), out,
                       period=4, lam=0.3)
    np.testing.assert_array_equal(np.asarray(a_seq[0]), np.asarray(cand[0]))
    assert bool(out2.initialized) and int(out2.last_update) == 12


@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_empty_batch_preserves_warm_state(scan):
    state = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(7, jnp.int32),
                      initialized=jnp.asarray(True))
    a_seq, out = scan(jnp.zeros((0, 3), jnp.float32),
                      jnp.zeros((0,), jnp.int32), state, period=4, lam=0.3)
    assert a_seq.shape == (0, 3)
    assert bool(out.initialized)
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(state.A))
    assert int(out.last_update) == 7


# --- resolve_mode env validation ---------------------------------------------

@pytest.mark.parametrize("bad", ["Pallas", "refs", "INTERPRET", "xla"])
def test_resolve_mode_rejects_unknown_env(monkeypatch, bad):
    """Unknown REPRO_KERNEL_MODE values used to fall through every dispatch
    wrapper's ``m == "ref"`` check into the compiled-Pallas branch."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", bad)
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        ops.resolve_mode("auto")
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        ops.dark_channel(jnp.zeros((1, 8, 8, 3)), 1)


def test_resolve_mode_rejects_unknown_argument():
    with pytest.raises(ValueError, match="unknown kernel mode"):
        ops.resolve_mode("fastest")


@pytest.mark.parametrize("env,expected", [
    ("ref", "ref"), ("pallas", "pallas"), ("interpret", "interpret"),
    ("fused", "ref"),       # pipeline-level mode -> default substrate (CPU)
    ("auto", "ref"),        # explicit "auto" == unset
])
def test_resolve_mode_accepts_known_env(monkeypatch, env, expected):
    monkeypatch.setenv("REPRO_KERNEL_MODE", env)
    assert ops.resolve_mode("auto") == expected
    assert ops.resolve_mode("fused") in ("ref", "pallas", "interpret")


def test_resolve_mode_explicit_arg_still_resolves(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    assert ops.resolve_mode("ref") == "ref"
    assert ops.resolve_mode("interpret") == "interpret"
    assert ops.resolve_mode("fused") in ("ref", "pallas")


# --- spout padding must not advance coherence state --------------------------

def test_spout_padding_tagged_minus_one():
    frames = [np.full((4, 4, 3), i, np.float32) for i in range(6)]
    batches = list(Spout(iter(frames), batch=4))
    np.testing.assert_array_equal(batches[0].frame_ids, [0, 1, 2, 3])
    # Tail padding: ids are -1, NOT the future real ids 2..5.
    np.testing.assert_array_equal(batches[1].frame_ids, [4, 5, -1, -1])
    assert batches[1].n_valid == 2


@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_padding_ids_do_not_advance_ema(scan):
    """State after a padded batch [k, -1, -1, -1] must equal the state
    after just [k]; previously the padded tail got ids k+1..k+3 and the
    EMA advanced on duplicate frames whose ids were later reused."""
    rng = np.random.default_rng(0)
    cand = jnp.asarray(rng.random((4, 3)), jnp.float32)
    state = init_atmo_state()
    a_pad, s_pad = scan(cand, jnp.asarray([4, -1, -1, -1], jnp.int32),
                        state, period=2, lam=0.3)
    a_one, s_one = scan(cand[:1], jnp.asarray([4], jnp.int32),
                        state, period=2, lam=0.3)
    np.testing.assert_array_equal(np.asarray(s_pad.A), np.asarray(s_one.A))
    assert int(s_pad.last_update) == 4 and bool(s_pad.initialized)
    # Padding output slots carry the running A through unchanged.
    np.testing.assert_array_equal(np.asarray(a_pad[1:]),
                                  np.broadcast_to(np.asarray(a_one[0]), (3, 3)))


@pytest.mark.parametrize("scan", [ema_scan, ema_scan_associative],
                         ids=["scan", "associative"])
def test_all_padding_batch_is_identity(scan):
    """A batch of only padding (an unoccupied scheduler lane) behaves like
    the empty batch: no update, no ``initialized`` flip."""
    state = init_atmo_state()
    cand = jnp.ones((4, 3), jnp.float32) * 0.5
    ids = jnp.full((4,), -1, jnp.int32)
    _, out = scan(cand, ids, state, period=4, lam=0.3)
    assert not bool(out.initialized)
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(state.A))
    assert int(out.last_update) == int(state.last_update)

    warm = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                     last_update=jnp.asarray(7, jnp.int32),
                     initialized=jnp.asarray(True))
    _, out = scan(cand, ids, warm, period=4, lam=0.3)
    assert bool(out.initialized) and int(out.last_update) == 7
    np.testing.assert_array_equal(np.asarray(out.A), np.asarray(warm.A))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_fused_dehaze_masks_padding_ids(mode):
    """The megakernel's in-grid EMA carry must honor the same padding
    contract as the host-side scans."""
    r = np.random.default_rng(5)
    img = jnp.asarray(r.random((4, 12, 16, 3), np.float32))
    ids = jnp.asarray([8, 9, -1, -1], jnp.int32)
    s = init_atmo_state()
    kw = dict(radius=2, omega=0.95, refine=False, gf_radius=2, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=3, lam=0.2)
    got = ops.fused_dehaze(img, ids, s.A, s.last_update, s.initialized,
                           mode=mode, **kw)
    want = ops.fused_dehaze(img[:2], ids[:2], s.A, s.last_update,
                            s.initialized, mode=mode, **kw)
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               atol=1e-6)                  # A_fin
    assert int(got[4]) == int(want[4]) == 8                # k_fin: bootstrap@8


def test_serve_chunked_with_padded_tails_matches_unchunked():
    """End-to-end: serving a stream in two chunks whose tails are padded
    must leave the same EMA state as one uninterrupted serve — the
    original bug EMA-advanced on padded duplicates of frames 4..5, then
    again on the real frames 4..5 of chunk 2."""
    from repro.core import DehazeConfig
    from repro.stream import ElasticServer
    rng = np.random.default_rng(6)
    frames = [rng.random((16, 20, 3)).astype(np.float32) for _ in range(12)]
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2, update_period=2)

    srv_ref = ElasticServer(cfg, n_workers=1, batch=4, timeout_s=5.0)
    srv_ref.serve(iter(frames))
    srv = ElasticServer(cfg, n_workers=1, batch=4, timeout_s=5.0)
    srv.serve(iter(frames[:6]))      # tail batch: [4, 5, pad, pad]
    srv.serve(iter(frames[6:]))      # resumes at cursor 6
    np.testing.assert_allclose(
        np.asarray(srv.store.get("default").A),
        np.asarray(srv_ref.store.get("default").A), atol=1e-6)
    assert srv.store.cursor("default") == 12


# --- supports_fused must cover the full production config grid ---------------

def test_supports_fused_production_grid():
    """Regression gate for the fused-coverage contract: ``supports_fused``
    used to gate on ``topk == 1`` (and the halo kernel on height-only
    sharding), silently bouncing the production configs — robust top-k A
    estimation, W-sharded high-res frames — to the seven-launch per-stage
    chain. It must now return True for every serving config; if a future
    kernel change reintroduces a gate, this fails loudly instead of
    production quietly losing the megakernel.
    """
    import itertools

    from repro.core import DehazeConfig
    from repro.core import algorithms as alg

    grid = itertools.product(
        ("dcp", "cap"),                    # algorithm
        (1, 4, 32),                        # topk: Eq. 6 and robust top-k
        ("float32", "bfloat16"),           # serving dtypes
        (False, True),                     # halo_packed (sharded perf lever)
        ("float32", "bfloat16"),           # halo_dtype
        (1, 8),                            # update_period
    )
    for algorithm, topk, dtype, packed, hdt, period in grid:
        cfg = DehazeConfig(algorithm=algorithm, topk=topk, dtype=dtype,
                           halo_packed=packed, halo_dtype=hdt,
                           update_period=period, kernel_mode="fused")
        assert alg.supports_fused(cfg), (algorithm, topk, dtype, packed,
                                         hdt, period)
    # The one documented fallback: DCP's recompute-with-final-A second
    # transmission pass is inherently two-stage.
    assert not alg.supports_fused(
        DehazeConfig(algorithm="dcp", recompute_t_with_final_a=True))


def test_supports_fused_docs_match_behavior():
    """The docstring/config comment used to still describe the retired
    ``topk == 1`` gate; keep the prose in sync with the predicate."""
    import inspect

    from repro.core import algorithms as alg
    from repro.core import config as cfg_mod

    doc = inspect.getdoc(alg.supports_fused)
    assert "topk == 1" not in doc and "k=1) estimator" not in doc
    assert "top-k" in doc                 # coverage is called out explicitly
    src = inspect.getsource(cfg_mod)
    assert "top-k / recompute configs fall" not in src
    assert "any topk" in src


# --- frames_per_block largest-divisor degradation ----------------------------

@pytest.mark.parametrize("batch,requested,expected", [
    (4, 3, 2),    # non-divisor rounds DOWN to the largest divisor, not to 1
    (6, 4, 3),
    (12, 5, 4),
    (5, 4, 1),    # prime batch: only 1 divides
    (4, 9, 4),    # over-request clamps to the batch
    (4, 0, 1),    # unset/registry-default
    (4, -1, 1),
])
def test_frames_per_block_largest_divisor(batch, requested, expected):
    assert _resolve_frames_per_block(batch, requested) == expected


def test_non_divisor_tile_stays_exact():
    """Requested tile 3 over a batch of 8 runs 2-frame blocks; the EMA grid
    carry must stay exact across the resulting block boundaries."""
    r = np.random.default_rng(3)
    img = jnp.asarray(r.random((8, 12, 16, 3), np.float32))
    ids = jnp.arange(8, dtype=jnp.int32)
    s = init_atmo_state()
    kw = dict(radius=2, omega=0.95, refine=False, gf_radius=2, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=3, lam=0.2)
    got = ops.fused_dehaze(img, ids, s.A, s.last_update, s.initialized,
                           frames_per_block=3, mode="interpret", **kw)
    want = ops.fused_dehaze(img, ids, s.A, s.last_update, s.initialized,
                            mode="ref", **kw)
    for g, w in zip(got[:3], want[:3]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=1e-5)
    assert int(got[4]) == int(want[4])


# --- autotune all-candidates-fail must not persist DEFAULTS ------------------

def test_autotune_all_fail_does_not_persist_defaults(tmp_path, monkeypatch):
    """Pre-fix, ``autotune`` initialized the winner to ``DEFAULTS[op]`` and
    silently ``continue``d on every exception — a sweep whose every
    candidate raised (wrong shapes, VMEM overflow) persisted the built-in
    defaults into the table with full measured authority."""
    from repro.kernels import tuning

    table = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(table))

    def build(params):
        raise RuntimeError("candidate cannot compile")

    stats = tuning.TuneStats()
    with pytest.raises(tuning.AutotuneError, match="refusing to persist"):
        tuning.autotune("fused_dcp", (2, 8, 8),
                        [{"frames_per_block": f} for f in (1, 2, 4)],
                        build, stats=stats)
    assert not table.exists()                  # nothing persisted
    assert stats.skipped == {"RuntimeError": 3}
    # ...and the search core enforces the same contract.
    with pytest.raises(tuning.AutotuneError):
        tuning.measured_search("fused_dcp", (2, 8, 8),
                               [{"frames_per_block": 1}], build)
    assert not table.exists()


# --- one monotonic deadline clock across the serving stack -------------------

def test_deadline_clock_unified_monotonic(monkeypatch):
    """``MultiStreamScheduler``/``FleetScheduler``/``serve_many`` defaulted
    ``clock=time.time`` while the Monitor used ``time.monotonic``: a
    deadline produced against one timebase was compared against the other,
    and an NTP wall-clock step could instantly mark every deadlined lane
    tardy. All defaults must be the one shared monotonic DEADLINE_CLOCK."""
    import inspect
    import time

    from repro.stream import elastic, fleet, monitor, scheduler
    from repro.stream.state import StreamStateStore

    assert monitor.DEADLINE_CLOCK is time.monotonic
    for fn in (scheduler.MultiStreamScheduler.__init__,
               fleet.FleetScheduler.__init__,
               elastic.ElasticServer.serve_many,
               monitor.Monitor.__init__):
        default = inspect.signature(fn).parameters["clock"].default
        assert default is monitor.DEADLINE_CLOCK, fn.__qualname__

    # Behavioral: a deadline an hour out stays an hour out across a
    # simulated NTP step. With the old wall-clock default, clock() jumps
    # to epoch scale and the fresh deadline is instantly "past due".
    deadline = monitor.DEADLINE_CLOCK() + 3600.0
    monkeypatch.setattr(time, "time", lambda: 4.0e9)   # the NTP step
    sched = scheduler.MultiStreamScheduler(
        step=lambda *a: None, store=StreamStateStore(), n_lanes=1)
    assert sched._clock() < deadline           # not tardy: monotonic clock
    assert time.time() >= deadline             # the old default would be


# --- LaneAutoscaler warm failures surfaced, retried once, reported -----------

class _FlakyRungFactory:
    """Step factory whose rung-8 build fails ``fail_times`` times before
    succeeding (or forever, for the permanent-failure case)."""

    def __init__(self, fail_times):
        self.fail_times = fail_times
        self.attempts = {}

    def __call__(self, rung):
        self.attempts[rung] = self.attempts.get(rung, 0) + 1
        if rung == 8 and self.attempts[rung] <= self.fail_times:
            raise RuntimeError(f"rung {rung} compile blew VMEM")

        def step(frames, ids, state):
            import types
            return types.SimpleNamespace(state=state)
        return step


def _spin_until(cond, timeout=5.0):
    import time as _t
    t0 = _t.monotonic()
    while not cond():
        if _t.monotonic() - t0 > timeout:
            raise AssertionError("condition not reached in time")
        _t.sleep(0.005)


def test_warm_failure_surfaced_and_retried_once():
    """Pre-fix, a rung whose background warm-up raised was recorded in
    ``_warm_errors`` and then *nothing* referenced that dict: the rung was
    silently never offered. Now the failure is logged, retried once when
    the ladder actually wants the rung, and a successful retry makes the
    rung offerable."""
    from repro.stream.autoscale import LaneAutoscaler, ScalePolicy

    factory = _FlakyRungFactory(fail_times=1)      # transient: retry wins
    scaler = LaneAutoscaler(factory, rungs=(4, 8),
                            policy=ScalePolicy(rungs=(4, 8), dwell_up=2))
    scaler.acquire_initial()
    scaler.ensure_warming((1, 8, 8, 3))
    scaler.wait_warm(timeout=5.0)
    assert 8 in scaler.warm_errors                 # surfaced, not buried
    assert scaler.warm_failures == 1

    # Load wants the bigger rung: dwell reached -> the retry is kicked.
    assert scaler.observe(pending=2, occupied=4) is None
    assert scaler.observe(pending=2, occupied=4) is None
    _spin_until(lambda: scaler.is_ready(8))
    assert scaler.warm_errors == {}                # retry cleared it
    assert scaler.warm_failures == 0
    assert scaler.observe(pending=2, occupied=4) == 8
    assert factory.attempts[8] == 2


def test_warm_failure_permanent_raises_on_request():
    from repro.stream.autoscale import (WARM_MAX_ATTEMPTS, LaneAutoscaler,
                                        ScalePolicy)

    factory = _FlakyRungFactory(fail_times=10**9)  # permanent
    scaler = LaneAutoscaler(factory, rungs=(4, 8),
                            policy=ScalePolicy(rungs=(4, 8), dwell_up=2))
    scaler.acquire_initial()
    scaler.ensure_warming((1, 8, 8, 3))
    scaler.wait_warm(timeout=5.0)
    for _ in range(4):                             # retry budget exhausts
        scaler.observe(pending=2, occupied=4)
        scaler.wait_warm(timeout=5.0)
    assert factory.attempts[8] == WARM_MAX_ATTEMPTS   # exactly one retry
    assert scaler.warm_failures == 1
    with pytest.raises(RuntimeError, match="rung"):
        scaler.wait_warm(timeout=5.0, raise_on_error=True)


def test_warm_failures_ride_the_serve_report():
    """`ServeReport.warm_failures` carries the count (the
    --expect-switches serve path exits nonzero on it)."""
    import dataclasses

    from repro.stream.scheduler import ServeReport

    assert any(f.name == "warm_failures"
               for f in dataclasses.fields(ServeReport))
    rep = ServeReport(per_stream={}, frames=0, skipped=0, wall_s=0.0,
                      n_lanes=4, ticks=0, warm_failures=2)
    assert rep.warm_failures == 2
