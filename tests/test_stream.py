"""Stream runtime: monitor ordering property, straggler skip, spout, server."""
import threading
import time

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import DehazeConfig
from repro.stream import (ElasticServer, Monitor, Spout, StreamStateStore)


# --- monitor (paper §3.2 layer 5) --------------------------------------------

@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 60), seed=st.integers(0, 10_000))
def test_monitor_emits_in_order_for_any_completion_order(n, seed):
    order = np.random.default_rng(seed).permutation(n)
    got = []
    mon = Monitor(lambda fid, _: got.append(fid), timeout_s=60.0)
    for fid in order:
        mon.put(int(fid), None)
        mon.poll()
    mon.close()
    mon.drain()
    assert got == list(range(n))


def test_monitor_skips_on_timeout():
    """The paper's 20 ms reader rule: a missing frame is skipped, later
    frames still flow, and the skip is recorded."""
    clock = [0.0]
    got = []
    mon = Monitor(lambda fid, _: got.append(fid), timeout_s=0.02,
                  clock=lambda: clock[0])
    mon.put(0, None)
    mon.poll()
    mon.put(2, None)          # frame 1 is missing
    mon.poll()                # arms the deadline
    assert got == [0]
    clock[0] = 0.5            # deadline passes
    mon.poll()
    assert got == [0, 2]
    assert mon.stats.skipped == 1 and mon.stats.skipped_ids == [1]
    mon.put(1, None)          # late straggler arrives -> dropped
    mon.poll()
    assert got == [0, 2]


def test_monitor_waits_within_deadline():
    clock = [0.0]
    got = []
    mon = Monitor(lambda fid, _: got.append(fid), timeout_s=0.02,
                  clock=lambda: clock[0])
    mon.put(1, None)
    mon.poll()
    clock[0] = 0.01           # still within deadline
    mon.poll()
    assert got == []          # waiting for frame 0
    mon.put(0, None)
    mon.poll()
    assert got == [0, 1]
    assert mon.stats.skipped == 0


# --- spout -------------------------------------------------------------------

def test_spout_batches_and_pads():
    frames = [np.full((4, 4, 3), i, np.float32) for i in range(10)]
    batches = list(Spout(iter(frames), batch=4))
    assert len(batches) == 3
    assert [b.n_valid for b in batches] == [4, 4, 2]
    assert batches[2].frames.shape == (4, 4, 4, 3)
    # padding repeats the last real frame
    np.testing.assert_array_equal(batches[2].frames[3], frames[-1])
    ids = np.concatenate([b.frame_ids[:b.n_valid] for b in batches])
    np.testing.assert_array_equal(ids, np.arange(10))


# --- end-to-end server ---------------------------------------------------------

def test_elastic_server_ordered_and_complete():
    rng = np.random.default_rng(3)
    frames = [rng.random((24, 32, 3)).astype(np.float32) for _ in range(21)]
    srv = ElasticServer(DehazeConfig(kernel_mode="ref", gf_radius=3),
                        n_workers=3, batch=4, timeout_s=1.0)
    got = []
    rep = srv.serve(iter(frames), sink=lambda fid, f: got.append(fid))
    assert got == list(range(21))
    assert rep.frames == 21 and rep.skipped == 0


def test_elastic_server_straggler_skip():
    """A pathologically slow worker triggers the paper's skip rule yet the
    output stays ordered."""
    rng = np.random.default_rng(4)
    frames = [rng.random((16, 16, 3)).astype(np.float32) for _ in range(24)]
    srv = ElasticServer(DehazeConfig(kernel_mode="ref", gf_radius=2),
                        n_workers=3, batch=4, timeout_s=0.005,
                        worker_delay_s=lambda w: 0.25 if w == 1 else 0.0)
    got = []
    rep = srv.serve(iter(frames), sink=lambda fid, f: got.append(fid))
    assert got == sorted(got)
    assert rep.skipped + len(got) == 24


def test_elastic_resize_and_stream_state_continuity():
    rng = np.random.default_rng(5)
    frames = [rng.random((16, 16, 3)).astype(np.float32) for _ in range(8)]
    srv = ElasticServer(DehazeConfig(kernel_mode="ref", gf_radius=2),
                        n_workers=1, batch=4)
    srv.serve(iter(frames))
    state1 = srv.store.get("default")
    assert bool(state1.initialized)
    srv.resize(3)
    rep = srv.serve(iter(frames))
    assert rep.n_workers == 3
    assert srv.store.cursor("default") == 16


def test_stream_state_store_checkpoint_roundtrip():
    import jax.numpy as jnp
    from repro.core.normalize import AtmoState
    store = StreamStateStore()
    store.update("cam0", AtmoState(
        A=jnp.asarray([0.5, 0.6, 0.7]),
        last_update=jnp.asarray(12, jnp.int32),
        initialized=jnp.asarray(True)), cursor=13)
    tree = store.to_pytree()
    restored = StreamStateStore.from_pytree(tree)
    assert restored.cursor("cam0") == 13
    np.testing.assert_allclose(np.asarray(restored.get("cam0").A),
                               [0.5, 0.6, 0.7], atol=1e-6)
