"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU, asserting output shapes and absence of NaNs. The FULL
assigned configs are exercised only through the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgreg
from repro.models import common as cm


def _assert_finite(x):
    assert not bool(jnp.isnan(x).any()) and not bool(jnp.isinf(x).any())


# --- LM family ----------------------------------------------------------------

@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b",
                                  "llama4-scout-17b-a16e",
                                  "granite-20b", "llama3-8b"])
def test_lm_smoke_forward_and_decode(arch):
    from repro.models import transformer as T
    cfg = cfgreg.get_module(arch).smoke_config()
    params = cm.init_params(jax.random.key(0), T.lm_param_table(cfg))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, aux = jax.jit(T.make_forward(cfg))(params, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    _assert_finite(logits)
    if cfg.moe_experts:
        assert float(aux) > 0.0

    loss_fn = jax.jit(T.make_loss_fn(cfg))
    l, m = loss_fn(params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    _assert_finite(l)
    # sanity: loss near ln(vocab) at init
    assert abs(float(m["nll"]) - np.log(cfg.vocab)) < 1.5

    prefill = jax.jit(T.make_prefill(cfg, max_len=32))
    decode = jax.jit(T.make_decode_step(cfg))
    last, cache = prefill(params, toks[:, :8])
    assert last.shape == (2, cfg.vocab)
    lg, cache2 = decode(params, cache, toks[:, 8:9])
    assert lg.shape == (2, 1, cfg.vocab)
    assert int(cache2["pos"]) == 9
    _assert_finite(lg)


def test_lm_train_step_reduces_loss():
    from repro.models import transformer as T
    from repro.models.steps import make_train_step
    from repro.optim import adamw_init, cosine_schedule
    cfg = cfgreg.get_module("llama3-8b").smoke_config()
    params = cm.init_params(jax.random.key(0), T.lm_param_table(cfg))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(T.make_loss_fn(cfg),
                                   cosine_schedule(3e-3, 5, 200)))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    first = None
    for _ in range(30):
        params, opt, metrics = step(params, opt, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first * 0.7
    assert int(opt.step) == 30


# --- diffusion family ----------------------------------------------------------

def test_dit_smoke():
    from repro.models import dit as M
    cfg = cfgreg.get_module("dit-l2").smoke_config()
    params = cm.init_params(jax.random.key(0), M.dit_param_table(cfg))
    lat = cfg.latent_res
    z = jax.random.normal(jax.random.key(1), (2, lat, lat, 4))
    t = jnp.asarray([1, 500])
    y = jnp.asarray([0, 3])
    out = jax.jit(M.make_forward(cfg))(params, z, t, y)
    assert out.shape == (2, lat, lat, 8)
    _assert_finite(out)
    z2 = jax.jit(M.make_sample_step(cfg))(params, z, t, t - 1, y)
    assert z2.shape == z.shape
    _assert_finite(z2)


def test_unet_smoke():
    from repro.models import unet as M
    cfg = cfgreg.get_module("unet-sdxl").smoke_config()
    params = cm.init_params(jax.random.key(0), M.unet_param_table(cfg))
    lat = cfg.latent_res
    z = jax.random.normal(jax.random.key(1), (2, lat, lat, 4))
    ctx = jax.random.normal(jax.random.key(2), (2, cfg.ctx_len, cfg.ctx_dim))
    pooled = jax.random.normal(jax.random.key(3), (2, cfg.ctx_dim))
    out = jax.jit(M.make_forward(cfg))(params, z, jnp.asarray([7, 9]),
                                       ctx, pooled)
    assert out.shape == (2, lat, lat, 4)
    _assert_finite(out)


def test_unet_plan_stack_balances():
    from repro.models.unet import build_plan
    cfg = cfgreg.get_module("unet-sdxl").config()
    down, mid, up = build_plan(cfg)
    pushes = 1 + sum(1 for b in down if b.kind in ("res", "down"))
    pops = sum(1 for b in up if b.kind == "res")
    assert pushes == pops
    assert sum(1 for b in mid if b.kind == "attn") == 1


# --- vision family ---------------------------------------------------------------

@pytest.mark.parametrize("arch", ["vit-l16", "resnet-50",
                                  "efficientnet-b7", "convnext-b"])
def test_vision_smoke_forward(arch):
    mod = cfgreg.get_module(arch)
    cfg = mod.smoke_config()
    img = jax.random.uniform(jax.random.key(1), (2, cfg.img_res, cfg.img_res, 3))
    if arch == "vit-l16":
        from repro.models import vit as M
        params = cm.init_params(jax.random.key(0), M.vit_param_table(cfg))
        logits = jax.jit(M.make_forward(cfg))(params, img)
        n_cls = cfg.n_classes
    elif arch == "resnet-50":
        from repro.models import resnet as M
        params = cm.init_params(jax.random.key(0), M.resnet_param_table(cfg))
        logits, _ = jax.jit(M.make_forward(cfg, training=False))(params, img)
        n_cls = cfg.n_classes
    elif arch == "efficientnet-b7":
        from repro.models import efficientnet as M
        params = cm.init_params(jax.random.key(0),
                                M.efficientnet_param_table(cfg))
        logits, _ = jax.jit(M.make_forward(cfg, training=False))(params, img)
        n_cls = cfg.n_classes
    else:
        from repro.models import convnext as M
        params = cm.init_params(jax.random.key(0),
                                M.convnext_param_table(cfg))
        logits = jax.jit(M.make_forward(cfg))(params, img)
        n_cls = cfg.n_classes
    assert logits.shape == (2, n_cls)
    _assert_finite(logits)


def test_resnet_bn_stats_update_and_merge():
    from repro.models import resnet as M
    from repro.models.steps import make_train_step
    from repro.optim import adamw_init, cosine_schedule
    cfg = cfgreg.get_module("resnet-50").smoke_config()
    params = cm.init_params(jax.random.key(0), M.resnet_param_table(cfg))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(M.make_loss_fn(cfg),
                                   cosine_schedule(1e-3, 5, 100),
                                   has_bn=True))
    img = jax.random.uniform(jax.random.key(1), (4, cfg.img_res, cfg.img_res, 3))
    batch = {"images": img, "labels": jnp.asarray([0, 1, 2, 3])}
    before = np.asarray(params["stem_bn"]["mean"]).copy()
    params, opt, metrics = step(params, opt, batch)
    after = np.asarray(params["stem_bn"]["mean"])
    assert not np.allclose(before, after), "BN running stats must move"
    _assert_finite(metrics["loss"])


# --- structural invariants ----------------------------------------------------

@pytest.mark.parametrize("arch", list(cfgreg.ASSIGNED_ARCHS))
def test_param_table_specs_and_shapes_align(arch):
    """params, pspecs and ShapeDtypeStructs must share one tree structure."""
    mod = cfgreg.get_module(arch)
    cfg = mod.smoke_config()
    fam = mod.FAMILY
    if fam == "lm":
        from repro.models.transformer import lm_param_table as table_fn
    elif arch == "dit-l2":
        from repro.models.dit import dit_param_table as table_fn
    elif arch == "unet-sdxl":
        from repro.models.unet import unet_param_table as table_fn
    elif arch == "vit-l16":
        from repro.models.vit import vit_param_table as table_fn
    elif arch == "resnet-50":
        from repro.models.resnet import resnet_param_table as table_fn
    elif arch == "efficientnet-b7":
        from repro.models.efficientnet import efficientnet_param_table as table_fn
    else:
        from repro.models.convnext import convnext_param_table as table_fn
    table = table_fn(cfg)
    shapes = cm.param_shapes(table)
    specs = cm.param_pspecs(table)
    s1 = jax.tree_util.tree_structure(shapes)
    s2 = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))
    assert s1 == s2
    for sh, spec in zip(jax.tree_util.tree_leaves(shapes),
                        jax.tree_util.tree_leaves(
                            specs, is_leaf=lambda x: isinstance(
                                x, jax.sharding.PartitionSpec))):
        assert len(spec) <= len(sh.shape)
