"""End-to-end behaviour of the paper's system on synthetic hazy video:
coherence (Fig. 6/8 claims), serving continuity across restart (fault
tolerance), and the full spout -> workers -> monitor path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video
from repro.stream import ElasticServer, StreamStateStore


def _video(n=32, h=48, w=64, seed=0, a_noise=0.03):
    return generate_haze_video(HazeVideoSpec(
        height=h, width=w, n_frames=n, seed=seed, a_noise=a_noise))


def _luminance_series(frames):
    return np.asarray([0.299 * f[..., 0] + 0.587 * f[..., 1]
                       + 0.114 * f[..., 2] for f in frames]).mean(axis=(1, 2))


def test_update_strategy_reduces_flicker():
    """Paper Fig. 6: per-frame independent A estimation flickers; the §3.3
    update strategy smooths it. Measured as the std of frame-to-frame
    luminance deltas of the dehazed stream.

    The paper's premise is that the TRUE atmospheric light varies slowly
    ("adjacent frames own similar atmospheric light", §3.3) while the
    per-frame estimates jitter; a_noise=0 models exactly that — the
    estimator's own noise (argmin pixel jumping with scene motion) is what
    the EMA must remove."""
    vid = _video(n=48, seed=2, a_noise=0.0)
    frames = jnp.asarray(vid.hazy)
    ids = jnp.arange(48, dtype=jnp.int32)

    def run(update_period, lam):
        cfg = DehazeConfig(kernel_mode="ref", gf_radius=4,
                           update_period=update_period, lam=lam)
        step = jax.jit(make_dehaze_step(cfg))
        out = step(frames, ids, init_atmo_state())
        return np.asarray(out.frames), np.asarray(out.atmo_light)

    # "independent": update every frame with lam=1 (A_m = A_new).
    raw_frames, raw_A = run(1, 1.0)
    ema_frames, ema_A = run(4, 0.05)

    flicker_raw = np.abs(np.diff(_luminance_series(raw_frames))).std()
    flicker_ema = np.abs(np.diff(_luminance_series(ema_frames))).std()
    assert flicker_ema <= flicker_raw * 1.05

    # A-curve smoothness (Fig. 8): EMA curve varies less.
    assert np.abs(np.diff(ema_A, axis=0)).mean() \
        < np.abs(np.diff(raw_A, axis=0)).mean()


def test_serving_restart_continues_A_trajectory():
    """Kill the server mid-stream, restore the stream-state store from its
    checkpoint pytree, continue: the EMA state and cursor must carry over
    (coherence across restart — DESIGN.md fault-tolerance claim)."""
    vid = _video(n=24, seed=3)
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=3, update_period=4)

    # Uninterrupted reference.
    srv_ref = ElasticServer(cfg, n_workers=1, batch=4)
    srv_ref.serve(iter(vid.hazy))
    a_ref = np.asarray(srv_ref.store.get("default").A)

    # Interrupted at frame 12 + restart from checkpointed store.
    srv1 = ElasticServer(cfg, n_workers=1, batch=4)
    srv1.serve(iter(vid.hazy[:12]))
    snapshot = srv1.store.to_pytree()
    del srv1                                     # "crash"
    srv2 = ElasticServer(cfg, n_workers=1, batch=4)
    srv2.store = StreamStateStore.from_pytree(snapshot)
    assert srv2.store.cursor("default") == 12
    srv2.serve(iter(vid.hazy[12:]))
    a_resumed = np.asarray(srv2.store.get("default").A)
    np.testing.assert_allclose(a_resumed, a_ref, atol=1e-6)
    assert srv2.store.cursor("default") == 24


def test_dehazing_accuracy_on_synthetic_ground_truth():
    vid = _video(n=8, seed=4, a_noise=0.01)
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=4)
    step = jax.jit(make_dehaze_step(cfg))
    out = step(jnp.asarray(vid.hazy), jnp.arange(8, dtype=jnp.int32),
               init_atmo_state())
    err_hazy = np.abs(vid.hazy - vid.clear).mean()
    err_dehazed = np.abs(np.asarray(out.frames) - vid.clear).mean()
    assert err_dehazed < err_hazy * 0.9
    # Transmission correlates with ground truth.
    t_est = np.asarray(out.transmission).ravel()
    t_true = vid.t.ravel()
    corr = np.corrcoef(t_est, t_true)[0, 1]
    assert corr > 0.5, corr


def test_multi_stream_state_isolation():
    """Two concurrent videos keep independent A-light states (the paper's
    future-work extension, §5)."""
    vid_a = _video(n=8, seed=5)
    vid_b = generate_haze_video(HazeVideoSpec(
        height=48, width=64, n_frames=8, seed=6,
        a_base=(0.7, 0.7, 0.72)))
    srv = ElasticServer(DehazeConfig(kernel_mode="ref", gf_radius=3),
                        n_workers=2, batch=4)
    srv.serve(iter(vid_a.hazy), stream_id="camA")
    srv.serve(iter(vid_b.hazy), stream_id="camB")
    a1 = np.asarray(srv.store.get("camA").A)
    a2 = np.asarray(srv.store.get("camB").A)
    assert not np.allclose(a1, a2)
    assert srv.store.cursor("camA") == 8 and srv.store.cursor("camB") == 8
