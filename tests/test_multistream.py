"""Multi-tenant serving: lane-batched steps + continuous batching scheduler.

Per-stream outputs of ``serve_many`` must match sequential single-stream
serves to float32 round-off (the vmapped program may fuse FMA differently,
so "bit-identical" holds up to <= 2 ULP on the staged XLA path and exactly
on the fused path), with the same skipped-frame semantics; lanes must
evict + be reused mid-serve; a lane-packed ``StreamStateStore`` must
checkpoint/restart through ``to_pytree``/``from_pytree``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (DehazeConfig, get_lane_state, init_atmo_state,
                        init_atmo_state_lanes, make_dehaze_step,
                        make_multi_stream_step, pack_atmo_states,
                        set_lane_state, unpack_atmo_states)
from repro.core.normalize import AtmoState
from repro.stream import (ElasticServer, Monitor, StreamRequest,
                          StreamStateStore)

ATOL = 3e-7          # float32 round-off between vmapped and plain programs


def _streams(n, lengths, h=16, w=20, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.random((h, w, 3)).astype(np.float32) for _ in range(k)]
            for k in lengths[:n]]


# --- lane-batched state helpers ----------------------------------------------

def test_pack_unpack_roundtrip():
    states = [AtmoState(A=jnp.asarray([0.1 * i, 0.2, 0.3], jnp.float32),
                        last_update=jnp.asarray(i, jnp.int32),
                        initialized=jnp.asarray(i % 2 == 0))
              for i in range(3)]
    packed = pack_atmo_states(states)
    assert packed.A.shape == (3, 3) and packed.last_update.shape == (3,)
    back = unpack_atmo_states(packed)
    for s, b in zip(states, back):
        np.testing.assert_array_equal(np.asarray(s.A), np.asarray(b.A))
        assert int(s.last_update) == int(b.last_update)
        assert bool(s.initialized) == bool(b.initialized)


def test_set_lane_state_replaces_one_lane():
    packed = init_atmo_state_lanes(3)
    s = AtmoState(A=jnp.asarray([0.5, 0.6, 0.7], jnp.float32),
                  last_update=jnp.asarray(9, jnp.int32),
                  initialized=jnp.asarray(True))
    packed = set_lane_state(packed, 1, s)
    lane1 = get_lane_state(packed, 1)
    np.testing.assert_array_equal(np.asarray(lane1.A),
                                  np.asarray([0.5, 0.6, 0.7], np.float32))
    assert int(lane1.last_update) == 9 and bool(lane1.initialized)
    for i in (0, 2):
        assert not bool(get_lane_state(packed, i).initialized)


# --- lane-vmapped step vs single-stream step ---------------------------------

@pytest.mark.parametrize("mode", ["ref", "fused"])
def test_multi_stream_step_matches_single(mode):
    cfg = DehazeConfig(kernel_mode=mode, gf_radius=2, update_period=2)
    rng = np.random.default_rng(1)
    frames = jnp.asarray(rng.random((3, 4, 16, 20, 3)), jnp.float32)
    ids = jnp.stack([jnp.arange(4, dtype=jnp.int32)] * 3)
    ids = ids.at[2].set(jnp.full((4,), -1, jnp.int32))   # lane 2 unoccupied
    multi = make_multi_stream_step(cfg)
    single = make_dehaze_step(cfg)
    packed = init_atmo_state_lanes(3)
    out = multi(frames, ids, packed)
    for lane in range(2):
        ref = single(frames[lane], ids[lane], init_atmo_state())
        np.testing.assert_allclose(np.asarray(out.frames[lane]),
                                   np.asarray(ref.frames), atol=ATOL, rtol=0)
        np.testing.assert_allclose(np.asarray(out.state.A[lane]),
                                   np.asarray(ref.state.A), atol=ATOL, rtol=0)
        assert int(out.state.last_update[lane]) == int(ref.state.last_update)
    # The padding lane's state rides through bit-unchanged.
    assert not bool(out.state.initialized[2])
    np.testing.assert_array_equal(np.asarray(out.state.A[2]),
                                  np.asarray(packed.A[2]))


# --- serve_many vs sequential serves -----------------------------------------

@pytest.mark.parametrize("mode", ["ref", "fused"])
def test_serve_many_matches_sequential(mode):
    """Interleaved lanes (incl. fewer lanes than streams -> eviction +
    reuse, and uneven lengths -> padded tails) produce per-stream outputs
    equal to sequential single-stream serves, same skip semantics."""
    cfg = DehazeConfig(kernel_mode=mode, gf_radius=2, update_period=2)
    vids = _streams(4, [10, 7, 13, 5])
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    outs = {}
    rep = srv.serve_many(
        [StreamRequest(f"s{i}", iter(v)) for i, v in enumerate(vids)],
        n_lanes=2,
        sink=lambda sid, fid, f: outs.setdefault((sid, fid), f))
    assert rep.frames == 35 and rep.skipped == 0
    assert rep.admissions == 4 and rep.n_lanes == 2

    for i, v in enumerate(vids):
        ref_srv = ElasticServer(cfg, n_workers=1, batch=4, timeout_s=5.0)
        ref_outs = {}
        ref_rep = ref_srv.serve(iter(v), stream_id=f"s{i}",
                                sink=lambda fid, f: ref_outs.setdefault(fid, f))
        assert ref_rep.skipped == 0
        assert rep.per_stream[f"s{i}"].frames == ref_rep.frames
        for fid, f in ref_outs.items():
            np.testing.assert_allclose(outs[(f"s{i}", fid)], f,
                                       atol=ATOL, rtol=0)
        # Same final EMA state + restart-safe cursor in the store.
        np.testing.assert_allclose(
            np.asarray(srv.store.get(f"s{i}").A),
            np.asarray(ref_srv.store.get(f"s{i}").A), atol=ATOL, rtol=0)
        assert srv.store.cursor(f"s{i}") == len(v)


def test_serve_many_lane_eviction_and_reuse():
    """More streams than lanes: every stream completes in order through
    lane turnover, and per-stream monitors keep streams isolated."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    vids = _streams(5, [6, 3, 9, 4, 5], seed=2)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    emitted = {}
    rep = srv.serve_many(
        [StreamRequest(f"cam{i}", iter(v)) for i, v in enumerate(vids)],
        n_lanes=2,
        sink=lambda sid, fid, f: emitted.setdefault(sid, []).append(fid))
    assert rep.admissions == 5
    assert rep.frames == sum(len(v) for v in vids) and rep.skipped == 0
    for i, v in enumerate(vids):
        assert emitted[f"cam{i}"] == list(range(len(v)))


def test_serve_many_checkpoint_restart():
    """Kill the fleet mid-way, restore the lane-packed store from its
    checkpoint pytree, serve the remainder: same A trajectories and
    cursors as one uninterrupted serve_many."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2, update_period=2)
    vids = _streams(3, [12, 8, 10], seed=3)

    ref_srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    ref_srv.serve_many([StreamRequest(f"s{i}", iter(v))
                        for i, v in enumerate(vids)])

    srv1 = ElasticServer(cfg, batch=4, timeout_s=5.0)
    srv1.serve_many([StreamRequest(f"s{i}", iter(v[:len(v) // 2]))
                     for i, v in enumerate(vids)])
    snapshot = srv1.store.to_pytree()
    del srv1                                             # "crash"

    srv2 = ElasticServer(cfg, batch=4, timeout_s=5.0)
    srv2.store = StreamStateStore.from_pytree(snapshot)
    for i, v in enumerate(vids):
        assert srv2.store.cursor(f"s{i}") == len(v) // 2
    srv2.serve_many([StreamRequest(f"s{i}", iter(v[len(v) // 2:]))
                     for i, v in enumerate(vids)])
    for i, v in enumerate(vids):
        np.testing.assert_allclose(
            np.asarray(srv2.store.get(f"s{i}").A),
            np.asarray(ref_srv.store.get(f"s{i}").A), atol=1e-6)
        assert srv2.store.cursor(f"s{i}") == len(v)


def test_serve_many_rejects_mismatched_resolutions():
    """A mismatched stream raises, but the server shuts down cleanly:
    live lanes are evicted (state + cursor persisted, monitors drained)
    and the server stays usable."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    a = _streams(1, [8], h=16, w=20)[0]
    b = _streams(1, [4], h=12, w=20, seed=4)[0]
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    with pytest.raises(ValueError, match="must share"):
        srv.serve_many([StreamRequest("a", iter(a)),
                        StreamRequest("b", iter(b))])
    # The failed call flushed its lanes; a fresh serve_many still works.
    rep = srv.serve_many([StreamRequest("c", iter(_streams(1, [6],
                                                           seed=5)[0]))])
    assert rep.frames == 6 and rep.skipped == 0


def test_serve_many_rejects_duplicate_stream_ids():
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    v = _streams(2, [4, 4], seed=6)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    with pytest.raises(ValueError, match="duplicate stream ids"):
        srv.serve_many([StreamRequest("cam", iter(v[0])),
                        StreamRequest("cam", iter(v[1]))])


# --- satellite: bounded monitor skip history ---------------------------------

def test_monitor_skipped_ids_bounded():
    mon = Monitor(lambda fid, payload: None, timeout_s=60.0,
                  max_skipped_ids=4)
    mon.put(100, None)           # frames 0..99 are gaps
    mon.close()
    mon.drain()
    assert mon.stats.skipped == 100              # running count intact
    assert mon.stats.skipped_ids == [96, 97, 98, 99]   # last K only
    assert mon.stats.emitted == 1


# --- satellite: bounded LRU step cache ---------------------------------------

def test_step_cache_lru_bounded():
    from repro.stream.elastic import _LRUStepCache
    cache = _LRUStepCache(maxsize=3)
    for i in range(10):
        cache.get(("single", i), lambda i=i: f"step{i}")
    assert len(cache) == 3
    # Most recent survive; LRU entries were dropped and rebuild on demand.
    builds = []
    cache.get(("single", 9), lambda: builds.append(1) or "rebuilt")
    assert builds == []                          # hit
    cache.get(("single", 0), lambda: builds.append(1) or "rebuilt")
    assert builds == [1]                         # miss -> rebuilt


# --- satellite: lane-native path through serve_many --------------------------

def test_serve_many_forced_vmap_matches_lane_native(monkeypatch):
    """REPRO_LANE_NATIVE=0 forces the vmapped fused path; results match a
    lane-native serve of the same streams (the env toggle is an A/B lever,
    not a semantics switch)."""
    cfg = DehazeConfig(kernel_mode="fused", gf_radius=2, update_period=2)
    vids = _streams(3, [6, 9, 4], seed=7)

    outs_native = {}
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    srv.serve_many([StreamRequest(f"s{i}", iter(v))
                    for i, v in enumerate(vids)], n_lanes=2,
                   sink=lambda sid, fid, f: outs_native.setdefault(
                       (sid, fid), f))

    monkeypatch.setenv("REPRO_LANE_NATIVE", "0")
    outs_vmap = {}
    srv2 = ElasticServer(cfg, batch=4, timeout_s=5.0)
    rep = srv2.serve_many([StreamRequest(f"s{i}", iter(v))
                           for i, v in enumerate(vids)], n_lanes=2,
                          sink=lambda sid, fid, f: outs_vmap.setdefault(
                              (sid, fid), f))
    assert rep.frames == 19 and rep.skipped == 0
    assert outs_native.keys() == outs_vmap.keys()
    for k in outs_native:
        np.testing.assert_allclose(outs_native[k], outs_vmap[k], atol=ATOL,
                                   rtol=0)


def test_lane_native_env_force_requires_fused_config(monkeypatch):
    """REPRO_LANE_NATIVE=1 on a config the megakernel cannot cover must
    raise, not silently fall back — CI relies on this to know its smoke
    run actually exercised the lane-native path."""
    from repro.core import make_multi_stream_step, resolve_lane_native
    monkeypatch.setenv("REPRO_LANE_NATIVE", "1")
    with pytest.raises(ValueError, match="REPRO_LANE_NATIVE"):
        resolve_lane_native(DehazeConfig(kernel_mode="ref"))
    with pytest.raises(ValueError, match="REPRO_LANE_NATIVE"):
        make_multi_stream_step(DehazeConfig(kernel_mode="fused",
                                            algorithm="dcp",
                                            recompute_t_with_final_a=True))
    # ...and a fused-covered config resolves lane-native.
    assert resolve_lane_native(DehazeConfig(kernel_mode="fused"))
    monkeypatch.setenv("REPRO_LANE_NATIVE", "maybe")
    with pytest.raises(ValueError, match="REPRO_LANE_NATIVE"):
        resolve_lane_native(DehazeConfig(kernel_mode="fused"))


# --- satellite: step cache keys on lane count and dispatch path --------------

def test_step_cache_keys_on_lane_count_and_path():
    """Regression: the bounded LRU used to key multi-stream steps on the
    config alone, so a serve_many resize (or a lane-native toggle) reused
    a stale compiled step. The key must include n_lanes and the
    lane-native-vs-vmap path."""
    from repro.stream.elastic import _LRUStepCache
    cache = _LRUStepCache(maxsize=8)
    cfg = DehazeConfig(kernel_mode="fused", gf_radius=2)
    builds = []
    for key in [("multi", cfg, 2, True), ("multi", cfg, 3, True),
                ("multi", cfg, 2, False)]:
        cache.get(key, lambda key=key: builds.append(key) or object())
    assert len(builds) == 3 and len(cache) == 3
    # Same (cfg, lanes, path) -> cache hit, no rebuild.
    cache.get(("multi", cfg, 2, True), lambda: builds.append("again"))
    assert "again" not in builds


def test_serve_many_resize_between_calls():
    """End-to-end form of the cache regression: the same server serving
    the same config at two lane counts must produce correct per-stream
    results both times (the second call must not reuse the 2-lane step)."""
    cfg = DehazeConfig(kernel_mode="fused", gf_radius=2, update_period=2)
    vids = _streams(3, [5, 6, 4], seed=11)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    rep2 = srv.serve_many([StreamRequest(f"a{i}", iter(v))
                           for i, v in enumerate(vids)], n_lanes=2)
    rep3 = srv.serve_many([StreamRequest(f"b{i}", iter(v))
                           for i, v in enumerate(vids)], n_lanes=3)
    assert rep2.frames == rep3.frames == 15
    assert rep2.skipped == 0 and rep3.skipped == 0
    for i, v in enumerate(vids):
        np.testing.assert_allclose(np.asarray(srv.store.get(f"a{i}").A),
                                   np.asarray(srv.store.get(f"b{i}").A),
                                   atol=ATOL, rtol=0)


# --- satellite: legacy tuple entries keep working (with a warning) -----------

def test_legacy_tuple_entries_coerce_with_deprecation_warning():
    """(stream_id, frames) and (stream_id, frames, deadline) tuples still
    serve correctly but emit DeprecationWarning; StreamRequest does not."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    vids = _streams(2, [4, 3], seed=29)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    with pytest.warns(DeprecationWarning, match="StreamRequest"):
        rep = srv.serve_many([("pair", iter(vids[0])),
                              ("triple", iter(vids[1]), 5.0)])
    assert rep.frames == 7 and rep.skipped == 0
    assert rep.per_stream["pair"].frames == 4
    assert rep.per_stream["triple"].frames == 3

    import warnings as _warnings
    srv2 = ElasticServer(cfg, batch=4, timeout_s=5.0)
    vids2 = _streams(1, [4], seed=31)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        srv2.serve_many([StreamRequest("clean", iter(vids2[0]))])


def test_malformed_entries_rejected():
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    with pytest.raises(TypeError, match="StreamRequest"):
        srv.serve_many(["just-a-string"])
    with pytest.raises(TypeError, match="StreamRequest"):
        srv.serve_many([("sid",)])


# --- satellite: deadline-aware (EDF) admission -------------------------------

def _admission_order(streams, n_lanes=1):
    """Serve on a single lane and recover the admission order from the
    order streams complete (with one lane, completion order == admission
    order)."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    order = []
    srv.serve_many(streams, n_lanes=n_lanes,
                   sink=lambda sid, fid, f: order.append(sid)
                   if sid not in order else None)
    return order


def test_admission_fifo_by_default():
    vids = _streams(3, [4, 4, 4], seed=13)
    order = _admission_order([StreamRequest(f"s{i}", iter(v))
                              for i, v in enumerate(vids)])
    assert order == ["s0", "s1", "s2"]


def test_admission_earliest_deadline_first():
    """Deadlined streams preempt the queue in deadline order; deadline-less
    streams go last (FIFO among themselves); equal deadlines tie-break by
    arrival."""
    vids = _streams(5, [4, 4, 4, 4, 4], seed=17)
    entries = [StreamRequest("batch0", iter(vids[0])),  # no deadline, first
               StreamRequest("rt_late", iter(vids[1]), deadline=50.0),
               StreamRequest("rt_soon", iter(vids[2]), deadline=2.0),
               StreamRequest("rt_tie", iter(vids[3]), deadline=50.0),
               StreamRequest("batch1", iter(vids[4]), deadline=None)]
    order = _admission_order(entries)
    assert order == ["rt_soon", "rt_late", "rt_tie", "batch0", "batch1"]


def test_admission_priority_classes_outrank_deadlines():
    """priority orders ahead of the deadline key: a negative-priority
    stream admits before the whole default class even when a default-class
    stream has the earliest deadline."""
    vids = _streams(4, [4, 4, 4, 4], seed=23)
    entries = [StreamRequest("rt", iter(vids[0]), deadline=1.0),
               StreamRequest("vip", iter(vids[1]), priority=-1),
               StreamRequest("bulk", iter(vids[2]), priority=5),
               StreamRequest("vip_rt", iter(vids[3]), deadline=9.0,
                             priority=-1)]
    order = _admission_order(entries)
    assert order == ["vip_rt", "vip", "rt", "bulk"]


def test_admission_deadline_streams_complete_and_match():
    """EDF reordering changes only admission order: every stream's outputs
    still match its sequential serve (per-lane state isolation holds)."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2, update_period=2)
    vids = _streams(3, [6, 5, 7], seed=19)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    outs = {}
    rep = srv.serve_many(
        [StreamRequest("a", iter(vids[0]), deadline=9.0),
         StreamRequest("b", iter(vids[1]), deadline=1.0),
         StreamRequest("c", iter(vids[2]))], n_lanes=2,
        sink=lambda sid, fid, f: outs.setdefault((sid, fid), f))
    assert rep.frames == 18 and rep.skipped == 0
    for sid, v in zip("abc", vids):
        ref_srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
        ref_outs = {}
        ref_srv.serve(iter(v), stream_id=sid,
                      sink=lambda fid, f: ref_outs.setdefault(fid, f))
        for fid, f in ref_outs.items():
            np.testing.assert_allclose(outs[(sid, fid)], f, atol=ATOL,
                                       rtol=0)

def test_legacy_tuple_warning_points_at_caller():
    """The DeprecationWarning's stacklevel must attribute the legacy tuple
    to the code that passed it (this file), not to repro internals — and
    the message must carry the removal version so the attribution is
    actionable."""
    import warnings as _warnings
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    vids = _streams(1, [3], seed=37)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always", DeprecationWarning)
        srv.serve_many([("legacy", iter(vids[0]))])
    got = [c for c in caught if issubclass(c.category, DeprecationWarning)]
    assert got, "legacy tuple entry must warn"
    assert all(c.filename == __file__ for c in got), \
        [(c.filename, c.lineno) for c in got]
    assert "removed in v0.3" in str(got[0].message)
