"""PlacementSpec: validation, hashability, derived PartitionSpecs, wire
roundtrip — the declarative layer every step builder now runs through."""
import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro.core import PlacementSpec


# --- construction + validation ----------------------------------------------

def test_constructors_validate_clean():
    PlacementSpec.single()
    PlacementSpec.lane_batched()
    PlacementSpec.lane_batched(n_hosts=4)
    PlacementSpec.lane_sharded()
    PlacementSpec.lane_sharded(lane_axis="data", height_axis="model")
    PlacementSpec.frame_sharded()
    PlacementSpec.frame_sharded(batch_axes=("pod", "data"),
                                height_axis="model", width_axis="model2")


@pytest.mark.parametrize("kwargs,match", [
    (dict(n_hosts=0), "n_hosts"),
    (dict(lane_axis="data"), "requires lanes=True"),
    (dict(lanes=True, lane_axis="data", batch_axes=("pod",)),
     "mutually exclusive"),
    (dict(lanes=True, batch_axes=("data",)), "do not shard the frame axis"),
    (dict(batch_axes=("data",), height_axis="data"), "distinct"),
    (dict(lanes=True, lane_axis="data", height_axis="data"), "distinct"),
])
def test_validate_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        PlacementSpec(**kwargs).validate()


def test_hashable_and_cache_key_stable():
    """The spec keys the serving step cache: equal placements must hash
    equal even when batch_axes arrives as a JSON list."""
    a = PlacementSpec(batch_axes=("data",), height_axis="model")
    b = PlacementSpec(batch_axes=["data"], height_axis="model")  # type: ignore
    assert a == b and hash(a) == hash(b)
    assert isinstance(b.batch_axes, tuple)
    assert len({a, b}) == 1
    # frozen: no mutation after construction
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.lanes = True  # type: ignore[misc]


# --- derived views -----------------------------------------------------------

def test_mesh_axes_and_sharded_flag():
    assert PlacementSpec.single().mesh_axes == ()
    assert not PlacementSpec.single().sharded
    assert not PlacementSpec.lane_batched(n_hosts=2).sharded
    assert PlacementSpec.lane_sharded(
        lane_axis="data", height_axis="model").mesh_axes == ("data", "model")
    assert PlacementSpec.frame_sharded(
        batch_axes=("pod", "data"), height_axis="model").mesh_axes \
        == ("pod", "data", "model")


def test_partition_specs_single_and_frame_sharded():
    single = PlacementSpec.single()
    assert single.frame_spec() == P(None, None, None)
    assert single.ids_spec() == P(None)
    assert single.state_spec().A == P()

    fs = PlacementSpec.frame_sharded(batch_axes=("data",),
                                     height_axis="model")
    assert fs.frame_spec() == P(("data",), "model", None)
    assert fs.ids_spec() == P(("data",))
    assert fs.state_spec().A == P()          # replicated: collective sync


def test_partition_specs_lane_placements():
    lb = PlacementSpec.lane_batched()
    assert lb.frame_spec() == P(None, None, None, None)
    assert lb.ids_spec() == P(None)
    assert lb.state_spec().A == P(None)

    ls = PlacementSpec.lane_sharded(lane_axis="data", height_axis="model")
    assert ls.frame_spec() == P("data", None, "model", None)
    assert ls.ids_spec() == P("data")
    # EMA rows co-placed with their lanes — the no-sync invariant
    st = ls.state_spec()
    assert st.A == P("data")
    assert st.last_update == P("data")
    assert st.initialized == P("data")


# --- wire form ---------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    PlacementSpec.single(),
    PlacementSpec.lane_batched(n_hosts=3),
    PlacementSpec.lane_sharded(lane_axis="data", height_axis="model",
                               n_hosts=2),
    PlacementSpec.frame_sharded(batch_axes=("pod", "data"),
                                height_axis="model", width_axis="model2"),
])
def test_dict_roundtrip(spec):
    d = spec.to_dict()
    assert isinstance(d["batch_axes"], list)          # JSON-able
    back = PlacementSpec.from_dict(d)
    assert back == spec and hash(back) == hash(spec)


def test_from_dict_validates():
    with pytest.raises(ValueError, match="requires lanes=True"):
        PlacementSpec.from_dict({"lane_axis": "data"})
