"""Fused DCP megakernel: interpret-mode parity vs the jnp oracle, tiling
registry behavior, and pipeline-level equivalence with the per-stage chain.

No hypothesis dependency here on purpose — this file is the minimal-install
coverage for the fused hot path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.core.normalize import AtmoState
from repro.kernels import ops, ref, tuning
from repro.kernels.fused import fused_dehaze_dcp_pallas, fused_transmission_pallas

# Odd H/W (not divisible by any tile), plus an even multi-frame shape.
SHAPES = [(1, 33, 17), (2, 24, 32), (4, 16, 16)]

FUSED_KW = dict(radius=3, omega=0.95, refine=False, gf_radius=4, gf_eps=1e-3,
                t0=0.1, gamma=1.0, period=2, lam=0.3)


def _img(shape, dtype=jnp.float32, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.random(shape + (3,), np.float32)).astype(dtype)


def _state(warm=False):
    if not warm:
        s = init_atmo_state()
    else:
        s = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(7, jnp.int32),
                      initialized=jnp.asarray(True))
    return s


def _run(img, state, mode, **kw):
    b = img.shape[0]
    ids = jnp.arange(10, 10 + b, dtype=jnp.int32)
    return ops.fused_dehaze_dcp(img, ids, state.A, state.last_update,
                                state.initialized, mode=mode, **kw)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("warm", [False, True])
def test_fused_parity_f32(shape, warm):
    """Acceptance gate: max abs err <= 1e-5 vs the oracle in float32."""
    img = _img(shape)
    state = _state(warm)
    got = _run(img, state, "interpret", **FUSED_KW)
    want = _run(img, state, "ref", **FUSED_KW)
    for g, w in zip(got[:3], want[:3]):                  # J, t, a_seq
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               atol=1e-5)                # final A
    assert int(got[4]) == int(want[4])                   # final last_update


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_fused_parity_with_guided_refine(shape):
    kw = dict(FUSED_KW, refine=True)
    img = _img(shape, seed=3)
    got = _run(img, _state(), "interpret", **kw)
    want = _run(img, _state(), "ref", **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-4)


def test_fused_parity_bfloat16():
    img = _img((2, 24, 32), jnp.bfloat16, seed=5)
    got = _run(img, _state(), "interpret", **FUSED_KW)
    want = _run(img, _state(), "ref", **FUSED_KW)
    assert got[0].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got[0], np.float32),
                               np.asarray(want[0], np.float32), atol=2e-2)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=2e-2)


@pytest.mark.parametrize("fpb", [2, 4, 3])
def test_fused_frames_per_block(fpb):
    """Multi-frame grid blocks keep the EMA carry exact; a non-dividing
    block size falls back to 1 frame per step rather than failing."""
    img = _img((4, 16, 16), seed=7)
    state = _state()
    ids = jnp.arange(4, dtype=jnp.int32)
    got = fused_dehaze_dcp_pallas(
        img, ids, state.A, state.last_update, state.initialized,
        frames_per_block=fpb, interpret=True, **FUSED_KW)
    want = _run(img, state, "ref", **FUSED_KW)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=1e-5)


def test_fused_large_frame_ids_stay_exact():
    """Frame ids past 2^24 (a week of continuous streaming) must not lose
    precision in the kernel's EMA carry — ids stay int32 end-to-end."""
    img = _img((4, 8, 8), seed=23)
    base = 2 ** 24
    ids = jnp.asarray([base, base + 1, base + 2, base + 3], jnp.int32)
    state = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(base - 1, jnp.int32),
                      initialized=jnp.asarray(True))
    got = ops.fused_dehaze_dcp(img, ids, state.A, state.last_update,
                               state.initialized, mode="interpret", **FUSED_KW)
    want = ops.fused_dehaze_dcp(img, ids, state.A, state.last_update,
                                state.initialized, mode="ref", **FUSED_KW)
    assert int(got[4]) == int(want[4])
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=1e-5)


@pytest.mark.parametrize("t0", [0.3, 0.95])
def test_fused_t_min_clamping(t0):
    """Dense haze: t_raw falls below t0 everywhere; Eq. 8 must clamp, stay
    finite, and still match the oracle."""
    # Near-white frames -> dark channel ~1 -> t_raw ~ 1 - omega ~ 0.05 < t0.
    img = jnp.clip(_img((2, 16, 16), seed=11) * 0.05 + 0.93, 0.0, 1.0)
    kw = dict(FUSED_KW, t0=t0)
    got = _run(img, _state(), "interpret", **kw)
    want = _run(img, _state(), "ref", **kw)
    assert np.isfinite(np.asarray(got[0])).all()
    assert float(jnp.min(got[1])) < t0            # raw t really is clamped
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)


def test_fused_transmission_stage_parity():
    img = _img((2, 24, 32), seed=13)
    A = jnp.asarray([0.9, 0.92, 0.88], jnp.float32)
    kw = dict(radius=3, omega=0.95, refine=True, gf_radius=4, gf_eps=1e-3)
    t_i, tmin_i, rgb_i = fused_transmission_pallas(img, A, interpret=True, **kw)
    t_r, tmin_r, rgb_r = ref.fused_transmission_dcp(img, A, **kw)
    np.testing.assert_allclose(np.asarray(t_i), np.asarray(t_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tmin_i), np.asarray(tmin_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(rgb_i), np.asarray(rgb_r), atol=1e-5)


# --- pipeline wiring ---------------------------------------------------------

def _pipeline_pair(monkeypatch, substrate):
    if substrate:
        monkeypatch.setenv("REPRO_KERNEL_MODE", substrate)
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    out_f = make_dehaze_step(DehazeConfig(kernel_mode="fused",
                                          update_period=2))(
        J, ids, init_atmo_state())
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    out_r = make_dehaze_step(DehazeConfig(kernel_mode="ref",
                                          update_period=2))(
        J, ids, init_atmo_state())
    return out_f, out_r


def _scene():
    r = np.random.default_rng(17)
    J = jnp.asarray(r.random((4, 24, 32, 3), np.float32))
    return J, None


@pytest.mark.parametrize("substrate", ["", "interpret"])
def test_pipeline_fused_matches_ref_chain(monkeypatch, substrate):
    """make_dehaze_step(kernel_mode="fused") == the per-stage ref chain
    (on CPU the fused substrate resolves to the oracle; with
    REPRO_KERNEL_MODE=interpret it runs the actual kernel body)."""
    out_f, out_r = _pipeline_pair(monkeypatch, substrate)
    np.testing.assert_allclose(np.asarray(out_f.frames),
                               np.asarray(out_r.frames), atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_f.transmission),
                               np.asarray(out_r.transmission), atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_f.atmo_light),
                               np.asarray(out_r.atmo_light), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_f.state.A),
                               np.asarray(out_r.state.A), atol=1e-4)


def test_pipeline_fused_falls_back_for_cap():
    """CAP has no fused variant yet — kernel_mode="fused" must still work."""
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    out = make_dehaze_step(DehazeConfig(algorithm="cap",
                                        kernel_mode="fused"))(
        J, ids, init_atmo_state())
    assert not bool(jnp.isnan(out.frames).any())


def test_sharded_step_selects_fused():
    """Single-device mesh: the sharded step's fused branch must agree with
    its per-stage branch."""
    from repro.core.pipeline import make_sharded_dehaze_step
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    outs = {}
    for mode in ("fused", "ref"):
        cfg = DehazeConfig(kernel_mode=mode, update_period=2)
        step, _, _ = make_sharded_dehaze_step(cfg, mesh, ("data",), None)
        outs[mode] = step(J, ids, init_atmo_state())
    np.testing.assert_allclose(np.asarray(outs["fused"].frames),
                               np.asarray(outs["ref"].frames), atol=2e-4)
    np.testing.assert_allclose(np.asarray(outs["fused"].transmission),
                               np.asarray(outs["ref"].transmission), atol=2e-4)


# --- tiling registry / autotune ----------------------------------------------

def test_tuning_defaults_and_env_override(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "none.json"))
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 4}')
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 4}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", "not json")
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1}


def test_tuning_table_roundtrip(monkeypatch, tmp_path):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(path))
    tuning.save_table({"fused_dcp": {"4x16x16": {"frames_per_block": 2}}})
    assert json.loads(path.read_text())["fused_dcp"]["4x16x16"] == \
        {"frames_per_block": 2}
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 2}
    # Other shapes fall back to the default.
    assert tuning.get_params("fused_dcp", (1, 8, 8)) == \
        {"frames_per_block": 1}


def test_autotune_picks_fastest_and_persists(monkeypatch, tmp_path):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(path))

    def build(params):
        if params["frames_per_block"] == 3:        # non-dividing tile
            raise ValueError("bad tile")
        import time

        def run():
            time.sleep(0.001 * params["frames_per_block"])
            return jnp.zeros(())
        return run

    best = tuning.autotune("fused_dcp", (4, 16, 16),
                           [{"frames_per_block": f} for f in (3, 1, 2)],
                           build, iters=1)
    assert best == {"frames_per_block": 1}
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == best


def test_fused_dispatch_reads_registry(monkeypatch, tmp_path):
    """ops.fused_dehaze_dcp resolves frames_per_block from the registry."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "t.json"))
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 2}')
    img = _img((4, 16, 16), seed=19)
    got = _run(img, _state(), "auto", **FUSED_KW)
    want = _run(img, _state(), "ref", **FUSED_KW)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)
