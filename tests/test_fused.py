"""Fused megakernels (DCP + CAP): interpret-mode parity vs the jnp oracles,
halo-aware masking semantics, tiling registry behavior, and pipeline-level
equivalence with the per-stage chain.

No hypothesis dependency here on purpose — this file is the minimal-install
coverage for the fused hot path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.core.normalize import AtmoState
from repro.kernels import ops, ref, tuning
from repro.kernels.fused import (fused_dehaze_dcp_pallas,
                                 fused_transmission_halo_pallas,
                                 fused_transmission_pallas)

# Odd H/W (not divisible by any tile), plus an even multi-frame shape.
SHAPES = [(1, 33, 17), (2, 24, 32), (4, 16, 16)]

FUSED_KW = dict(radius=3, omega=0.95, refine=False, gf_radius=4, gf_eps=1e-3,
                t0=0.1, gamma=1.0, period=2, lam=0.3)


def _img(shape, dtype=jnp.float32, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.random(shape + (3,), np.float32)).astype(dtype)


def _state(warm=False):
    if not warm:
        s = init_atmo_state()
    else:
        s = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(7, jnp.int32),
                      initialized=jnp.asarray(True))
    return s


def _run(img, state, mode, **kw):
    b = img.shape[0]
    ids = jnp.arange(10, 10 + b, dtype=jnp.int32)
    return ops.fused_dehaze(img, ids, state.A, state.last_update,
                            state.initialized, mode=mode, **kw)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("warm", [False, True])
def test_fused_parity_f32(shape, warm):
    """Acceptance gate: max abs err <= 1e-5 vs the oracle in float32."""
    img = _img(shape)
    state = _state(warm)
    got = _run(img, state, "interpret", **FUSED_KW)
    want = _run(img, state, "ref", **FUSED_KW)
    for g, w in zip(got[:3], want[:3]):                  # J, t, a_seq
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               atol=1e-5)                # final A
    assert int(got[4]) == int(want[4])                   # final last_update


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_fused_parity_with_guided_refine(shape):
    kw = dict(FUSED_KW, refine=True)
    img = _img(shape, seed=3)
    got = _run(img, _state(), "interpret", **kw)
    want = _run(img, _state(), "ref", **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-4)


def test_fused_parity_bfloat16():
    img = _img((2, 24, 32), jnp.bfloat16, seed=5)
    got = _run(img, _state(), "interpret", **FUSED_KW)
    want = _run(img, _state(), "ref", **FUSED_KW)
    assert got[0].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got[0], np.float32),
                               np.asarray(want[0], np.float32), atol=2e-2)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=2e-2)


@pytest.mark.parametrize("algorithm", ["cap"])
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("warm", [False, True])
def test_fused_parity_cap(shape, warm, algorithm):
    """CAP megakernel (Eq. 4 depth pre-map + exp transmission): max abs err
    <= 1e-5 vs the oracle, cold and warm state."""
    kw = dict(FUSED_KW, algorithm=algorithm, beta=1.2)
    img = _img(shape, seed=29)
    state = _state(warm)
    got = _run(img, state, "interpret", **kw)
    want = _run(img, state, "ref", **kw)
    for g, w in zip(got[:3], want[:3]):                  # J, t, a_seq
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               atol=1e-5)                # final A
    assert int(got[4]) == int(want[4])                   # final last_update


def test_fused_parity_cap_with_guided_refine():
    kw = dict(FUSED_KW, algorithm="cap", refine=True)
    img = _img((2, 24, 32), seed=31)
    got = _run(img, _state(), "interpret", **kw)
    want = _run(img, _state(), "ref", **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-4)


def test_fused_transmission_cap_stage_parity():
    img = _img((2, 24, 32), seed=37)
    A = jnp.asarray([0.9, 0.92, 0.88], jnp.float32)
    kw = dict(algorithm="cap", radius=3, beta=1.0, refine=True, gf_radius=4,
              gf_eps=1e-3)
    t_i, tmin_i, rgb_i = fused_transmission_pallas(img, A, interpret=True, **kw)
    t_r, tmin_r, rgb_r = ref.fused_transmission(img, A, **kw)
    np.testing.assert_allclose(np.asarray(t_i), np.asarray(t_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tmin_i), np.asarray(tmin_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(rgb_i), np.asarray(rgb_r), atol=1e-5)


@pytest.mark.parametrize("fpb", [2, 4, 3])
def test_fused_frames_per_block(fpb):
    """Multi-frame grid blocks keep the EMA carry exact; a non-dividing
    block size rounds down to the largest divisor (3 -> 2 over a batch of
    4) rather than failing."""
    img = _img((4, 16, 16), seed=7)
    state = _state()
    ids = jnp.arange(4, dtype=jnp.int32)
    got = fused_dehaze_dcp_pallas(
        img, ids, state.A, state.last_update, state.initialized,
        frames_per_block=fpb, interpret=True, **FUSED_KW)
    want = _run(img, state, "ref", **FUSED_KW)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=1e-5)


def test_fused_large_frame_ids_stay_exact():
    """Frame ids past 2^24 (a week of continuous streaming) must not lose
    precision in the kernel's EMA carry — ids stay int32 end-to-end."""
    img = _img((4, 8, 8), seed=23)
    base = 2 ** 24
    ids = jnp.asarray([base, base + 1, base + 2, base + 3], jnp.int32)
    state = AtmoState(A=jnp.asarray([0.8, 0.85, 0.9], jnp.float32),
                      last_update=jnp.asarray(base - 1, jnp.int32),
                      initialized=jnp.asarray(True))
    got = ops.fused_dehaze_dcp(img, ids, state.A, state.last_update,
                               state.initialized, mode="interpret", **FUSED_KW)
    want = ops.fused_dehaze_dcp(img, ids, state.A, state.last_update,
                                state.initialized, mode="ref", **FUSED_KW)
    assert int(got[4]) == int(want[4])
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               atol=1e-5)


@pytest.mark.parametrize("t0", [0.3, 0.95])
def test_fused_t_min_clamping(t0):
    """Dense haze: t_raw falls below t0 everywhere; Eq. 8 must clamp, stay
    finite, and still match the oracle."""
    # Near-white frames -> dark channel ~1 -> t_raw ~ 1 - omega ~ 0.05 < t0.
    img = jnp.clip(_img((2, 16, 16), seed=11) * 0.05 + 0.93, 0.0, 1.0)
    kw = dict(FUSED_KW, t0=t0)
    got = _run(img, _state(), "interpret", **kw)
    want = _run(img, _state(), "ref", **kw)
    assert np.isfinite(np.asarray(got[0])).all()
    assert float(jnp.min(got[1])) < t0            # raw t really is clamped
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)


def test_fused_transmission_stage_parity():
    img = _img((2, 24, 32), seed=13)
    A = jnp.asarray([0.9, 0.92, 0.88], jnp.float32)
    kw = dict(radius=3, omega=0.95, refine=True, gf_radius=4, gf_eps=1e-3)
    t_i, tmin_i, rgb_i = fused_transmission_pallas(img, A, interpret=True, **kw)
    t_r, tmin_r, rgb_r = ref.fused_transmission_dcp(img, A, **kw)
    np.testing.assert_allclose(np.asarray(t_i), np.asarray(t_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tmin_i), np.asarray(tmin_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(rgb_i), np.asarray(rgb_r), atol=1e-5)


# --- halo-aware fused transmission (height-sharded stage) --------------------

HALO_KW = dict(radius=3, omega=0.95, beta=1.0, gf_radius=4, gf_eps=1e-3)


def _halo_inputs(h_loc=16, w=20, halo=5, b=2, seed=41):
    """Synthetic halo-extended shard inputs with *garbage* in the invalid
    rows — masking must make them irrelevant."""
    r = np.random.default_rng(seed)
    img = jnp.asarray(r.random((b, h_loc, w, 3), np.float32))
    pre_ext = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
    guide_ext = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
    return img, pre_ext, guide_ext, halo


MASKS = {
    "interior": lambda n, halo: jnp.ones((n,), bool),
    "top-edge": lambda n, halo: jnp.arange(n) >= halo,
    "bottom-edge": lambda n, halo: jnp.arange(n) < n - halo,
}


@pytest.mark.parametrize("mask", sorted(MASKS))
@pytest.mark.parametrize("algorithm", ["dcp", "cap"])
@pytest.mark.parametrize("refine", [False, True])
def test_fused_halo_parity(mask, algorithm, refine):
    """Halo kernel (interpret) vs the masked XLA chain oracle, including
    mesh-edge shards where row-validity masking must reproduce the
    clipped-window border semantics. Acceptance gate: <= 1e-5 max-abs."""
    img, pre_ext, guide_ext, halo = _halo_inputs()
    valid = MASKS[mask](pre_ext.shape[1], halo)
    kw = dict(HALO_KW, algorithm=algorithm, refine=refine)
    got = fused_transmission_halo_pallas(img, pre_ext, guide_ext, valid,
                                         interpret=True, **kw)
    want = ref.fused_transmission_halo(img, pre_ext, guide_ext, valid, **kw)
    for g, w in zip(got, want):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err


def _halo_inputs_2d(h_loc=14, w_loc=18, halo=5, b=2, seed=47):
    """W-extended variant of ``_halo_inputs`` (2-D shard of an H x W mesh),
    again with garbage in the invalid rows *and* columns."""
    r = np.random.default_rng(seed)
    img = jnp.asarray(r.random((b, h_loc, w_loc, 3), np.float32))
    pre_ext = jnp.asarray(
        r.random((b, h_loc + 2 * halo, w_loc + 2 * halo), np.float32))
    guide_ext = jnp.asarray(
        r.random((b, h_loc + 2 * halo, w_loc + 2 * halo), np.float32))
    return img, pre_ext, guide_ext, halo


W_MASKS = {
    "interior": lambda n, halo: jnp.ones((n,), bool),
    "left-edge": lambda n, halo: jnp.arange(n) >= halo,
    "right-edge": lambda n, halo: jnp.arange(n) < n - halo,
}


@pytest.mark.parametrize("hmask", sorted(MASKS))
@pytest.mark.parametrize("wmask", sorted(W_MASKS))
@pytest.mark.parametrize("topk", [1, 4])
def test_fused_halo_parity_2d(hmask, wmask, topk):
    """2-D (H x W) shard masking: the halo kernel with row *and* column
    validity — including the corner shards of a 2-D mesh, where both masks
    clip — must match the masked XLA chain oracle, for the argmin and the
    robust top-k candidate estimators."""
    img, pre_ext, guide_ext, halo = _halo_inputs_2d()
    valid_h = MASKS[hmask](pre_ext.shape[1], halo)
    valid_w = W_MASKS[wmask](pre_ext.shape[2], halo)
    kw = dict(HALO_KW, algorithm="dcp", refine=True, topk=topk)
    got = fused_transmission_halo_pallas(img, pre_ext, guide_ext, valid_h,
                                         valid_w, interpret=True, **kw)
    want = ref.fused_transmission_halo(img, pre_ext, guide_ext, valid_h,
                                       valid_w, **kw)
    for g, w in zip(got, want):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))


def test_fused_halo_frames_per_block():
    """Halo-kernel tiling (``fused_halo_2d`` bucket): multiple frames per
    grid step must be output-identical to one frame per step."""
    img, pre_ext, guide_ext, halo = _halo_inputs_2d(b=4)
    valid_h = jnp.arange(pre_ext.shape[1]) >= halo
    valid_w = jnp.arange(pre_ext.shape[2]) < pre_ext.shape[2] - halo
    kw = dict(HALO_KW, algorithm="cap", refine=True, topk=2)
    got = fused_transmission_halo_pallas(img, pre_ext, guide_ext, valid_h,
                                         valid_w, frames_per_block=2,
                                         interpret=True, **kw)
    want = ref.fused_transmission_halo(img, pre_ext, guide_ext, valid_h,
                                       valid_w, **kw)
    for g, w in zip(got, want):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err


@pytest.mark.parametrize("algorithm", ["dcp", "cap"])
def test_fused_halo_stitches_to_full_frame(algorithm):
    """Two hand-built shards (top edge + bottom edge) run through the halo
    kernel stitch bit-comparably into the unsharded fused oracle — the
    in-kernel masking preserves global clipped-window border semantics."""
    r = np.random.default_rng(43)
    b, h, w = 2, 32, 20
    h_loc = h // 2
    img = jnp.asarray(r.random((b, h, w, 3), np.float32))
    A = jnp.asarray([0.9, 0.92, 0.88], jnp.float32)
    kw = dict(HALO_KW, algorithm=algorithm, refine=True)
    # Halo composition rule (core.spatial): patch_radius + 2 * gf_radius.
    halo = kw["radius"] + 2 * kw["gf_radius"]

    pre = ref.premap(img, jnp.maximum(A, 1e-3), algorithm)
    guide = ref.luminance(img)
    junk = jnp.asarray(r.random((b, halo, w), np.float32))

    t_parts, tmins, rgbs = [], [], []
    for s, rows in enumerate((slice(0, h_loc), slice(h_loc, h))):
        lo, hi = rows.start - halo, rows.stop + halo
        if s == 0:                      # top shard: rows above image invalid
            pre_ext = jnp.concatenate([junk, pre[:, :hi]], axis=1)
            guide_ext = jnp.concatenate([junk, guide[:, :hi]], axis=1)
            valid = jnp.arange(h_loc + 2 * halo) >= halo
        else:                           # bottom shard: rows below invalid
            pre_ext = jnp.concatenate([pre[:, lo:], junk], axis=1)
            guide_ext = jnp.concatenate([guide[:, lo:], junk], axis=1)
            valid = jnp.arange(h_loc + 2 * halo) < h_loc + halo
        t, tk_t, tk_rgb, _ = fused_transmission_halo_pallas(
            img[:, rows], pre_ext, guide_ext, valid, interpret=True, **kw)
        t_parts.append(t)
        tmins.append(tk_t[:, 0])
        rgbs.append(tk_rgb[:, 0])

    t_full, tmin_full, rgb_full = ref.fused_transmission(
        img, A, algorithm=algorithm, radius=kw["radius"], omega=kw["omega"],
        beta=kw["beta"], refine=True, gf_radius=kw["gf_radius"],
        gf_eps=kw["gf_eps"])
    np.testing.assert_allclose(np.asarray(jnp.concatenate(t_parts, axis=1)),
                               np.asarray(t_full), atol=1e-5)
    # Global argmin-t candidate == the better of the two shard candidates.
    j = np.argmin(np.stack(tmins), axis=0)
    np.testing.assert_allclose(np.stack(tmins).min(axis=0),
                               np.asarray(tmin_full), atol=1e-6)
    picked = np.stack(rgbs)[j, np.arange(b)]
    np.testing.assert_allclose(picked, np.asarray(rgb_full), atol=1e-6)


# --- pipeline wiring ---------------------------------------------------------

def _pipeline_pair(monkeypatch, substrate, algorithm="dcp"):
    if substrate:
        monkeypatch.setenv("REPRO_KERNEL_MODE", substrate)
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    out_f = make_dehaze_step(DehazeConfig(algorithm=algorithm,
                                          kernel_mode="fused",
                                          update_period=2))(
        J, ids, init_atmo_state())
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    out_r = make_dehaze_step(DehazeConfig(algorithm=algorithm,
                                          kernel_mode="ref",
                                          update_period=2))(
        J, ids, init_atmo_state())
    return out_f, out_r


def _scene():
    r = np.random.default_rng(17)
    J = jnp.asarray(r.random((4, 24, 32, 3), np.float32))
    return J, None


@pytest.mark.parametrize("algorithm", ["dcp", "cap"])
@pytest.mark.parametrize("substrate", ["", "interpret"])
def test_pipeline_fused_matches_ref_chain(monkeypatch, substrate, algorithm):
    """make_dehaze_step(kernel_mode="fused") == the per-stage ref chain, for
    both algorithm instantiations (on CPU the fused substrate resolves to
    the oracle; with REPRO_KERNEL_MODE=interpret it runs the actual kernel
    body)."""
    out_f, out_r = _pipeline_pair(monkeypatch, substrate, algorithm)
    np.testing.assert_allclose(np.asarray(out_f.frames),
                               np.asarray(out_r.frames), atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_f.transmission),
                               np.asarray(out_r.transmission), atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_f.atmo_light),
                               np.asarray(out_r.atmo_light), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_f.state.A),
                               np.asarray(out_r.state.A), atol=1e-4)


def test_supports_fused_coverage():
    """Top-k (any k) is fused-covered now alongside DCP and CAP; the only
    remaining fallback is DCP + recompute (kernel_mode="fused" must keep
    working through the per-stage chain there)."""
    from repro.core import algorithms as alg
    assert alg.supports_fused(DehazeConfig(algorithm="cap"))
    assert alg.supports_fused(DehazeConfig(algorithm="dcp"))
    assert alg.supports_fused(DehazeConfig(topk=8))
    assert alg.supports_fused(DehazeConfig(algorithm="cap", topk=8))
    assert not alg.supports_fused(
        DehazeConfig(algorithm="dcp", recompute_t_with_final_a=True))
    # CAP's transmission is A-free: the recompute flag is a chain no-op
    # there and must not knock it off the fused path.
    assert alg.supports_fused(
        DehazeConfig(algorithm="cap", recompute_t_with_final_a=True))
    # The remaining fallback config still runs through the per-stage chain.
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    out = make_dehaze_step(DehazeConfig(algorithm="dcp", kernel_mode="fused",
                                        recompute_t_with_final_a=True))(
        J, ids, init_atmo_state())
    assert not bool(jnp.isnan(out.frames).any())


@pytest.mark.parametrize("algorithm", ["dcp", "cap"])
def test_fused_parity_topk(algorithm):
    """Robust top-k (k=4) megakernel: the in-VMEM running selection must
    feed the EMA the same mean-of-top-k candidate as the oracle."""
    kw = dict(FUSED_KW, algorithm=algorithm, topk=4)
    img = _img((4, 16, 16), seed=53)
    for warm in (False, True):
        state = _state(warm)
        got = _run(img, state, "interpret", **kw)
        want = _run(img, state, "ref", **kw)
        for g, w in zip(got[:3], want[:3]):
            err = np.max(np.abs(np.asarray(g, np.float32)
                                - np.asarray(w, np.float32)))
            assert err <= 1e-5, err
        np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                                   atol=1e-5)
        assert int(got[4]) == int(want[4])


def test_fused_topk_registry_bucket(monkeypatch, tmp_path):
    """topk > 1 resolves its tile from the ``fused_<alg>_topk`` bucket."""
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "none.json"))
    assert tuning.get_params("fused_dcp_topk", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP_TOPK", '{"frames_per_block": 2}')
    assert tuning.get_params("fused_dcp_topk", (4, 16, 16)) == \
        {"frames_per_block": 2, "buffer_depth": 2}
    # The argmin bucket is unaffected by the topk override.
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    img = _img((4, 16, 16), seed=19)
    kw = dict(FUSED_KW, topk=4)
    got = _run(img, _state(), "auto", **kw)
    want = _run(img, _state(), "ref", **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)


def test_sharded_step_selects_fused():
    """Single-device mesh: the sharded step's fused branch must agree with
    its per-stage branch."""
    from repro.core.pipeline import make_sharded_dehaze_step
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    J, _ = _scene()
    ids = jnp.arange(4, dtype=jnp.int32)
    outs = {}
    for mode in ("fused", "ref"):
        cfg = DehazeConfig(kernel_mode=mode, update_period=2)
        step, _, _ = make_sharded_dehaze_step(cfg, mesh, ("data",), None)
        outs[mode] = step(J, ids, init_atmo_state())
    np.testing.assert_allclose(np.asarray(outs["fused"].frames),
                               np.asarray(outs["ref"].frames), atol=2e-4)
    np.testing.assert_allclose(np.asarray(outs["fused"].transmission),
                               np.asarray(outs["ref"].transmission), atol=2e-4)


# --- tiling registry / autotune ----------------------------------------------

def test_tuning_defaults_and_env_override(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "none.json"))
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 4}')
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 4, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", "not json")
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}


def test_tuning_table_roundtrip(monkeypatch, tmp_path):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(path))
    tuning.save_table({"fused_dcp": {"4x16x16": {"frames_per_block": 2}}})
    assert json.loads(path.read_text())["fused_dcp"]["4x16x16"] == \
        {"frames_per_block": 2}
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 2, "buffer_depth": 2}
    # Other shapes fall back to the default.
    assert tuning.get_params("fused_dcp", (1, 8, 8)) == \
        {"frames_per_block": 1, "buffer_depth": 2}


def test_autotune_picks_fastest_and_persists(monkeypatch, tmp_path):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(path))

    def build(params):
        if params["frames_per_block"] == 3:        # non-dividing tile
            raise ValueError("bad tile")
        import time

        def run():
            time.sleep(0.001 * params["frames_per_block"])
            return jnp.zeros(())
        return run

    best = tuning.autotune("fused_dcp", (4, 16, 16),
                           [{"frames_per_block": f} for f in (3, 1, 2)],
                           build, iters=1)
    assert best == {"frames_per_block": 1}
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        dict(best, buffer_depth=2)


def test_fused_dispatch_reads_registry(monkeypatch, tmp_path):
    """ops.fused_dehaze_dcp resolves frames_per_block from the registry."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "t.json"))
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 2}')
    img = _img((4, 16, 16), seed=19)
    got = _run(img, _state(), "auto", **FUSED_KW)
    want = _run(img, _state(), "ref", **FUSED_KW)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)


def test_fused_cap_registry_bucket(monkeypatch, tmp_path):
    """CAP resolves its tile from its own ``fused_cap`` bucket."""
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "none.json"))
    assert tuning.get_params("fused_cap", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_TUNE_FUSED_CAP", '{"frames_per_block": 2}')
    assert tuning.get_params("fused_cap", (4, 16, 16)) == \
        {"frames_per_block": 2, "buffer_depth": 2}
    # ...and the dcp bucket is unaffected by the cap override.
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    img = _img((4, 16, 16), seed=19)
    kw = dict(FUSED_KW, algorithm="cap")
    got = _run(img, _state(), "auto", **kw)
    want = _run(img, _state(), "ref", **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-5)


# --- lane-native megakernel (multi-stream lane axis in the pallas grid) ------

def _lane_inputs(n_lanes=3, b=4, h=16, w=20, seed=29):
    """Tie-stable lane-batched inputs (``conftest.ramp_frames`` — all t
    values distinct, so selections cannot fork across separately compiled
    programs), warm and cold per-lane states, and one all-padding lane."""
    from conftest import ramp_frames
    r = np.random.default_rng(seed)
    img = ramp_frames(seed, n_lanes, b, h=h, w=w)
    ids = jnp.stack([jnp.arange(b, dtype=jnp.int32) + 10 * lane
                     for lane in range(n_lanes - 1)]
                    + [jnp.full((b,), -1, jnp.int32)])
    carry_f = jnp.asarray(r.random((n_lanes, 3), np.float32) * 0.4 + 0.6)
    carry_i = jnp.stack([jnp.asarray([3, 1], jnp.int32)]
                        + [jnp.asarray([-2 ** 30, 0], jnp.int32)] *
                        (n_lanes - 1))
    return img, ids, carry_f, carry_i


@pytest.mark.parametrize("lane_major", [True, False])
@pytest.mark.parametrize("fpb", [1, 2])
def test_fused_lanes_kernel_matches_per_lane(lane_major, fpb):
    """The lane-native kernel's per-lane outputs match the single-stream
    kernel run on each lane alone — for both grid orders (lane-major and
    frame-major) and multi-frame blocks — and an all-padding lane's carry
    rides through untouched. Float outputs are compared to 2 ulp: the two
    interpret-mode programs compile separately, and XLA's shape-dependent
    FMA fusion legally reassociates at that level (the candidate
    *selection* cannot fork — the frames are a tie-stable ramp); integer
    state is exact."""
    from repro.kernels.fused import (fused_dehaze_lanes_pallas,
                                     fused_dehaze_pallas)
    img, ids, carry_f, carry_i = _lane_inputs()
    kw = dict(FUSED_KW, refine=True, topk=4)
    out = fused_dehaze_lanes_pallas(img, ids, carry_f, carry_i,
                                    frames_per_block=fpb,
                                    lane_major=lane_major, interpret=True,
                                    **kw)
    for lane in range(img.shape[0]):
        want = fused_dehaze_pallas(img[lane], ids[lane], carry_f[lane],
                                   carry_i[lane, 0], carry_i[lane, 1],
                                   frames_per_block=fpb, interpret=True,
                                   **kw)
        tag = f"lane{lane}/major{lane_major}/fpb{fpb}"
        for g, w in zip(out[:4], want[:4]):          # J, t, a_seq, A_fin
            np.testing.assert_allclose(np.asarray(g[lane]), np.asarray(w),
                                       atol=1.2e-7, rtol=0, err_msg=tag)
        assert int(out[4][lane, 0]) == int(want[4]), tag
    pad = img.shape[0] - 1
    np.testing.assert_array_equal(np.asarray(out[3][pad]),
                                  np.asarray(carry_f[pad]))
    assert int(out[4][pad, 1]) == 0                  # never initialized


def test_fused_lanes_ref_dispatch_matches_per_lane():
    """ops.fused_dehaze_lanes on the XLA oracle substrate == per-lane
    oracle runs (the lane-vmapped reference the serving runtime uses on
    CPU)."""
    img, ids, carry_f, carry_i = _lane_inputs(seed=31)
    kw = dict(FUSED_KW, topk=2)
    out = ops.fused_dehaze_lanes(img, ids, carry_f, carry_i, mode="ref",
                                 **kw)
    for lane in range(img.shape[0]):
        want = ref.fused_dehaze(img[lane], ids[lane], carry_f[lane],
                                carry_i[lane, 0],
                                carry_i[lane, 1].astype(bool), **kw)
        for g, w in zip(out[:4], want[:4]):
            np.testing.assert_allclose(np.asarray(g[lane]), np.asarray(w),
                                       atol=1.2e-7, rtol=0)
        assert int(out[4][lane, 0]) == int(want[4])


def test_fused_lanes_interpret_vs_ref_parity():
    """Acceptance gate vs the oracle: the lane-native kernel body keeps
    the 1e-5 max-abs bar of the single-stream kernel."""
    img, ids, carry_f, carry_i = _lane_inputs(seed=37)
    kw = dict(FUSED_KW, refine=True)
    got = ops.fused_dehaze_lanes(img, ids, carry_f, carry_i,
                                 mode="interpret", **kw)
    want = ops.fused_dehaze_lanes(img, ids, carry_f, carry_i, mode="ref",
                                  **kw)
    for g, w in zip(got[:4], want[:4]):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err
    np.testing.assert_array_equal(np.asarray(got[4]), np.asarray(want[4]))


def test_fused_transmission_lanes_matches_per_lane():
    """Lane-batched t-map stage: each lane's pre-map must divide by that
    lane's own saved A (the per-lane A input is what makes the stage
    lane-native)."""
    from repro.kernels.fused import (fused_transmission_lanes_pallas,
                                     fused_transmission_pallas)
    img, _, carry_f, _ = _lane_inputs(seed=43)
    kw = dict(radius=3, omega=0.95, refine=True, gf_radius=4, gf_eps=1e-3,
              topk=2)
    t, tmin, cand = fused_transmission_lanes_pallas(img, carry_f,
                                                    interpret=True, **kw)
    for lane in range(img.shape[0]):
        tr, tminr, candr = fused_transmission_pallas(img[lane], carry_f[lane],
                                                     interpret=True, **kw)
        np.testing.assert_allclose(np.asarray(t[lane]), np.asarray(tr),
                                   atol=1.2e-7, rtol=0)
        np.testing.assert_allclose(np.asarray(tmin[lane]),
                                   np.asarray(tminr), atol=1.2e-7, rtol=0)
        np.testing.assert_allclose(np.asarray(cand[lane]),
                                   np.asarray(candr), atol=1.2e-7, rtol=0)
    # Dispatch-level ref path, same per-lane contract.
    got = ops.fused_transmission_lanes(img, carry_f, mode="ref", **kw)
    for lane in range(img.shape[0]):
        want = ref.fused_transmission(img[lane], carry_f[lane],
                                      algorithm="dcp", **kw)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g[lane], np.float32),
                                       np.asarray(w, np.float32),
                                       atol=1.2e-7, rtol=0)


def test_lane_native_single_launch():
    """The acceptance criterion of the lane-axis refactor: serving L lanes
    traces exactly ONE pallas_call, vs L for per-lane kernel dispatch."""
    n_lanes = 4
    img, ids, carry_f, carry_i = _lane_inputs(n_lanes=n_lanes, b=2, h=8, w=8)
    kw = dict(FUSED_KW)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    n_loop = ops.pallas_launch_count(
        lambda f: [ops.fused_dehaze(f[lane], ids[lane], A0, k0, init,
                                    mode="interpret", **kw)[0]
                   for lane in range(n_lanes)], img)
    n_lane = ops.pallas_launch_count(
        lambda f: ops.fused_dehaze_lanes(f, ids, carry_f, carry_i,
                                         mode="interpret", **kw)[0], img)
    assert n_loop == n_lanes
    assert n_lane == 1


def test_fused_lanes_registry_bucket(monkeypatch, tmp_path):
    """The lane-native kernel resolves its grid from the ``fused_lanes``
    bucket — frames_per_block AND grid order — keyed on the lane count."""
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "none.json"))
    assert tuning.get_params("fused_lanes", (4, 8, 16, 16)) == \
        {"frames_per_block": 1, "grid_order": "lane_major", "buffer_depth": 2}
    monkeypatch.setenv("REPRO_TUNE_FUSED_LANES",
                       '{"frames_per_block": 2, "grid_order": "frame_major"}')
    assert tuning.get_params("fused_lanes", (4, 8, 16, 16)) == \
        {"frames_per_block": 2, "grid_order": "frame_major", "buffer_depth": 2}
    # The single-stream buckets are unaffected by the lanes override.
    assert tuning.get_params("fused_dcp", (8, 16, 16)) == \
        {"frames_per_block": 1, "buffer_depth": 2}
    # The dispatch layer honors the override end-to-end (kernel runs with
    # frame-major grid + 2-frame blocks and still matches the oracle).
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    img, ids, carry_f, carry_i = _lane_inputs(seed=53)
    got = ops.fused_dehaze_lanes(img, ids, carry_f, carry_i, mode="auto",
                                 **FUSED_KW)
    want = ops.fused_dehaze_lanes(img, ids, carry_f, carry_i, mode="ref",
                                  **FUSED_KW)
    for g, w in zip(got[:4], want[:4]):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err


# --- bf16 halo planes feed the halo kernel directly --------------------------

def test_fused_halo_accepts_bf16_planes():
    """bf16 (pre-map, guide) halo inputs upcast in-VMEM: outputs are
    bit-identical to upcasting outside the kernel (bf16 -> f32 is exact),
    so `halo_dtype="bfloat16"` needs no boundary re-cast pass."""
    img, pre_ext, guide_ext, halo = _halo_inputs()
    valid = jnp.arange(pre_ext.shape[1]) >= halo          # top-edge shard
    pre_bf = pre_ext.astype(jnp.bfloat16)
    guide_bf = guide_ext.astype(jnp.bfloat16)
    kw = dict(HALO_KW, algorithm="dcp", refine=True, topk=2)
    got = fused_transmission_halo_pallas(img, pre_bf, guide_bf, valid,
                                         interpret=True, **kw)
    want = fused_transmission_halo_pallas(
        img, pre_bf.astype(jnp.float32), guide_bf.astype(jnp.float32),
        valid, interpret=True, **kw)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g, np.float32),
                                      np.asarray(w, np.float32))
    # Dispatch-level: the XLA oracle path accepts bf16 planes too.
    got_ref = ops.fused_transmission_halo(img, pre_bf, guide_bf, valid,
                                          mode="ref", **kw)
    for g, w in zip(got_ref, want):
        err = np.max(np.abs(np.asarray(g, np.float32)
                            - np.asarray(w, np.float32)))
        assert err <= 1e-5, err


# --- in-kernel cross-shard candidate merge -----------------------------------

@pytest.mark.parametrize("b,m,k", [(1, 4, 4), (3, 16, 4), (2, 24, 8),
                                   (4, 8, 1)])
def test_merge_topk_kernel_matches_sort_path(b, m, k):
    """The grid-carry merge kernel must reproduce the ``lax.sort``-based
    cross-shard candidate merge bit-for-bit — including on t plateaus,
    where only the global-flat-index tie-break decides which rgb rows
    enter the mean (min-filter output is piecewise constant, so ties
    spanning shard boundaries are the common case, not the corner)."""
    from repro.kernels.atmolight import merge_topk_pallas
    r = np.random.default_rng(11)
    tk_t = jnp.asarray(r.random((b, m), np.float32))
    # Force cross-segment ties: quantize half the rows hard.
    tk_t = tk_t.at[:, ::2].set(jnp.round(tk_t[:, ::2] * 2) / 2)
    tk_idx = jnp.asarray(r.permutation(np.arange(b * m))
                         .reshape(b, m).astype(np.int32))
    tk_rgb = jnp.asarray(r.random((b, m, 3), np.float32))

    want = ops.merge_topk_candidates(tk_t, tk_idx, tk_rgb, k, mode="ref")
    got = merge_topk_pallas(tk_t, tk_idx, tk_rgb, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # dispatch layer: interpret mode routes to the kernel body
    got2 = ops.merge_topk_candidates(tk_t, tk_idx, tk_rgb, k,
                                     mode="interpret")
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


def test_merge_topk_kernel_segment_fold():
    """Segment width != k exercises the fold-across-grid-steps carry (a
    2k-wide union select per step), and an all-tied plateau collapses the
    decision entirely onto the index key."""
    from repro.kernels.atmolight import merge_topk_pallas
    b, m, k = 2, 12, 3
    r = np.random.default_rng(12)
    tk_t = jnp.full((b, m), 0.5, jnp.float32)          # total plateau
    tk_idx = jnp.asarray(r.permutation(np.arange(b * m))
                         .reshape(b, m).astype(np.int32))
    tk_rgb = jnp.asarray(r.random((b, m, 3), np.float32))
    want = ops.merge_topk_candidates(tk_t, tk_idx, tk_rgb, k, mode="ref")
    for seg in (k, 6, m):
        got = merge_topk_pallas(tk_t, tk_idx, tk_rgb, k, seg=seg,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
