"""Cross-path differential-test matrix for the production dehazing configs.

Sweeps {dcp, cap} x {topk 1, 4} x {staged, fused, lane_native} x
{n_h 1, 2} x {n_w 1, 2} x {single-stream, lanes 1, 4} and asserts
J / t / A / AtmoState agreement against the per-stage ref-oracle chain —
including all-padding lanes and mesh-edge shards. Every serving config is
fused-covered now (``supports_fused`` has no topk / sharding gates), so
this matrix is the contract that future kernel work cannot silently fork
the fused and staged semantics. The lane-native cells additionally pin
the multi-stream refactor's parity bar: per lane, the megakernel with the
lane axis folded into its grid must equal the ``jax.vmap``-of-fused path
(bit-for-bit on the XLA-oracle substrate; to 2 ulp across the separately
compiled interpret-mode programs).

Single-device and multi-stream cells run in-process (under
``REPRO_KERNEL_MODE=interpret`` they exercise the actual Pallas kernel
bodies — the CI kernel-parity job does exactly that); the sharded cells
spawn subprocesses with 8 forced host devices, one per mesh shape, and
sweep the algorithm/topk/path axes inside the child.

No hypothesis dependency on purpose: this file is minimal-install
tier-1 coverage for the whole fused surface.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DehazeConfig, init_atmo_state, make_dehaze_step,
                        make_multi_stream_step)
from repro.core.normalize import pack_atmo_states

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALGORITHMS = ["dcp", "cap"]
TOPKS = [1, 4]
PATHS = ["staged", "fused"]

# Frames/transmission: the fused substrate composes the same jnp ops in a
# different order than the per-stage chain, so XLA re-association costs a
# few float32 ulps through the guided filter. A is compared tighter (the
# candidate selection is bit-identical by construction).
TOL_IMG = 2e-4
TOL_A = 1e-4


def _cfg(algorithm: str, topk: int, path: str) -> DehazeConfig:
    return DehazeConfig(algorithm=algorithm, topk=topk,
                        kernel_mode="fused" if path == "fused" else "ref",
                        patch_radius=3, gf_radius=4, update_period=2)


def _oracle_cfg(algorithm: str, topk: int) -> DehazeConfig:
    return DehazeConfig(algorithm=algorithm, topk=topk, kernel_mode="ref",
                        patch_radius=3, gf_radius=4, update_period=2)


def _frames(seed=17, b=4, h=32, w=32):
    """Tie-stable parity frames — ``conftest.ramp_frames``, THE shared
    recipe for differential-testing discontinuous top-k selections across
    separately compiled programs (see its docstring for why uniform random
    frames are unusable here: observed a 0.03 A fork from one 1-ulp
    boundary tie flipping a pick)."""
    from conftest import ramp_frames
    return ramp_frames(seed, b, h=h, w=w)


def _assert_output_close(got, want, tag=""):
    np.testing.assert_allclose(np.asarray(got.frames),
                               np.asarray(want.frames), atol=TOL_IMG,
                               err_msg=f"J {tag}")
    np.testing.assert_allclose(np.asarray(got.transmission),
                               np.asarray(want.transmission), atol=TOL_IMG,
                               err_msg=f"t {tag}")
    np.testing.assert_allclose(np.asarray(got.atmo_light),
                               np.asarray(want.atmo_light), atol=TOL_A,
                               err_msg=f"a_seq {tag}")
    np.testing.assert_allclose(np.asarray(got.state.A),
                               np.asarray(want.state.A), atol=TOL_A,
                               err_msg=f"state.A {tag}")
    assert int(got.state.last_update) == int(want.state.last_update), tag
    assert bool(got.state.initialized) == bool(want.state.initialized), tag


# ---------------------------------------------------------------------------
# Single-device cells (n_h = n_w = 1, single stream)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", PATHS)
@pytest.mark.parametrize("topk", TOPKS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_single_device_parity(algorithm, topk, path):
    frames = _frames()
    ids = jnp.arange(4, dtype=jnp.int32)
    got = make_dehaze_step(_cfg(algorithm, topk, path))(
        frames, ids, init_atmo_state())
    want = make_dehaze_step(_oracle_cfg(algorithm, topk))(
        frames, ids, init_atmo_state())
    _assert_output_close(got, want, f"{algorithm}/topk{topk}/{path}")


@pytest.mark.parametrize("path", PATHS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_single_device_parity_warm_state_chain(algorithm, path):
    """Two chained batches: the EMA state handed from batch 1 to batch 2
    must keep the paths in lockstep (a state fork would compound)."""
    ids1 = jnp.arange(4, dtype=jnp.int32)
    ids2 = jnp.arange(4, 8, dtype=jnp.int32)
    f1, f2 = _frames(seed=3), _frames(seed=5)
    step_g = make_dehaze_step(_cfg(algorithm, 4, path))
    step_w = make_dehaze_step(_oracle_cfg(algorithm, 4))
    out_g = step_g(f1, ids1, init_atmo_state())
    out_w = step_w(f1, ids1, init_atmo_state())
    got = step_g(f2, ids2, out_g.state)
    want = step_w(f2, ids2, out_w.state)
    _assert_output_close(got, want, f"{algorithm}/{path}/chained")


# ---------------------------------------------------------------------------
# Multi-stream cells (4 lanes, incl. an all-padding lane)
# ---------------------------------------------------------------------------

# The lane axis has two device realizations: the single-stream chain under
# jax.vmap ("staged"/"fused"), and the lane-native megakernel with the
# lane axis folded into the pallas grid ("lane_native").
MULTI_PATHS = PATHS + ["lane_native"]


def _multi_step(algorithm, topk, path):
    if path == "lane_native":
        return make_multi_stream_step(_cfg(algorithm, topk, "fused"),
                                      lane_native=True)
    return make_multi_stream_step(_cfg(algorithm, topk, path),
                                  lane_native=False)


@pytest.mark.parametrize("path", MULTI_PATHS)
@pytest.mark.parametrize("topk", TOPKS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_multistream_parity(algorithm, topk, path):
    """4-lane lane-batched step vs per-lane single-stream oracle runs.

    Lane 3 is all padding (an unoccupied scheduler lane): its outputs are
    discarded by the scheduler, but its state must ride through
    bit-unchanged and must not perturb the live lanes.
    """
    n_lanes, b = 4, 4
    frames = jnp.stack([_frames(seed=20 + lane, b=b) for lane in range(n_lanes)])
    ids = jnp.stack([jnp.arange(lane * 10, lane * 10 + b, dtype=jnp.int32)
                     for lane in range(n_lanes - 1)]
                    + [jnp.full((b,), -1, jnp.int32)])
    states = [init_atmo_state() for _ in range(n_lanes)]
    packed = pack_atmo_states(states)

    multi = _multi_step(algorithm, topk, path)
    out = multi(frames, ids, packed)

    oracle = make_dehaze_step(_oracle_cfg(algorithm, topk))
    for lane in range(n_lanes - 1):
        want = oracle(frames[lane], ids[lane], states[lane])
        tag = f"{algorithm}/topk{topk}/{path}/lane{lane}"
        np.testing.assert_allclose(np.asarray(out.frames[lane]),
                                   np.asarray(want.frames), atol=TOL_IMG,
                                   err_msg=tag)
        np.testing.assert_allclose(np.asarray(out.transmission[lane]),
                                   np.asarray(want.transmission),
                                   atol=TOL_IMG, err_msg=tag)
        np.testing.assert_allclose(np.asarray(out.atmo_light[lane]),
                                   np.asarray(want.atmo_light), atol=TOL_A,
                                   err_msg=tag)
        np.testing.assert_allclose(np.asarray(out.state.A[lane]),
                                   np.asarray(want.state.A), atol=TOL_A,
                                   err_msg=tag)
        assert int(out.state.last_update[lane]) == int(want.state.last_update)
    # The all-padding lane: state unchanged, bit-for-bit.
    pad = n_lanes - 1
    np.testing.assert_array_equal(np.asarray(out.state.A[pad]),
                                  np.asarray(packed.A[pad]))
    assert int(out.state.last_update[pad]) == int(packed.last_update[pad])
    assert not bool(out.state.initialized[pad])


@pytest.mark.parametrize("n_lanes", [1, 4])
@pytest.mark.parametrize("topk", TOPKS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_multistream_lane_native_matches_vmapped_fused(algorithm, topk,
                                                       n_lanes):
    """The lane-axis refactor's parity bar: the lane-native megakernel
    equals ``jax.vmap`` of the fused single-stream step per lane — for
    lane counts 1 and 4, including an all-padding lane (and, at
    ``n_lanes == 1``, a batch that is *entirely* padding in a second
    step). On the XLA-oracle substrate the two paths are bit-identical;
    on the interpret substrate (the CI kernel-parity job) the separately
    compiled programs may differ by FMA reassociation, bounded at 2 ulp.
    Integer state is exact everywhere.
    """
    from repro.kernels.ops import resolve_mode
    float_tol = 0.0 if resolve_mode("fused") == "ref" else 1.2e-7
    b = 4
    frames = jnp.stack([_frames(seed=60 + lane, b=b)
                        for lane in range(n_lanes)])
    if n_lanes == 1:
        ids = jnp.arange(b, dtype=jnp.int32)[None]
    else:
        ids = jnp.stack(
            [jnp.arange(lane * 7, lane * 7 + b, dtype=jnp.int32)
             for lane in range(n_lanes - 1)]
            + [jnp.full((b,), -1, jnp.int32)])
    packed = pack_atmo_states([init_atmo_state() for _ in range(n_lanes)])

    lane_native = _multi_step(algorithm, topk, "lane_native")
    vmapped = _multi_step(algorithm, topk, "fused")

    def check(got, want, tag):
        for field in ("frames", "transmission", "atmo_light"):
            np.testing.assert_allclose(
                np.asarray(getattr(got, field)),
                np.asarray(getattr(want, field)), atol=float_tol, rtol=0,
                err_msg=f"{field} {tag}")
        np.testing.assert_allclose(np.asarray(got.state.A),
                                   np.asarray(want.state.A), atol=float_tol,
                                   rtol=0, err_msg=f"state.A {tag}")
        np.testing.assert_array_equal(np.asarray(got.state.last_update),
                                      np.asarray(want.state.last_update),
                                      err_msg=tag)
        np.testing.assert_array_equal(np.asarray(got.state.initialized),
                                      np.asarray(want.state.initialized),
                                      err_msg=tag)

    tag = f"{algorithm}/topk{topk}/L{n_lanes}"
    got = lane_native(frames, ids, packed)
    want = vmapped(frames, ids, packed)
    check(got, want, tag)

    # Chain a second batch through the returned states: a state fork
    # between the two realizations would compound here. At n_lanes == 1
    # the second batch is all padding — the whole program must be a state
    # no-op on both paths.
    ids2 = jnp.full_like(ids, -1) if n_lanes == 1 else ids + b
    got2 = lane_native(frames, ids2, got.state)
    want2 = vmapped(frames, ids2, want.state)
    check(got2, want2, tag + "/chained")
    if n_lanes == 1:
        np.testing.assert_array_equal(np.asarray(got2.state.A),
                                      np.asarray(got.state.A))
        np.testing.assert_array_equal(np.asarray(got2.state.last_update),
                                      np.asarray(got.state.last_update))


# ---------------------------------------------------------------------------
# Sharded cells (subprocess with 8 forced host devices per mesh shape)
# ---------------------------------------------------------------------------

def _run_child(body: str, devices: int = 8) -> None:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(REPO_SRC))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"


@pytest.mark.parametrize("n_h,n_w", [(2, 1), (1, 2), (2, 2)],
                         ids=["nh2", "nw2", "nh2xnw2"])
def test_sharded_parity_matrix(n_h, n_w):
    """{{dcp, cap}} x {{topk 1, 4}} x {{staged, fused}} on a (2, n_h, n_w)
    mesh vs the single-device ref-oracle chain. Every shard of a 2-shard
    spatial axis touches a mesh edge, so the row/column validity masking
    (and the lexicographic cross-shard top-k merge) is exercised in every
    cell; the (2, 2) mesh adds the corner shards."""
    _run_child(f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((2, {n_h}, {n_w}), ("data", "model", "width"))
        # Tie-stable ramp frames — see _frames() in the parent module.
        rng = np.random.default_rng(2)
        g = (rng.permutation(4 * 32 * 32).reshape(4, 32, 32) + 1.0) / (4096 + 1.0)
        I = jnp.asarray(np.stack([g, 0.9 * g, 0.8 * g], -1).astype(np.float32))
        ids = jnp.arange(4, dtype=jnp.int32)
        for algo in ("dcp", "cap"):
            for topk in (1, 4):
                base = DehazeConfig(algorithm=algo, kernel_mode="ref",
                                    patch_radius=3, gf_radius=4,
                                    update_period=2, topk=topk)
                want = jax.jit(make_dehaze_step(base))(I, ids,
                                                       init_atmo_state())
                for km in ("ref", "fused"):
                    cfg = DehazeConfig(algorithm=algo, kernel_mode=km,
                                       patch_radius=3, gf_radius=4,
                                       update_period=2, topk=topk)
                    step, _, _ = make_sharded_dehaze_step(
                        cfg, mesh, ("data",), "model", "width")
                    with mesh:
                        out = jax.jit(step)(I, ids, init_atmo_state())
                    tag = f"{{algo}}/topk{{topk}}/{{km}}"
                    np.testing.assert_allclose(
                        np.asarray(out.frames), np.asarray(want.frames),
                        atol=2e-5, err_msg=tag)
                    np.testing.assert_allclose(
                        np.asarray(out.transmission),
                        np.asarray(want.transmission), atol=2e-5,
                        err_msg=tag)
                    np.testing.assert_allclose(
                        np.asarray(out.atmo_light),
                        np.asarray(want.atmo_light), atol=1e-5, err_msg=tag)
                    np.testing.assert_allclose(
                        np.asarray(out.state.A), np.asarray(want.state.A),
                        atol=1e-5, err_msg=tag)
                    assert int(out.state.last_update) == \\
                        int(want.state.last_update), tag
        print("ok")
    """)


def test_sharded_parity_tie_plateau():
    """Adversarial tie cell: a transmission plateau spanning the shard
    boundaries (constant image regions -> piecewise-constant min-filter
    output). The cross-shard merge must still pick the same top-k pixels
    as the single device — this is exactly what the explicit global-index
    sort key exists for."""
    _run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((1, 2, 2), ("data", "model", "width"))
        rng = np.random.default_rng(7)
        # Quantized frames: large equal-t plateaus across shard boundaries,
        # but per-pixel RGB still varies inside a plateau (the channel mins
        # tie, the picked colors do not) — wrong tie-breaking shows up in A.
        I = jnp.asarray(np.round(rng.random((2, 32, 32, 3)) * 4) / 4
                        ).astype(jnp.float32)
        I = I * 0.8 + 0.1
        ids = jnp.arange(2, dtype=jnp.int32)
        for km in ("ref", "fused"):
            cfg = DehazeConfig(algorithm="dcp", kernel_mode=km,
                               patch_radius=3, gf_radius=4, topk=4,
                               update_period=1)
            want = jax.jit(make_dehaze_step(
                DehazeConfig(algorithm="dcp", kernel_mode="ref",
                             patch_radius=3, gf_radius=4, topk=4,
                             update_period=1)))(I, ids, init_atmo_state())
            step, _, _ = make_sharded_dehaze_step(cfg, mesh, ("data",),
                                                  "model", "width")
            with mesh:
                out = jax.jit(step)(I, ids, init_atmo_state())
            np.testing.assert_allclose(np.asarray(out.atmo_light),
                                       np.asarray(want.atmo_light),
                                       atol=1e-6, err_msg=km)
            np.testing.assert_allclose(np.asarray(out.state.A),
                                       np.asarray(want.state.A), atol=1e-6,
                                       err_msg=km)
        print("ok")
    """)


# ---------------------------------------------------------------------------
# Fleet cells ({1 host, 2 hosts} x {staged, lane_native}, serving tier).
# The n_h 2 dimension of the fleet bar — lanes sharded over the data axis
# composed with height-halo sharding — runs in
# test_distributed.test_lane_sharded_step_matches_per_lane_single_device.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", ["staged", "lane_native"])
@pytest.mark.parametrize("n_hosts", [1, 2])
def test_fleet_parity_cells(n_hosts, path):
    """Fleet serve == single-host serve, bit-for-bit per stream: emitted
    frames (the EMA trajectory is baked into every recovered frame via
    a_seq), final EMA state, cursors. Sticky placement asserted: zero EMA
    migrations; at 2 hosts the first-fit waterfall must spill."""
    from repro.stream import ElasticServer, StreamRequest

    cfg = _cfg("dcp", 4, "fused" if path == "lane_native" else "staged")

    def stream_frames():
        return [[np.asarray(f) for f in _frames(seed=40 + i, b=6, h=24, w=24)]
                for i in range(4)]

    def run(server, n):
        sunk = {}
        rep = server.serve_many(
            [StreamRequest(f"v{i}", iter(v))
             for i, v in enumerate(stream_frames())],
            n_lanes=2, n_hosts=n,
            sink=lambda s, f, p: sunk.setdefault(s, []).append((f, p.copy())))
        return rep, sunk

    base = ElasticServer(cfg, batch=3, timeout_s=5.0)
    rep_w, want = run(base, 1)
    srv = ElasticServer(cfg, batch=3, timeout_s=5.0)
    rep_g, got = run(srv, n_hosts)

    tag = f"fleet/{n_hosts}host/{path}"
    assert rep_g.frames == rep_w.frames == 24, tag
    assert rep_g.skipped == 0 and rep_g.migrations == 0, tag
    if n_hosts > 1:
        assert rep_g.spillovers >= 1, tag
        placements = srv.last_fleet.queue.placements
        assert all(e["host"] == placements[e["stream_id"]]
                   for e in srv.last_fleet.queue.admission_log), tag
    for sid in want:
        assert [f for f, _ in got[sid]] == [f for f, _ in want[sid]], tag
        for (_, a), (_, b) in zip(got[sid], want[sid]):
            np.testing.assert_array_equal(a, b, err_msg=f"{tag}/{sid}")
        np.testing.assert_array_equal(
            np.asarray(srv.store.get(sid).A),
            np.asarray(base.store.get(sid).A), err_msg=f"{tag}/{sid}")
        assert srv.store.cursor(sid) == base.store.cursor(sid), tag
