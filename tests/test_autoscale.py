"""Elastic lane autoscaling: ladder hysteresis, state-preserving rung
switches, deadline-aware eviction, and the no-trace-on-serve-thread
compile discipline.

The load-bearing claims (ISSUE acceptance): a forced ramp drives at least
one grow and one shrink with zero dropped or duplicated frames and
per-stream EMA trajectories identical to a fixed-max-lane serve; ladder
rungs beyond the starting one are only ever built by the background warm
thread; a preempted (tardy) stream resumes from its checkpoint with the
same trajectory an uninterrupted serve would have produced.
"""
import threading
import types

import numpy as np
import pytest

from repro.core import DehazeConfig, PlacementSpec
from repro.stream import (ElasticServer, LaneAutoscaler, ScalePolicy,
                          StreamRequest, ladder_rungs)

ATOL = 3e-7


def _streams(n, lengths, h=16, w=20, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.random((h, w, 3)).astype(np.float32) for _ in range(k)]
            for k in lengths[:n]]


# --- ladder construction -----------------------------------------------------

def test_ladder_rungs_capped():
    assert ladder_rungs((4, 8, 16, 32), 6) == (4, 6)
    assert ladder_rungs((4, 8, 16, 32), 32) == (4, 8, 16, 32)
    assert ladder_rungs((4, 8), 16) == (4, 8, 16)
    # Cap below the smallest rung degenerates to a single-rung ladder.
    assert ladder_rungs((4, 8), 2) == (2,)
    assert ladder_rungs((8, 4, 8), 8) == (4, 8)      # dedup + sort
    with pytest.raises(ValueError):
        ladder_rungs((4, 8), 0)


# --- hysteresis (fake steps, no device) --------------------------------------

def _fake_scaler(rungs=(2, 4, 8), **pol_kw):
    """A LaneAutoscaler over trivial host 'steps' — exercises the ladder
    walk and warming machinery without compiling anything."""
    built = []

    def factory(n):
        built.append(n)
        return lambda frames, ids, state: types.SimpleNamespace(state=state)

    pol = ScalePolicy(rungs=rungs, **pol_kw)
    sc = LaneAutoscaler(factory, rungs, policy=pol,
                        state_factory=lambda n: np.zeros((n,), np.float32))
    return sc, built


def _warm_all(sc):
    sc.ensure_warming((2, 4, 4, 3))
    assert sc.wait_warm(timeout=10.0)


def test_grow_requires_dwell_and_resets_on_break():
    sc, _ = _fake_scaler(dwell_up=2, dwell_down=2)
    sc.acquire_initial()
    _warm_all(sc)
    assert sc.observe(pending=3, occupied=2) is None      # streak 1
    assert sc.observe(pending=0, occupied=1) is None      # break resets
    assert sc.observe(pending=3, occupied=2) is None      # streak 1 again
    assert sc.observe(pending=3, occupied=2) == 4         # streak 2 -> grow
    sc.commit(4)
    assert sc.rung == 4 and len(sc.switches) == 1
    assert sc.switches[0]["from"] == 2 and sc.switches[0]["to"] == 4


def test_shrink_requires_empty_queue_and_fit():
    sc, _ = _fake_scaler(dwell_up=1, dwell_down=2)
    sc.acquire_initial()
    _warm_all(sc)
    sc.commit(8)
    # Occupancy must fit the next rung down AND the queue must be empty.
    assert sc.observe(pending=0, occupied=7) is None
    assert sc.observe(pending=1, occupied=2) is None
    assert sc.observe(pending=0, occupied=3) is None      # streak 1
    assert sc.observe(pending=0, occupied=4) == 4         # streak 2 -> shrink
    sc.commit(4)
    assert sc.switches[-1] == {"from": 8, "to": 4, "wall_s": 0.0}


def test_no_thrash_on_alternating_load():
    """A load level flapping between grow-ish and shrink-ish each tick
    never satisfies either dwell — the rung holds."""
    sc, _ = _fake_scaler(dwell_up=2, dwell_down=2)
    sc.acquire_initial()
    _warm_all(sc)
    sc.commit(4)
    for _ in range(10):
        assert sc.observe(pending=2, occupied=4) is None  # load
        assert sc.observe(pending=0, occupied=1) is None  # slack
    assert sc.rung == 4 and len(sc.switches) == 1         # only the commit


def test_unwarm_rung_defers_switch():
    """Load against a rung that has not warmed yet holds the current rung;
    the switch lands once warming finishes (dwell state persists)."""
    sc, built = _fake_scaler(dwell_up=2)
    sc.acquire_initial()                                  # only rung 2 ready
    assert sc.observe(pending=3, occupied=2) is None
    assert sc.observe(pending=3, occupied=2) is None      # dwell met, not warm
    assert built == [2]
    _warm_all(sc)
    assert sc.observe(pending=3, occupied=2) == 4         # first warm tick


def test_top_and_bottom_rungs_are_sticky():
    sc, _ = _fake_scaler(rungs=(2, 4), dwell_up=1, dwell_down=1)
    sc.acquire_initial()
    _warm_all(sc)
    for _ in range(3):                                    # bottom: no shrink
        assert sc.observe(pending=0, occupied=0) is None
    sc.commit(4)
    for _ in range(3):                                    # top: no grow
        assert sc.observe(pending=9, occupied=4) is None
    assert sc.rung == 4


# --- compile discipline ------------------------------------------------------

def test_ladder_warms_off_the_serve_thread():
    """Every rung beyond the starting one must be built by the background
    warm thread — the step cache's built_by ledger proves no ladder trace
    ever ran on the caller (serve) thread."""
    from repro.stream.elastic import _STEP_CACHE, _cached_multi_step
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=3, update_period=3)
    rungs = ladder_rungs((2, 4), 4)
    sc = LaneAutoscaler(lambda n: _cached_multi_step(cfg, n, False), rungs)
    sc.acquire_initial()
    misses_before = _STEP_CACHE.misses
    sc.ensure_warming((2, 16, 20, 3))
    assert sc.wait_warm(timeout=120.0)
    assert not sc._warm_errors
    main = threading.get_ident()
    place = PlacementSpec.lane_batched()
    assert _STEP_CACHE.built_by[
        ("multi", cfg, rungs[0], False, place, False)] == main
    for rung in rungs[1:]:
        key = ("multi", cfg, rung, False, place, False)
        assert _STEP_CACHE.built_by[key] != main
        assert sc.is_ready(rung)
    # The warm pass actually built (missed) the non-initial rungs.
    assert _STEP_CACHE.misses - misses_before >= len(rungs) - 1
    # A switch is then a pure lookup: the cached step object is returned.
    assert sc.step_for(rungs[1]) is _cached_multi_step(cfg, rungs[1], False)


# --- end-to-end: forced ramp -------------------------------------------------

def test_autoscale_ramp_grow_shrink_and_ema_parity():
    """Five short streams + two long ones through a (2, 4) ladder: the
    backlog forces a grow, the drained tail forces a shrink, and every
    stream's output frames, emission order, and final EMA state are
    identical to a fixed-max-lane serve of the same streams."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2, update_period=2)
    lengths = [8, 8, 8, 8, 8, 40, 40]
    pol = ScalePolicy(rungs=(2, 4), grow_pending=1, dwell_up=1,
                      dwell_down=1, evict_tardy_after=None)

    # Fixed-lane reference (also pre-compiles the 4-lane step; a separate
    # 2-lane prime below makes ladder warming a cache hit, so the ramp's
    # switches don't hinge on compile latency).
    ref = ElasticServer(cfg, batch=2, timeout_s=5.0)
    ref_outs = {}
    ref_rep = ref.serve_many(
        [StreamRequest(f"s{i}", iter(v))
         for i, v in enumerate(_streams(7, lengths, seed=41))], n_lanes=4,
        sink=lambda sid, fid, f: ref_outs.setdefault((sid, fid), f))
    assert ref_rep.skipped == 0 and ref_rep.ladder_switches == 0
    prime = ElasticServer(cfg, batch=2, timeout_s=5.0)
    prime.serve_many([StreamRequest("pr", iter(_streams(1, [4],
                                                        seed=43)[0]))],
                     n_lanes=2)

    srv = ElasticServer(cfg, batch=2, timeout_s=5.0)
    outs, emitted = {}, {}

    def sink(sid, fid, f):
        outs[(sid, fid)] = f
        emitted.setdefault(sid, []).append(fid)

    rep = srv.serve_many(
        [StreamRequest(f"s{i}", iter(v))
         for i, v in enumerate(_streams(7, lengths, seed=41))],
        n_lanes=4, sink=sink, autoscale=True, policy=pol)

    # The ramp actually walked the ladder: with a two-rung ladder starting
    # (and ending, since rep.n_lanes == 2) at the bottom, >= 2 switches
    # means at least one grow AND one shrink.
    assert rep.ladder_switches >= 2
    assert rep.n_lanes == 2
    assert rep.evictions == 0

    # Zero dropped, zero duplicated, in order — per stream.
    assert rep.frames == sum(lengths) and rep.skipped == 0
    for i, n in enumerate(lengths):
        assert emitted[f"s{i}"] == list(range(n))

    # Bit-for-bit the same outputs and EMA trajectory as the fixed-lane
    # serve: the rung switch repacks state, it does not perturb it.
    assert outs.keys() == ref_outs.keys()
    for k in outs:
        np.testing.assert_allclose(outs[k], ref_outs[k], atol=ATOL, rtol=0)
    for i in range(7):
        np.testing.assert_allclose(
            np.asarray(srv.store.get(f"s{i}").A),
            np.asarray(ref.store.get(f"s{i}").A), atol=ATOL, rtol=0)
        assert srv.store.cursor(f"s{i}") == lengths[i]


# --- deadline-aware eviction -------------------------------------------------

def test_tardy_stream_checkpoints_requeues_and_resumes():
    """A past-deadline stream hogging the only lane is preempted after
    ``evict_tardy_after`` ticks: the waiter serves next, the tardy stream
    resumes from its checkpoint, emits every frame exactly once in order,
    and its final EMA state matches an uninterrupted serve."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2, update_period=2)
    tardy_v = _streams(1, [12], seed=53)[0]
    waiter_v = _streams(1, [4], seed=59)[0]

    srv = ElasticServer(cfg, batch=2, timeout_s=5.0)
    seq = []

    def sink(sid, fid, f):
        seq.append((sid, fid))

    rep = srv.serve_many(
        [StreamRequest("tardy", iter(tardy_v), deadline=0.0),
         StreamRequest("waiter", iter(waiter_v))],
        n_lanes=1, sink=sink,
        policy=ScalePolicy(evict_tardy_after=2),
        clock=lambda: 100.0)                     # deadline long blown

    assert rep.evictions == 1
    assert rep.ladder_switches == 0              # policy without autoscale
    assert rep.admissions == 3                   # tardy, waiter, tardy again
    assert rep.frames == 16 and rep.skipped == 0
    assert rep.per_stream["tardy"].frames == 12
    assert rep.per_stream["waiter"].frames == 4

    tardy_fids = [fid for sid, fid in seq if sid == "tardy"]
    waiter_fids = [fid for sid, fid in seq if sid == "waiter"]
    assert tardy_fids == list(range(12))         # no loss, no dupes, ordered
    assert waiter_fids == list(range(4))
    # The preemption actually interleaved: the waiter finished before the
    # tardy stream's last frame.
    assert seq.index(("waiter", 3)) < seq.index(("tardy", 11))
    assert srv.store.cursor("tardy") == 12

    # Checkpoint/resume preserved the EMA trajectory exactly.
    ref = ElasticServer(cfg, batch=2, timeout_s=5.0)
    ref.serve_many([StreamRequest("tardy", iter(tardy_v))], n_lanes=1)
    np.testing.assert_allclose(np.asarray(srv.store.get("tardy").A),
                               np.asarray(ref.store.get("tardy").A),
                               atol=ATOL, rtol=0)


def test_no_eviction_without_waiters_or_before_deadline():
    """Eviction needs all three: a blown deadline, the dwell, and a queue.
    A tardy stream alone on the fleet is never preempted; a deadlined
    stream still inside its deadline is never preempted."""
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    pol = ScalePolicy(evict_tardy_after=1)

    srv = ElasticServer(cfg, batch=2, timeout_s=5.0)
    rep = srv.serve_many(
        [StreamRequest("solo", iter(_streams(1, [8], seed=61)[0]),
                       deadline=0.0)],
        n_lanes=1, policy=pol, clock=lambda: 100.0)
    assert rep.evictions == 0 and rep.frames == 8

    srv2 = ElasticServer(cfg, batch=2, timeout_s=5.0)
    vids = _streams(2, [8, 4], seed=67)
    rep2 = srv2.serve_many(
        [StreamRequest("ok", iter(vids[0]), deadline=1e9),
         StreamRequest("queued", iter(vids[1]))],
        n_lanes=1, policy=pol, clock=lambda: 0.0)
    assert rep2.evictions == 0
    assert rep2.frames == 12 and rep2.skipped == 0
