"""Zero-copy tick I/O: donation contract, lane buffer adapter, overlap parity.

The overlapped serve path (``stream.iobuf``) must be bit-identical to the
blocking oracle it replaces on every cell of the dispatch-path x occupancy
matrix, the donated-state step must actually alias (zero new HBM for the
state output), and use-after-donate must be confined to the documented
ownership contract: reads dispatched before the donating tick are safe,
reads after it are the bug the contract exists to prevent.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DehazeConfig, PlacementSpec, init_atmo_state_lanes,
                        make_step)
from repro.core.pipeline import donation_spec
from repro.stream import (ElasticServer, LaneTickStep, StreamRequest,
                          TickBufferPool, donation_supported, fetch_valid)
from repro.stream.elastic import _cached_multi_step

needs_donation = pytest.mark.skipif(
    not donation_supported(),
    reason="backend does not honor donate_argnums")


def _frames(lanes, batch, h=12, w=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random((lanes, batch, h, w, 3)).astype(np.float32)


def _cfg(**kw):
    kw.setdefault("kernel_mode", "ref")
    kw.setdefault("gf_radius", 2)
    kw.setdefault("update_period", 2)
    return DehazeConfig(**kw)


# --- fetch_valid --------------------------------------------------------------

def test_fetch_valid_slices_and_lane_select():
    frames = jnp.asarray(_frames(3, 4))
    got = fetch_valid(frames, 2, lane=1)
    np.testing.assert_array_equal(got, np.asarray(frames)[1, :2])
    assert got.nbytes == frames[1, :2].nbytes
    whole = fetch_valid(frames, 2)            # lane=None: batch-axis slice
    np.testing.assert_array_equal(whole, np.asarray(frames)[:2])


# --- donation contract (core.pipeline.make_step) ------------------------------

def test_donation_spec_follows_dtype_contract():
    # f32 in / f32 out: frames buffer can alias the output -> donated.
    assert donation_spec(_cfg()) == (0, 2)
    # uint8 wire dtype, f32 out: shapes/dtypes differ -> state only.
    assert donation_spec(_cfg(io_dtype="uint8")) == (2,)
    # bf16 in / bf16 out aliases again.
    assert donation_spec(_cfg(io_dtype="bfloat16",
                              out_dtype="bfloat16")) == (0, 2)


@needs_donation
def test_state_donated_step_aliases_input_state():
    """donate="state": the packed EMA state passed in is consumed by the
    call — deleted on exit, proving the output state aliased its buffer
    (zero new HBM allocated for the state each steady tick)."""
    cfg = _cfg()
    step = make_step(cfg, PlacementSpec.lane_batched(), donate="state")
    frames = jnp.asarray(_frames(2, 4))
    ids = jnp.stack([jnp.arange(4, dtype=jnp.int32)] * 2)
    packed = init_atmo_state_lanes(2)
    out = step(frames, ids, packed)
    jax.block_until_ready(out.state)
    assert packed.A.is_deleted(), "input state survived a donating step"
    assert not frames.is_deleted(), 'donate="state" must not touch frames'


@needs_donation
def test_full_donation_takes_frames_when_dtypes_alias():
    cfg = _cfg()
    step = make_step(cfg, PlacementSpec.lane_batched(), donate=True)
    frames = jnp.asarray(_frames(2, 4, seed=1))
    ids = jnp.stack([jnp.arange(4, dtype=jnp.int32)] * 2)
    packed = init_atmo_state_lanes(2)
    out = step(frames, ids, packed)
    jax.block_until_ready(out.frames)
    assert frames.is_deleted() and packed.A.is_deleted()


def test_donation_rejected_for_sharded_placement():
    with pytest.raises(ValueError, match="donat"):
        make_step(_cfg(), PlacementSpec.lane_sharded(), donate="state")


# --- use-after-donate: the ownership contract, both directions ----------------

@needs_donation
def test_use_after_donate_regression():
    """The serve loop's pattern: a host read of ``out.state`` dispatched
    BEFORE the next (donating) tick sees the pre-donation value; touching
    the same buffer AFTER it was donated raises instead of silently
    returning garbage. This is the eviction-snapshot/rung-repack ordering
    rule from the iobuf ownership contract."""
    cfg = _cfg()
    step = make_step(cfg, PlacementSpec.lane_batched(), donate="state")
    frames = jnp.asarray(_frames(2, 4, seed=2))
    ids = jnp.stack([jnp.arange(4, dtype=jnp.int32)] * 2)
    out1 = step(frames, ids, init_atmo_state_lanes(2))
    # Snapshot BEFORE tick 2, with an explicit copy: np.asarray on CPU
    # returns a zero-copy view whose external reference pins the buffer
    # (the runtime then declines to donate that leaf — correct, but it
    # would mask the deletion this test asserts).
    snapshot = np.array(out1.state.A)
    out2 = step(frames, ids + 4, out1.state)  # donates out1.state
    jax.block_until_ready(out2.state)
    assert out1.state.A.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(out1.state.A)              # after donation: loud failure
    assert snapshot.shape == (2, 3)           # the early read stayed valid


# --- LaneTickStep adapter -----------------------------------------------------

def test_lane_tick_step_matches_blocking_step():
    """stage()-per-lane + tick() on the device-resident buffer produces
    the same frames and state as the blocking full-batch call of the
    plain (non-donating) step."""
    cfg = _cfg()
    lanes, batch = 3, 4
    frames = _frames(lanes, batch, seed=3)
    ids = np.stack([np.arange(batch, dtype=np.int32) + 10 * i
                    for i in range(lanes)])
    ref = _cached_multi_step(cfg, lanes, False)(
        jnp.asarray(frames), jnp.asarray(ids), init_atmo_state_lanes(lanes))

    adapter = LaneTickStep(
        _cached_multi_step(cfg, lanes, False, donate="state"), lanes)
    for i in range(lanes):
        adapter.stage(i, frames[i])
    out = adapter.tick(ids, init_atmo_state_lanes(lanes))
    np.testing.assert_array_equal(np.asarray(out.frames),
                                  np.asarray(ref.frames))
    np.testing.assert_array_equal(np.asarray(out.state.A),
                                  np.asarray(ref.state.A))
    assert adapter.staged_lanes == lanes
    assert adapter.staged_bytes == frames.nbytes


def test_lane_tick_step_stale_padding_rows_are_inert():
    """Sparse occupancy: restaging only lane 0 leaves lane 1's row stale
    on device — the frame_id=-1 mask must keep lane 1's state bit-frozen
    and lane 0's output equal to a fresh full-batch run."""
    cfg = _cfg()
    lanes, batch = 2, 4
    f0, f1 = _frames(lanes, batch, seed=4)
    adapter = LaneTickStep(
        _cached_multi_step(cfg, lanes, False, donate="state"), lanes)
    adapter.stage(0, f0)
    adapter.stage(1, f1)
    ids = np.stack([np.arange(batch, dtype=np.int32)] * lanes)
    out1 = adapter.tick(ids, init_atmo_state_lanes(lanes))
    # Host snapshot BEFORE the next tick donates out1.state (the contract).
    state1_host = jax.tree.map(np.asarray, out1.state)

    f0b = _frames(1, batch, seed=5)[0]
    adapter.stage(0, f0b)                     # lane 1 left stale
    ids2 = np.stack([np.arange(batch, dtype=np.int32) + batch,
                     np.full((batch,), -1, np.int32)])
    out2 = adapter.tick(ids2, out1.state)

    ref_frames = np.stack([f0b, f1])          # what the buffer now holds
    ref = _cached_multi_step(cfg, lanes, False)(
        jnp.asarray(ref_frames), jnp.asarray(ids2),
        jax.tree.map(jnp.asarray, state1_host))
    np.testing.assert_array_equal(np.asarray(out2.frames[0]),
                                  np.asarray(ref.frames[0]))
    # Padding lane's state rode through bit-unchanged despite stale frames.
    np.testing.assert_array_equal(np.asarray(out2.state.A[1]),
                                  state1_host.A[1])


def test_all_padding_tick_keeps_state_bit_unchanged():
    """A tick where every lane is padding (all frame ids -1, nothing ever
    staged beyond buffer init) must return the packed state bit-for-bit."""
    cfg = _cfg()
    lanes, batch = 2, 3
    adapter = LaneTickStep(
        _cached_multi_step(cfg, lanes, False, donate="state"), lanes)
    adapter.ensure_buf((batch, 12, 16, 3), np.float32)
    ids = np.full((lanes, batch), -1, np.int32)
    packed = init_atmo_state_lanes(lanes)
    before = jax.tree.map(np.asarray, packed)
    out = adapter.tick(ids, packed)
    after = jax.tree.map(np.asarray, out.state)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)


def test_tick_buffer_pool_one_adapter_per_rung():
    pool = TickBufferPool(lambda n: _cached_multi_step(_cfg(), n, False,
                                                       donate="state"))
    a2, a4 = pool.adapter(2), pool.adapter(4)
    assert a2 is pool.adapter(2) and a4 is pool.adapter(4)
    assert a2 is not a4 and a2.n_lanes == 2 and a4.n_lanes == 4


# --- overlap vs blocking serve parity matrix ----------------------------------

@pytest.mark.parametrize("mode,lane_native", [
    ("ref", False),           # staged XLA chain
    ("fused", False),         # fused kernels, lane-vmapped
    ("fused", True),          # lane-native megakernel
])
@pytest.mark.parametrize("occupancy", ["full", "sparse"])
def test_overlap_serve_parity(monkeypatch, mode, lane_native, occupancy):
    """Every dispatch path x occupancy cell: the overlapped serve's
    delivered frames and final EMA states are bit-identical to the
    blocking oracle's (same executable, same values — donation and
    device-resident staging change where buffers live, never the math)."""
    if not donation_supported():
        pytest.skip("backend does not honor donate_argnums")
    monkeypatch.setenv("REPRO_LANE_NATIVE", "1" if lane_native else "0")
    cfg = _cfg(kernel_mode=mode)
    n_streams, lanes = (4, 4) if occupancy == "full" else (2, 4)
    lengths = [10, 7, 13, 5][:n_streams]
    rng = np.random.default_rng(42)
    vids = [[rng.random((12, 16, 3)).astype(np.float32) for _ in range(k)]
            for k in lengths]

    def serve(tick_overlap):
        srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
        outs = {}
        rep = srv.serve_many(
            [StreamRequest(f"s{i}", iter(v)) for i, v in enumerate(vids)],
            n_lanes=lanes, tick_overlap=tick_overlap,
            sink=lambda sid, fid, f: outs.setdefault((sid, fid), f))
        finals = {f"s{i}": np.asarray(srv.store.get(f"s{i}").A)
                  for i in range(n_streams)}
        return rep, outs, finals

    rep_b, outs_b, fin_b = serve(False)
    rep_o, outs_o, fin_o = serve(True)
    assert rep_b.overlap_ticks == 0
    assert rep_o.overlap_ticks == rep_o.ticks > 0
    assert rep_o.frames == rep_b.frames == sum(lengths)
    assert outs_o.keys() == outs_b.keys()
    for k in outs_b:
        np.testing.assert_array_equal(outs_o[k], outs_b[k])
    for sid in fin_b:
        np.testing.assert_array_equal(fin_o[sid], fin_b[sid])
    if occupancy == "sparse":
        # Valid-only D2H: the blocking path fetched the padding lanes too.
        assert rep_o.d2h_bytes < rep_b.d2h_bytes


def test_env_knob_forces_overlap(monkeypatch):
    if not donation_supported():
        pytest.skip("backend does not honor donate_argnums")
    monkeypatch.setenv("REPRO_TICK_OVERLAP", "1")
    cfg = _cfg()
    rng = np.random.default_rng(7)
    vids = [[rng.random((12, 16, 3)).astype(np.float32) for _ in range(6)]
            for _ in range(2)]
    srv = ElasticServer(cfg, batch=3, timeout_s=5.0)
    rep = srv.serve_many([StreamRequest(f"s{i}", iter(v))
                          for i, v in enumerate(vids)], n_lanes=2)
    assert rep.overlap_ticks == rep.ticks > 0
    monkeypatch.setenv("REPRO_TICK_OVERLAP", "0")
    rep2 = srv.serve_many([StreamRequest(f"t{i}", iter(v))
                           for i, v in enumerate(vids)], n_lanes=2)
    assert rep2.overlap_ticks == 0


def test_serve_report_phases_and_stragglers():
    """Healthy serve: the three tick phases are populated on the report's
    injectable clock and no shutdown stragglers are counted."""
    cfg = _cfg()
    rng = np.random.default_rng(11)
    vids = [[rng.random((12, 16, 3)).astype(np.float32) for _ in range(5)]]
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    rep = srv.serve_many([StreamRequest("s0", iter(vids[0]))], n_lanes=1)
    assert set(rep.phases) == {"host_stage_s", "device_step_s", "deliver_s"}
    assert all(v >= 0.0 for v in rep.phases.values())
    assert rep.phases["device_step_s"] > 0.0
    assert rep.stragglers == 0
