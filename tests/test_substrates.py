"""Substrates: optimizer, schedules, checkpointing, data pipelines."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              load_pytree, save_pytree)
from repro.data import (DiffusionStream, HazeVideoSpec, ImageStream,
                        TokenStream, generate_haze_video, prefetch_to_device)
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, global_norm)


# --- optimizer ----------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - 2.0) ** 2) + jnp.sum((p["b"] + 1) ** 2)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        return adamw_update(g, s, p, 0.05, weight_decay=0.0)

    for _ in range(300):
        params, opt = step(params, opt)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), 20.0)
    g2, _ = clip_by_global_norm({"a": jnp.full((4,), 0.01)}, 1.0)
    np.testing.assert_allclose(np.asarray(g2["a"]), 0.01)  # below max: no-op


def test_weight_decay_mask_default():
    """ndim<2 leaves (biases, norms) are not decayed by default."""
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    opt = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _ = adamw_update(zeros, opt, params, lr=1.0, weight_decay=0.1)
    assert float(jnp.abs(new["b"] - 1.0).max()) < 1e-6     # no decay
    assert float(jnp.abs(new["w"] - 1.0).max()) > 1e-3     # decayed


def test_microbatched_train_step_matches_plain():
    """Gradient accumulation (EXPERIMENTS §Perf A3/B4) must be numerically
    equivalent to the full-batch step."""
    from repro.models import transformer as T
    from repro.models.common import init_params
    from repro.models.steps import make_train_step
    cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     head_dim=8, d_ff=64, vocab=64, dtype="float32",
                     kv_block=16, remat=False)
    params = init_params(jax.random.key(0), T.lm_param_table(cfg))
    opt = adamw_init(params)
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    lr = cosine_schedule(1e-3, 2, 10)
    s1 = jax.jit(make_train_step(T.make_loss_fn(cfg), lr))
    s2 = jax.jit(make_train_step(T.make_loss_fn(cfg), lr, microbatches=4))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.asarray(55))) < 1.0
    np.testing.assert_allclose(float(lr(jnp.asarray(100))), 0.1, rtol=1e-4)


# --- checkpointing ----------------------------------------------------------------

def test_checkpoint_atomic_and_retention():
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, {"step": s})
        assert mgr.all_steps() == [3, 4]
        restored, extra, step = mgr.restore(tree)
        assert step == 4 and extra["step"] == 4
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10))


def test_checkpoint_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_pytree(os.path.join(d, "ck"), {"a": jnp.ones(3)})
        with pytest.raises(AssertionError):
            load_pytree(os.path.join(d, "ck"),
                        {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_async_checkpointer_overlaps_and_surfaces_errors():
    tree = {"a": jnp.arange(5)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        ck = AsyncCheckpointer(mgr)
        ck.save(1, tree)
        ck.wait()
        assert mgr.all_steps() == [1]
        # Background-write failures must surface on the next wait().
        def boom(*a, **k):
            raise RuntimeError("disk gone")
        mgr.save = boom
        ck.save(2, tree)
        with pytest.raises(RuntimeError, match="disk gone"):
            ck.wait()


def test_train_resume_equivalence():
    """Fault tolerance: save at step k, restart, continue — trajectories
    must match an uninterrupted run exactly."""
    from repro.models import transformer as T
    from repro.models.common import init_params
    from repro.models.steps import make_train_step
    from repro import configs as cfgreg
    cfg = cfgreg.get_module("llama3-8b").smoke_config()
    params = init_params(jax.random.key(0), T.lm_param_table(cfg))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(T.make_loss_fn(cfg),
                                   cosine_schedule(1e-3, 2, 50)))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    # Uninterrupted: 6 steps.
    p1, o1 = params, opt
    for _ in range(6):
        p1, o1, _ = step(p1, o1, batch)

    # Interrupted at 3, checkpoint, restore, continue.
    p2, o2 = params, opt
    for _ in range(3):
        p2, o2, _ = step(p2, o2, batch)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, {"params": p2, "opt": o2})
        restored, _, _ = mgr.restore({"params": p2, "opt": o2})
    p3, o3 = restored["params"], restored["opt"]
    for _ in range(3):
        p3, o3, _ = step(p3, o3, batch)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


# --- data ----------------------------------------------------------------------

def test_haze_video_physics_consistency():
    spec = HazeVideoSpec(height=32, width=40, n_frames=6, seed=3)
    vid = generate_haze_video(spec)
    assert vid.hazy.shape == (6, 32, 40, 3)
    # I = J t + A(1-t) must hold exactly (pre-clip).
    i = 2
    recon = (vid.clear[i] * vid.t[i][..., None]
             + vid.A[i] * (1 - vid.t[i][..., None]))
    np.testing.assert_allclose(np.clip(recon, 0, 1), vid.hazy[i], atol=1e-6)
    # determinism
    vid2 = generate_haze_video(spec)
    np.testing.assert_array_equal(vid.hazy, vid2.hazy)


def test_token_stream_shapes_and_labels():
    it = iter(TokenStream(batch=4, seq_len=16, vocab=100, seed=0))
    b = next(it)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert b["tokens"].max() < 100


def test_image_stream_learnable_signal():
    it = iter(ImageStream(batch=32, height=8, width=8, n_classes=8, seed=0))
    b = next(it)
    means = [b["images"][b["labels"] % 8 == k].mean() for k in (0, 7)]
    assert abs(means[0] - means[1]) > 0.3   # class-dependent mean


def test_diffusion_stream_keys():
    it = iter(DiffusionStream(batch=2, latent_res=8, channels=4,
                              ctx_len=7, ctx_dim=16))
    b = next(it)
    assert set(b) == {"latents", "timesteps", "labels", "context"}


def test_prefetch_to_device_preserves_order():
    src = ({"x": np.full((2,), i, np.float32)} for i in range(5))
    out = [int(b["x"][0]) for b in prefetch_to_device(iter(src), size=2)]
    assert out == [0, 1, 2, 3, 4]
