"""The frame I/O dtype contract (README §Dtype contract).

Parity matrix {uint8, bfloat16, float32} ingest x {fused, lane_native,
halo} against the dtype-matched ref oracle, the double-buffered grid's
bit-parity + traced DMA structure, spout wire-dtype preservation, the
dtype-tagged tuning buckets, and the step-cache stale-key regression
(an io/out dtype toggle must never reuse a step compiled for another
dtype contract).

Tolerances: uint8 ingest uses the identical canonical upcast
(``kernels.ref.upcast_frames``) on every substrate, so it is bit-exact vs
the dtype-matched oracle on the ref substrate and float32-round-off-tight
under interpret. bfloat16 ingest is *bounded, not exact*, against the
staged chain: the megakernel upcasts to f32 in-VMEM while the staged XLA
chain computes in bf16, so they agree only to bf16 precision (~1e-2).

No hypothesis dependency on purpose — tier-1 coverage for the quantized
ingest path (the CI kernel-parity job runs this file under
``REPRO_KERNEL_MODE=interpret``).
"""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DehazeConfig, init_atmo_state, make_dehaze_step,
                        make_multi_stream_step)
from repro.core.normalize import pack_atmo_states
from repro.kernels import ops, tuning
from repro.kernels import ref as kref
from repro.kernels.ops import resolve_mode

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

IO_DTYPES = ["float32", "bfloat16", "uint8"]

# uint8/f32 ingest: same f32 compute on both paths -> substrate round-off.
# bf16 ingest: staged chain computes in bf16, the kernels upcast -> bf16
# precision is the agreement bar.
TOL = {"float32": 2e-4, "uint8": 2e-4, "bfloat16": 2e-2}
TOL_A = {"float32": 1e-4, "uint8": 1e-4, "bfloat16": 2e-2}


def _frames(seed=17, *lead, h=32, w=32):
    from conftest import ramp_frames
    return ramp_frames(seed, *(lead or (4,)), h=h, w=w)


def _wire(frames, io_dtype):
    return jnp.asarray(kref.quantize_frames(np.asarray(frames), io_dtype))


def _cfg(kernel_mode, io_dtype="float32", **kw):
    return DehazeConfig(kernel_mode=kernel_mode, io_dtype=io_dtype,
                        patch_radius=3, gf_radius=4, update_period=2, **kw)


# ---------------------------------------------------------------------------
# Ingest parity: fused single-stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("io_dtype", IO_DTYPES)
def test_fused_ingest_parity(io_dtype):
    """Fused step on wire-dtype frames vs the dtype-matched staged ref
    oracle on the SAME wire frames. uint8 on the ref substrate is
    bit-exact (identical canonical upcast on both paths)."""
    wire = _wire(_frames(), io_dtype)
    ids = jnp.arange(4, dtype=jnp.int32)
    got = make_dehaze_step(_cfg("fused", io_dtype))(
        wire, ids, init_atmo_state())
    want = make_dehaze_step(_cfg("ref", io_dtype))(
        wire, ids, init_atmo_state())
    exact = io_dtype == "uint8" and resolve_mode("fused") == "ref"
    if exact:
        np.testing.assert_array_equal(np.asarray(got.frames),
                                      np.asarray(want.frames))
        np.testing.assert_array_equal(np.asarray(got.transmission),
                                      np.asarray(want.transmission))
    tol, tol_a = TOL[io_dtype], TOL_A[io_dtype]
    for field in ("frames", "transmission"):
        np.testing.assert_allclose(
            np.asarray(getattr(got, field), np.float32),
            np.asarray(getattr(want, field), np.float32), atol=tol,
            err_msg=f"{field}/{io_dtype}")
    np.testing.assert_allclose(np.asarray(got.state.A),
                               np.asarray(want.state.A), atol=tol_a,
                               err_msg=io_dtype)
    assert int(got.state.last_update) == int(want.state.last_update)


@pytest.mark.parametrize("io_dtype", IO_DTYPES)
def test_ingest_output_dtype_contract(io_dtype):
    """out_dtype="auto": float ingest keeps its dtype on J/t, uint8
    resolves to float32. Both step flavors."""
    expect = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
              "uint8": jnp.float32}[io_dtype]
    wire = _wire(_frames(), io_dtype)
    ids = jnp.arange(4, dtype=jnp.int32)
    for km in ("fused", "ref"):
        out = make_dehaze_step(_cfg(km, io_dtype))(
            wire, ids, init_atmo_state())
        assert out.frames.dtype == expect, (km, io_dtype)
        assert out.transmission.dtype == expect, (km, io_dtype)


def test_explicit_out_dtype_bfloat16():
    """out_dtype="bfloat16" halves output HBM traffic for f32 ingest."""
    frames = _frames()
    ids = jnp.arange(4, dtype=jnp.int32)
    for km in ("fused", "ref"):
        cfg = DehazeConfig(kernel_mode=km, out_dtype="bfloat16",
                           patch_radius=3, gf_radius=4, update_period=2)
        out = make_dehaze_step(cfg)(frames, ids, init_atmo_state())
        assert out.frames.dtype == jnp.bfloat16, km
        assert out.transmission.dtype == jnp.bfloat16, km


# ---------------------------------------------------------------------------
# Ingest parity: lane-native megakernel (+ the all-padding uint8 lane)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("io_dtype", IO_DTYPES)
def test_lane_native_ingest_parity(io_dtype):
    """Lane-native megakernel on wire-dtype lanes vs the per-lane
    single-stream oracle, with lane 3 all padding: its state must ride
    through bit-unchanged at every wire dtype."""
    n_lanes, b = 4, 4
    frames = jnp.stack([_frames(20 + lane, b) for lane in range(n_lanes)])
    wire = _wire(frames, io_dtype)
    ids = jnp.stack([jnp.arange(lane * 10, lane * 10 + b, dtype=jnp.int32)
                     for lane in range(n_lanes - 1)]
                    + [jnp.full((b,), -1, jnp.int32)])
    states = [init_atmo_state() for _ in range(n_lanes)]
    packed = pack_atmo_states(states)
    multi = make_multi_stream_step(_cfg("fused", io_dtype),
                                   lane_native=True)
    out = multi(wire, ids, packed)
    oracle = make_dehaze_step(_cfg("ref", io_dtype))
    tol, tol_a = TOL[io_dtype], TOL_A[io_dtype]
    for lane in range(n_lanes - 1):
        want = oracle(wire[lane], ids[lane], states[lane])
        tag = f"{io_dtype}/lane{lane}"
        np.testing.assert_allclose(
            np.asarray(out.frames[lane], np.float32),
            np.asarray(want.frames, np.float32), atol=tol, err_msg=tag)
        np.testing.assert_allclose(np.asarray(out.state.A[lane]),
                                   np.asarray(want.state.A), atol=tol_a,
                                   err_msg=tag)
        assert int(out.state.last_update[lane]) == \
            int(want.state.last_update), tag
    pad = n_lanes - 1
    np.testing.assert_array_equal(np.asarray(out.state.A[pad]),
                                  np.asarray(packed.A[pad]))
    assert int(out.state.last_update[pad]) == int(packed.last_update[pad])
    assert not bool(out.state.initialized[pad])


# ---------------------------------------------------------------------------
# Ingest parity: halo-aware kernel (the n_h = 2 shard workload)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("io_dtype", IO_DTYPES)
def test_halo_ingest_parity(io_dtype):
    """The halo megakernel's wire-dtype img input (interpret substrate,
    i.e. the actual kernel body) vs the dtype-matched XLA oracle, on a
    shard-0-of-2 workload with an invalid top halo. Both paths share the
    canonical upcast, so every wire dtype is round-off-tight here."""
    b, h, w = 2, 24, 16
    n_h, radius, gf_radius = 2, 2, 3
    halo = radius + 2 * gf_radius
    frames = _frames(31, b, h=h, w=w)
    h_loc = h // n_h
    img = frames[:, :h_loc]
    pre = kref.premap(frames, jnp.ones((3,), jnp.float32), "dcp")
    guide = kref.luminance(frames)
    n_avail = min(h, h_loc + halo)
    pad_top = jnp.zeros((b, halo, w), jnp.float32)
    pad_bot = jnp.zeros((b, h_loc + halo - n_avail, w), jnp.float32)
    pre_ext = jnp.concatenate([pad_top, pre[:, :n_avail], pad_bot], axis=1)
    guide_ext = jnp.concatenate([pad_top, guide[:, :n_avail], pad_bot],
                                axis=1)
    rows_i = jnp.arange(h_loc + 2 * halo)
    valid = (rows_i >= halo) & (rows_i < halo + n_avail)

    wire_img = _wire(img, io_dtype)
    kw = dict(algorithm="dcp", radius=radius, omega=0.95, refine=True,
              gf_radius=gf_radius, gf_eps=1e-3, topk=2)
    got = ops.fused_transmission_halo(wire_img, pre_ext, guide_ext, valid,
                                      mode="interpret", **kw)
    want = ops.fused_transmission_halo(wire_img, pre_ext, guide_ext, valid,
                                       mode="ref", **kw)
    for g, r, name in zip(got[:3], want[:3], ("t", "tk_t", "tk_rgb")):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32), atol=2e-4,
                                   err_msg=f"{name}/{io_dtype}")
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))
    # Candidate RGB comes back at the resolved out dtype.
    expect = jnp.float32 if io_dtype == "uint8" else jnp.dtype(io_dtype)
    assert got[2].dtype == expect, io_dtype


# ---------------------------------------------------------------------------
# Double buffering: bit-parity through ops + traced DMA structure
# ---------------------------------------------------------------------------

def _dehaze_args(img):
    b = img.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    s = init_atmo_state()
    kw = dict(algorithm="dcp", radius=2, omega=0.95, refine=True,
              gf_radius=3, gf_eps=1e-3, t0=0.1, gamma=1.0, period=2,
              lam=0.3, frames_per_block=2)
    return (img, ids, s.A, s.last_update, s.initialized), kw


@pytest.mark.parametrize("io_dtype", ["float32", "uint8"])
def test_dbuf_matches_classic_through_ops(io_dtype):
    """buffer_depth=2 through the ops dispatch (explicit depth overrides
    the interpret clamp, so the manual-DMA kernel body actually runs) must
    be bit-identical to the single-buffered grid — the double buffering
    changes WHEN bytes move, never what the kernel computes."""
    img = _wire(_frames(41, 4, h=16, w=16), io_dtype)
    args, kw = _dehaze_args(img)
    classic = ops.fused_dehaze(*args, buffer_depth=1, mode="interpret", **kw)
    dbuf = ops.fused_dehaze(*args, buffer_depth=2, mode="interpret", **kw)
    for c, d in zip(classic, dbuf):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(d))
    assert dbuf[0].dtype == jnp.float32 if io_dtype == "uint8" else True


def test_dbuf_traced_dma_structure():
    """The overlap is in the lowered program: the double-buffered body
    traces a warm-up + a next-block prefetch ``dma_start`` against one
    ``dma_wait`` per grid step (copy of block n+1 in flight while block n
    computes); the classic body traces none. The halo kernel moves three
    input planes per block -> 3x the counts."""
    img = _frames(43, 4, h=16, w=16)
    args, kw = _dehaze_args(img)

    def run(depth):
        return ops.fused_dehaze(*args, buffer_depth=depth,
                                mode="interpret", **kw)[0]

    assert ops.dma_copy_count(lambda: run(1)) == {"starts": 0, "waits": 0}
    assert ops.dma_copy_count(lambda: run(2)) == {"starts": 2, "waits": 1}

    b, h, w = 4, 24, 16
    frames = _frames(47, b, h=h, w=w)
    pre = kref.premap(frames, jnp.ones((3,), jnp.float32), "dcp")
    guide = kref.luminance(frames)
    valid = jnp.ones((h,), bool)
    hkw = dict(algorithm="dcp", radius=2, omega=0.95, refine=True,
               gf_radius=3, gf_eps=1e-3, frames_per_block=2)

    def run_halo(depth):
        return ops.fused_transmission_halo(frames, pre, guide, valid,
                                           buffer_depth=depth,
                                           mode="interpret", **hkw)[0]

    assert ops.dma_copy_count(lambda: run_halo(1)) == \
        {"starts": 0, "waits": 0}
    assert ops.dma_copy_count(lambda: run_halo(2)) == \
        {"starts": 6, "waits": 3}


def test_interpret_clamps_resolved_buffer_depth():
    """Substrate-resolved depth (buffer_depth <= 0, the production
    default) clamps to the single-buffered body under interpret — the
    manual-DMA ring brings no overlap there. An explicit depth passes
    through (how the tests above execute the DMA body)."""
    img = _frames(53, 4, h=16, w=16)
    args, kw = _dehaze_args(img)
    resolved = ops.dma_copy_count(
        lambda: ops.fused_dehaze(*args, mode="interpret", **kw)[0])
    assert resolved == {"starts": 0, "waits": 0}


# ---------------------------------------------------------------------------
# Spout: wire dtype preserved host-side
# ---------------------------------------------------------------------------

def test_spout_preserves_wire_dtype():
    from repro.stream.spout import Spout

    u8 = [np.zeros((4, 4, 3), np.uint8) + i for i in range(3)]
    batches = list(Spout(iter(u8), batch=2))
    assert [b.frames.dtype for b in batches] == [np.uint8, np.uint8]
    # Padding repeats the last frame — dtype-matched by construction.
    assert batches[1].n_valid == 1
    np.testing.assert_array_equal(batches[1].frames[1], u8[-1])
    assert list(batches[1].frame_ids) == [2, -1]

    f32 = [np.zeros((4, 4, 3), np.float32)]
    assert next(iter(Spout(iter(f32), batch=1))).frames.dtype == np.float32
    # Unsupported wire dtypes coerce to f32 (the pre-contract behavior).
    f64 = [np.zeros((4, 4, 3), np.float64)]
    assert next(iter(Spout(iter(f64), batch=1))).frames.dtype == np.float32


# ---------------------------------------------------------------------------
# Tuning registry: dtype-tagged buckets
# ---------------------------------------------------------------------------

def test_tuning_bucket_dtype_tags(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "t.json"))
    assert tuning.shape_bucket((4, 16, 16)) == "4x16x16"
    assert tuning.shape_bucket((4, 16, 16), jnp.float32) == "4x16x16"
    assert tuning.shape_bucket((4, 16, 16), jnp.uint8) == "4x16x16xu8"
    assert tuning.shape_bucket((4, 16, 16), jnp.bfloat16) == "4x16x16xbf16"
    # A persisted uint8 bucket layers over the untagged one for uint8
    # lookups only; f32 resolution is untouched.
    tuning.save_table({"fused_dcp": {
        "4x16x16": {"frames_per_block": 2},
        "4x16x16xu8": {"frames_per_block": 4, "buffer_depth": 3}}})
    assert tuning.get_params("fused_dcp", (4, 16, 16)) == \
        {"frames_per_block": 2, "buffer_depth": 2}
    assert tuning.get_params("fused_dcp", (4, 16, 16), dtype=jnp.uint8) == \
        {"frames_per_block": 4, "buffer_depth": 3}
    assert tuning.get_params("fused_dcp", (4, 16, 16),
                             dtype=jnp.float32) == \
        {"frames_per_block": 2, "buffer_depth": 2}


# ---------------------------------------------------------------------------
# Step cache: io/out dtype toggles must never reuse a stale step
# ---------------------------------------------------------------------------

def test_step_cache_keys_on_io_dtype():
    from repro.stream.elastic import _STEP_CACHE, _cached_multi_step, \
        _cached_step

    base = DehazeConfig(patch_radius=3, gf_radius=4)
    u8 = DehazeConfig(patch_radius=3, gf_radius=4, io_dtype="uint8")
    out_bf16 = DehazeConfig(patch_radius=3, gf_radius=4,
                            out_dtype="bfloat16")
    s_base, s_u8, s_out = (_cached_step(c) for c in (base, u8, out_bf16))
    assert s_base is not s_u8, "io_dtype toggle reused a cached step"
    assert s_base is not s_out, "out_dtype toggle reused a cached step"
    assert _cached_step(base) is s_base          # same cfg still hits

    m_base = _cached_multi_step(base, 2, False)
    m_u8 = _cached_multi_step(u8, 2, False)
    assert m_base is not m_u8, "multi-step io_dtype toggle reused a step"
    assert _cached_multi_step(base, 2, False) is m_base
    assert _STEP_CACHE.hits >= 2

    # The donation contract is part of the key: a donating tick step must
    # never be handed to a caller that reuses its input buffers.
    assert _cached_step(base, donate=True) is not s_base
    m_don = _cached_multi_step(base, 2, False, donate="state")
    assert m_don is not m_base, "donation contract toggle reused a step"
    assert _cached_multi_step(base, 2, False, donate="state") is m_don


# ---------------------------------------------------------------------------
# Roofline gate: measured kernel-boundary bytes per ingest dtype
# ---------------------------------------------------------------------------

def test_roofline_u8_input_bytes_within_target():
    """The bench-side gate as a test: the traced pallas_call operand bytes
    for uint8 ingest must be <= 30% of the f32 baseline (no hidden XLA
    upcast copy in front of the kernel), and the report must flag it ok."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks import roofline_report
    finally:
        sys.path.remove(ROOT)
    rows = {name: detail for name, _, detail
            in roofline_report._fused_io_rows()}
    u8 = rows["roofline/fused_io/uint8"]
    assert "ok=yes" in u8, u8
    ratio = float(u8.split("input_ratio_vs_f32=")[1].split(";")[0])
    assert ratio <= 0.30, u8
