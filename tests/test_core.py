"""Core dehazing invariants: physics roundtrip, EMA normalization, components."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import (DehazeConfig, ema_scan, ema_scan_associative,
                        init_atmo_state, make_dehaze_step)
from repro.core.normalize import AtmoState
from repro.core.physics import (recover, synthesize_haze,
                                transmission_from_depth)


def _scene(b=4, h=32, w=40, seed=0):
    """Physically plausible scene: iid albedo (satisfies the dark channel
    prior) but spatially SMOOTH depth (real scenes; DCP's window min mixes
    depths otherwise)."""
    r = np.random.default_rng(seed)
    J = jnp.asarray(r.random((b, h, w, 3), np.float32)) * 0.8
    yy = np.linspace(0, 1, h)[None, :, None]
    xx = np.linspace(0, 1, w)[None, None, :]
    phase = r.random((b, 1, 1))
    depth = 0.3 + 2.0 * (0.5 + 0.5 * np.sin(
        2 * np.pi * (yy + 0.7 * xx + phase))).astype(np.float32)
    t = transmission_from_depth(jnp.asarray(depth, jnp.float32), 1.0)
    return J, t


# --- physics ----------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000),
       a=st.floats(0.6, 1.0), beta=st.floats(0.3, 2.0))
def test_physics_roundtrip_exact(seed, a, beta):
    """recover(synthesize(J, t, A), t, A) == J wherever t >= t0."""
    r = np.random.default_rng(seed)
    J = jnp.asarray(r.random((2, 16, 16, 3), np.float32)) * 0.9
    depth = jnp.asarray(0.1 + r.random((2, 16, 16), np.float32))
    t = transmission_from_depth(depth, beta)
    A = jnp.asarray([a, a * 0.97, min(a * 1.02, 1.0)])
    I = synthesize_haze(J, t, A)
    Jr = recover(I, t, A, t0=0.0)
    mask = np.asarray(t) >= 0.1
    np.testing.assert_allclose(np.asarray(Jr)[mask], np.asarray(J)[mask],
                               atol=1e-4)


def test_transmission_bounds():
    _, t = _scene()
    assert float(jnp.min(t)) > 0.0 and float(jnp.max(t)) <= 1.0


# --- end-to-end component chain ----------------------------------------------

@pytest.mark.parametrize("algo", ["dcp", "cap"])
def test_pipeline_improves_hazy_frames(algo):
    """Dehazed output must be closer to ground truth than the hazy input
    on a synthetic scene (the paper's qualitative claim, made quantitative)."""
    J, t = _scene(b=6, h=48, w=64, seed=1)
    A = jnp.asarray([0.92, 0.9, 0.95])
    I = synthesize_haze(J, t, A)
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref", update_period=2)
    step = jax.jit(make_dehaze_step(cfg))
    out = step(I, jnp.arange(6, dtype=jnp.int32), init_atmo_state())
    err_hazy = float(jnp.mean(jnp.abs(I - J)))
    err_dehazed = float(jnp.mean(jnp.abs(out.frames - J)))
    assert err_dehazed < err_hazy, (err_dehazed, err_hazy)
    assert not bool(jnp.isnan(out.frames).any())
    # estimated A should be in the ballpark of the true A
    a_est = np.asarray(out.atmo_light[-1])
    assert np.all(np.abs(a_est - np.asarray(A)) < 0.25), a_est


def test_dcp_recovers_atmospheric_light_argmin():
    """Paper Eq. 6: with k=1 the estimator picks I at the argmin of t."""
    from repro.core import algorithms as alg
    J, t = _scene(b=2)
    A = jnp.asarray([0.9, 0.91, 0.93])
    I = synthesize_haze(J, t, A)
    cfg = DehazeConfig(kernel_mode="ref", topk=1)
    t_raw = alg.transmission_dcp(I, jnp.ones(3), cfg)
    a_new = alg.estimate_atmospheric_light(I, t_raw, cfg)
    flat_t = np.asarray(t_raw).reshape(2, -1)
    flat_i = np.asarray(I).reshape(2, -1, 3)
    for b in range(2):
        want = flat_i[b, flat_t[b].argmin()]
        np.testing.assert_allclose(np.asarray(a_new[b]), want, atol=1e-6)


def test_recompute_t_with_final_a_changes_dcp_only():
    J, t = _scene(b=2)
    I = synthesize_haze(J, t, jnp.asarray([0.9, 0.9, 0.9]))
    ids = jnp.arange(2, dtype=jnp.int32)
    for algo in ("dcp", "cap"):
        o1 = make_dehaze_step(DehazeConfig(
            algorithm=algo, kernel_mode="ref"))(I, ids, init_atmo_state())
        o2 = make_dehaze_step(DehazeConfig(
            algorithm=algo, kernel_mode="ref",
            recompute_t_with_final_a=True))(I, ids, init_atmo_state())
        same = np.allclose(np.asarray(o1.frames), np.asarray(o2.frames))
        assert same == (algo == "cap")   # CAP's t is A-free


# --- EMA update strategy (paper §3.3) ----------------------------------------

def _numpy_ema(cands, ids, period, lam, a0=None, k0=None):
    """Literal transcription of the paper's update rule."""
    A = a0
    k = k0
    out = []
    for c, fid in zip(cands, ids):
        if A is None:
            A, k = c.copy(), fid
        elif fid - k >= period:
            A = lam * c + (1 - lam) * A
            k = fid
        out.append(A.copy())
    return np.stack(out), A, k


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 40), period=st.integers(1, 9),
       lam=st.floats(0.0, 1.0), seed=st.integers(0, 999))
def test_ema_scan_matches_paper_rule(n, period, lam, seed):
    r = np.random.default_rng(seed)
    cands = r.random((n, 3)).astype(np.float32)
    ids = np.arange(100, 100 + n, dtype=np.int32)
    want, A_fin, k_fin = _numpy_ema(cands, ids, period, lam)
    got, state = ema_scan(jnp.asarray(cands), jnp.asarray(ids),
                          init_atmo_state(), period, lam)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.A), A_fin, atol=1e-5)
    assert int(state.last_update) == int(k_fin)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 33), period=st.integers(1, 9),
       lam=st.floats(0.0, 1.0), seed=st.integers(0, 999),
       pre=st.booleans())
def test_associative_scan_equals_sequential(n, period, lam, seed, pre):
    r = np.random.default_rng(seed)
    cands = jnp.asarray(r.random((n, 3)).astype(np.float32))
    ids = jnp.arange(50, 50 + n, dtype=jnp.int32)
    state = init_atmo_state()
    if pre:   # warmed-up state
        state = AtmoState(A=jnp.asarray(r.random(3).astype(np.float32)),
                          last_update=jnp.asarray(47, jnp.int32),
                          initialized=jnp.asarray(True))
    a1, s1 = ema_scan(cands, ids, state, period, lam)
    a2, s2 = ema_scan_associative(cands, ids, state, period, lam)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.A), np.asarray(s2.A), atol=1e-5)
    assert int(s1.last_update) == int(s2.last_update)


def test_ema_smoothing_reduces_variance():
    """The paper's Fig. 8 claim: normalized A varies less than raw A."""
    r = np.random.default_rng(5)
    base = np.asarray([0.9, 0.9, 0.9], np.float32)
    cands = base + 0.05 * r.standard_normal((64, 3)).astype(np.float32)
    out, _ = ema_scan(jnp.asarray(cands), jnp.arange(64, dtype=jnp.int32),
                      init_atmo_state(), 4, 0.05)
    assert float(np.std(np.asarray(out)[1:])) < float(np.std(cands[1:])) * 0.5


def test_ema_output_in_convex_hull():
    r = np.random.default_rng(7)
    cands = jnp.asarray(r.random((32, 3)).astype(np.float32))
    out, _ = ema_scan(cands, jnp.arange(32, dtype=jnp.int32),
                      init_atmo_state(), 3, 0.3)
    assert float(out.min()) >= float(cands.min()) - 1e-6
    assert float(out.max()) <= float(cands.max()) + 1e-6


def test_config_validation():
    with pytest.raises(AssertionError):
        DehazeConfig(algorithm="nope").validate()
    with pytest.raises(AssertionError):
        DehazeConfig(lam=1.5).validate()
    DehazeConfig().validate()


# --- lane-packed state + lane-native step properties -------------------------

def _random_lane_states(r, n_lanes):
    from repro.core import init_atmo_state
    states = []
    for lane in range(n_lanes):
        if r.random() < 0.3:                       # padding / fresh lane
            states.append(init_atmo_state())
        else:
            states.append(AtmoState(
                A=jnp.asarray(r.random(3), jnp.float32),
                last_update=jnp.asarray(int(r.integers(0, 1000)), jnp.int32),
                initialized=jnp.asarray(bool(r.integers(0, 2)))))
    return states


@settings(max_examples=20, deadline=None)
@given(n_lanes=st.integers(1, 6), seed=st.integers(0, 1000))
def test_lane_state_pack_unpack_get_set_roundtrip(n_lanes, seed):
    """Lane-packed AtmoState invariants: pack/unpack is the identity,
    get_lane_state reads what pack wrote, set_lane_state replaces exactly
    one lane, and the kernel carry layout (lane_carry /
    state_from_lane_carry) round-trips — including uninitialized
    (padding-lane) states."""
    from repro.core import (get_lane_state, lane_carry, pack_atmo_states,
                            set_lane_state, state_from_lane_carry,
                            unpack_atmo_states)
    r = np.random.default_rng(seed)
    states = _random_lane_states(r, n_lanes)
    packed = pack_atmo_states(states)
    assert packed.A.shape == (n_lanes, 3)
    assert packed.last_update.shape == (n_lanes,)

    def assert_state_eq(a, b):
        np.testing.assert_array_equal(np.asarray(a.A), np.asarray(b.A))
        assert int(a.last_update) == int(b.last_update)
        assert bool(a.initialized) == bool(b.initialized)

    for lane, (s, u) in enumerate(zip(states, unpack_atmo_states(packed))):
        assert_state_eq(s, u)
        assert_state_eq(s, get_lane_state(packed, lane))

    # Kernel carry layout round-trip.
    carry_f, carry_i = lane_carry(packed)
    assert carry_f.shape == (n_lanes, 3) and carry_f.dtype == jnp.float32
    assert carry_i.shape == (n_lanes, 2) and carry_i.dtype == jnp.int32
    back = state_from_lane_carry(carry_f, carry_i)
    for lane in range(n_lanes):
        assert_state_eq(get_lane_state(packed, lane),
                        get_lane_state(back, lane))

    # set_lane_state replaces one lane, leaves every other bit-unchanged.
    victim = int(r.integers(0, n_lanes))
    repl = AtmoState(A=jnp.asarray([0.5, 0.25, 0.125], jnp.float32),
                     last_update=jnp.asarray(4242, jnp.int32),
                     initialized=jnp.asarray(True))
    updated = set_lane_state(packed, victim, repl)
    for lane in range(n_lanes):
        assert_state_eq(repl if lane == victim else states[lane],
                        get_lane_state(updated, lane))


@settings(max_examples=10, deadline=None)
@given(n_lanes=st.integers(1, 4), seed=st.integers(0, 1000),
       algorithm=st.sampled_from(["dcp", "cap"]),
       pad_mask=st.lists(st.booleans(), min_size=4, max_size=4),
       data=st.data())
def test_lane_native_step_equals_vmapped(n_lanes, seed, algorithm, pad_mask,
                                         data):
    """Lane-native megakernel vs jax.vmap of the fused single-stream step,
    over random lane counts and padding patterns (whole padding lanes and
    padded batch tails): identical outputs and states per lane. On the
    XLA-oracle substrate (this suite's default) the comparison is
    bit-exact; under REPRO_KERNEL_MODE=interpret the separately compiled
    programs are allowed 2 ulp of FMA reassociation."""
    from repro.core import make_multi_stream_step, pack_atmo_states
    from repro.kernels.ops import resolve_mode
    float_tol = 0.0 if resolve_mode("fused") == "ref" else 1.2e-7
    b, h, w = 3, 12, 16
    r = np.random.default_rng(seed)
    # Tie-stable ramp frames: distinct t everywhere, so the top-k
    # *selection* cannot fork between the two compiled programs.
    from conftest import ramp_frames
    frames = ramp_frames(seed, n_lanes, b, h=h, w=w)
    ids = np.stack([np.arange(lane * 5, lane * 5 + b, dtype=np.int32)
                    for lane in range(n_lanes)])
    for lane in range(n_lanes):
        if pad_mask[lane]:                          # whole lane unoccupied
            ids[lane] = -1
        else:                                       # padded batch tail
            tail = int(data.draw(st.integers(0, b - 1)))
            if tail:
                ids[lane, b - tail:] = -1
    ids = jnp.asarray(ids)
    packed = pack_atmo_states(_random_lane_states(r, n_lanes))

    cfg = DehazeConfig(algorithm=algorithm, kernel_mode="fused",
                       patch_radius=2, gf_radius=2, update_period=2,
                       topk=int(data.draw(st.sampled_from([1, 3]))))
    got = make_multi_stream_step(cfg, lane_native=True)(frames, ids, packed)
    want = make_multi_stream_step(cfg, lane_native=False)(frames, ids,
                                                          packed)
    for field in ("frames", "transmission", "atmo_light"):
        np.testing.assert_allclose(np.asarray(getattr(got, field)),
                                   np.asarray(getattr(want, field)),
                                   atol=float_tol, rtol=0, err_msg=field)
    np.testing.assert_allclose(np.asarray(got.state.A),
                               np.asarray(want.state.A), atol=float_tol,
                               rtol=0)
    np.testing.assert_array_equal(np.asarray(got.state.last_update),
                                  np.asarray(want.state.last_update))
    np.testing.assert_array_equal(np.asarray(got.state.initialized),
                                  np.asarray(want.state.initialized))
    # All-padding lanes ride through bit-unchanged on the lane-native path.
    for lane in range(n_lanes):
        if pad_mask[lane]:
            np.testing.assert_array_equal(np.asarray(got.state.A[lane]),
                                          np.asarray(packed.A[lane]))
            assert int(got.state.last_update[lane]) == \
                int(packed.last_update[lane])
