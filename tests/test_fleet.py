"""Fleet-tier serving: global EDF over N hosts, sticky placement,
spillover admission — and bit-identical per-stream semantics vs the
single-host serve (the paper's §4 claim, distribution changes *where* a
stream runs, never *what* it computes)."""
import threading

import numpy as np
import pytest

from repro.core import DehazeConfig
from repro.stream import ElasticServer, StreamRequest
from repro.stream.fleet import _FleetQueue
from repro.stream.scheduler import _Resume


def _videos(n, length, h=16, w=20, seed=5):
    rng = np.random.default_rng(seed)
    return [[rng.random((h, w, 3)).astype(np.float32)
             for _ in range(length)] for _ in range(n)]


def _serve(srv, vids, sink_store, **kw):
    def sink(sid, fid, payload):
        sink_store.setdefault(sid, []).append((fid, payload.copy()))
    return srv.serve_many(
        [StreamRequest(f"s{i}", iter(v)) for i, v in enumerate(vids)],
        sink=sink, **kw)


# --- parity matrix: fleet cells ----------------------------------------------

@pytest.mark.parametrize("path", ["staged", "lane_native"])
@pytest.mark.parametrize("n_hosts", [1, 2])
def test_fleet_serve_matches_single_host(path, n_hosts):
    """{1, 2 hosts} x {staged, lane-native}: per-stream emitted frames
    (the EMA trajectory is baked into every recovered frame), emission
    order, final EMA state and cursors are bit-identical to the one-host
    one-scheduler serve of the same streams; sticky placement holds
    (zero migrations)."""
    cfg = DehazeConfig(kernel_mode="fused" if path == "lane_native"
                       else "ref", patch_radius=3, gf_radius=4,
                       update_period=2)
    vids = _videos(6, 8)

    base = ElasticServer(cfg, batch=4, timeout_s=5.0)
    want = {}
    rep_w = _serve(base, _videos(6, 8), want, n_lanes=2)
    assert rep_w.frames == 48 and rep_w.skipped == 0

    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    got = {}
    rep = _serve(srv, vids, got, n_lanes=2, n_hosts=n_hosts)
    assert rep.frames == 48 and rep.skipped == 0
    assert rep.n_hosts == n_hosts
    assert rep.migrations == 0
    if n_hosts > 1:
        # first-fit waterfall over 2 lanes/host MUST have spilled
        assert rep.spillovers >= 1
        placements = srv.last_fleet.queue.placements
        assert sorted(placements) == [f"s{i}" for i in range(6)]
        for entry in srv.last_fleet.queue.admission_log:
            assert entry["host"] == placements[entry["stream_id"]]

    for sid in want:
        fids_w = [f for f, _ in want[sid]]
        fids_g = [f for f, _ in got[sid]]
        assert fids_g == fids_w == sorted(fids_w)        # order + exactly-once
        for (_, a), (_, b) in zip(got[sid], want[sid]):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(
            np.asarray(srv.store.get(sid).A), np.asarray(base.store.get(sid).A))
        assert srv.store.cursor(sid) == base.store.cursor(sid)


def test_fleet_duplicate_stream_ids_rejected():
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    v = _videos(2, 3)
    with pytest.raises(ValueError, match="duplicate"):
        srv.serve_many([StreamRequest("dup", iter(v[0])),
                        StreamRequest("dup", iter(v[1]))],
                       n_lanes=1, n_hosts=2)


def test_fleet_hash_policy_spreads_and_stays_sticky():
    cfg = DehazeConfig(kernel_mode="ref", gf_radius=2)
    srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
    rep = _serve(srv, _videos(8, 4), {}, n_lanes=2, n_hosts=2,
                 placement_policy="hash")
    assert rep.frames == 32 and rep.migrations == 0
    hosts_used = {e["host"] for e in srv.last_fleet.queue.admission_log}
    assert hosts_used == {0, 1}


# --- the sticky/spillover no-migration property ------------------------------

def _drive_queue(n_streams, n_hosts, lanes, prefs, choices):
    """Replay a random schedule against the shared queue: hosts pop in an
    arbitrary interleaving, admitted streams either finish or get
    preempted-and-requeued (pinned), until the queue drains. Returns the
    queue for invariant checks."""
    q = _FleetQueue(n_hosts, lanes, lambda sid: prefs[sid])
    for i in range(n_streams):
        q.seed(StreamRequest(f"s{i}", iter(())))
    live = []                         # (host, req) admitted, lane occupied
    occupied = [0] * n_hosts
    step = 0
    while True:
        acted = False
        for h in range(n_hosts):
            if occupied[h] < lanes:
                got = q.pop_for(h)
                if got is not None:
                    _, req, _resume = got
                    occupied[h] += 1
                    live.append((h, req))
                    acted = True
        if live:
            step += 1
            h, req = live.pop(choices(step) % len(live))
            occupied[h] -= 1
            if choices(step + 1) % 3 == 0:       # preempt: requeue pinned
                resume = _Resume(None, 0, threading.Event())
                resume.barrier.set()
                q.push_requeue(req, resume, pin=h)
            else:                                # stream done
                q.note_freed(h)
            acted = True
        if not acted:
            break
    return q


@pytest.mark.parametrize("seed", range(6))
def test_sticky_spillover_never_migrates(seed):
    """Deterministic slice of the property: under arbitrary pop/finish/
    preempt interleavings, every admission of a stream after its first
    lands on the same host — spillover picks the FIRST host, it never
    moves a live stream's EMA."""
    rng = np.random.default_rng(seed)
    n_streams, n_hosts, lanes = 7, 3, 2
    prefs = {f"s{i}": int(rng.integers(n_hosts)) for i in range(n_streams)}
    seq = rng.integers(0, 1_000_000, size=4096)
    q = _drive_queue(n_streams, n_hosts, lanes, prefs,
                     lambda step: int(seq[step % len(seq)]))
    assert q.migrations == 0
    assert not q._entries
    hosts_per_sid = {}
    for e in q.admission_log:
        hosts_per_sid.setdefault(e["stream_id"], set()).add(e["host"])
    assert all(len(hs) == 1 for hs in hosts_per_sid.values()), hosts_per_sid
    # re-admissions are never counted as fresh spillovers
    for e in q.admission_log:
        if e["resumed"]:
            assert not e["spillover"]


def test_sticky_spillover_never_migrates_property():
    """The hypothesis version: random host counts, lane widths, policies
    and interleavings."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -e .[dev])")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 10),
           st.data())
    def prop(n_hosts, lanes, n_streams, data):
        prefs = {f"s{i}": data.draw(st.integers(0, n_hosts - 1))
                 for i in range(n_streams)}
        seq = data.draw(st.lists(st.integers(0, 10**6), min_size=64,
                                 max_size=64))
        q = _drive_queue(n_streams, n_hosts, lanes, prefs,
                         lambda step: seq[step % len(seq)])
        assert q.migrations == 0 and not q._entries
        hosts_per_sid = {}
        for e in q.admission_log:
            hosts_per_sid.setdefault(e["stream_id"], set()).add(e["host"])
        assert all(len(h) == 1 for h in hosts_per_sid.values())

    prop()


# --- exactly-once / frame order through real (subprocess) devices ------------

def test_fleet_exactly_once_frame_order_subprocess():
    """Reuses the distributed harness: a child with 2 forced host devices
    serves 5 streams over a 2-host fleet and asserts every frame id is
    emitted exactly once, in order, matching a sequential single-stream
    reference serve."""
    from test_distributed import run_child
    run_child("""
        import numpy as np
        from repro.core import DehazeConfig
        from repro.stream import ElasticServer, StreamRequest
        cfg = DehazeConfig(kernel_mode="ref", patch_radius=2, gf_radius=3,
                           update_period=2)
        rng = np.random.default_rng(3)
        vids = [[rng.random((16, 20, 3)).astype(np.float32)
                 for _ in range(7)] for _ in range(5)]
        ref = ElasticServer(cfg, batch=4, timeout_s=5.0)
        want = {}
        # sequential reference: same 2-lane executable, one host
        ref.serve_many(
            [StreamRequest(f"s{i}", iter(v)) for i, v in enumerate(vids)],
            n_lanes=2,
            sink=lambda s, f, p: want.setdefault(s, []).append((f, p.copy())))
        srv = ElasticServer(cfg, batch=4, timeout_s=5.0)
        got = {}
        rep = srv.serve_many(
            [StreamRequest(f"s{i}", iter(v)) for i, v in enumerate(vids)],
            n_lanes=2, n_hosts=2,
            sink=lambda s, f, p: got.setdefault(s, []).append((f, p.copy())))
        assert rep.frames == 35 and rep.skipped == 0
        assert rep.migrations == 0
        for sid, pairs in want.items():
            fids = [f for f, _ in got[sid]]
            assert fids == list(range(7)), (sid, fids)       # exactly once
            for (fw, pw), (fg, pg) in zip(pairs, got[sid]):
                assert fw == fg
                np.testing.assert_array_equal(pw, pg)
        print("ok")
    """, devices=2)
