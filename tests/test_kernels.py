"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Sweeps shapes and dtypes per the deliverable spec; hypothesis drives
randomized shapes/content for the filter kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(1, 8, 8), (2, 24, 32), (1, 33, 17), (3, 48, 64)]
RADII = [1, 3, 7]
DTYPES = [jnp.float32, jnp.bfloat16]


def _img(shape, dtype, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.random(shape + (3,), np.float32)).astype(dtype)


def _map(shape, dtype, seed=1):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.random(shape, np.float32)).astype(dtype)


def _tol(dtype):
    return 1e-5 if dtype == jnp.float32 else 2e-2


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("radius", RADII)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dark_channel_matches_oracle(shape, radius, dtype):
    img = _img(shape, dtype)
    got = ops.dark_channel(img, radius, mode="interpret")
    want = ref.dark_channel(img, radius)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("radius", RADII)
@pytest.mark.parametrize("dtype", DTYPES)
def test_box_filter_matches_oracle(shape, radius, dtype):
    x = _map(shape, dtype)
    got = ops.box_filter_2d(x, radius, mode="interpret")
    want = ref.box_filter_2d(x, radius)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype) * 4)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("radius", [1, 5])
def test_min_filter_matches_oracle(shape, radius):
    x = _map(shape, jnp.float32)
    got = ops.min_filter_2d(x, radius, mode="interpret")
    np.testing.assert_allclose(got, ref.min_filter_2d(x, radius), atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_atmolight_matches_oracle(shape):
    img, t = _img(shape, jnp.float32), _map(shape, jnp.float32)
    got = ops.atmospheric_light(img, t, k=1, mode="interpret")
    want = ref.atmospheric_light(img, t, k=1)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_atmolight_tiled_grid():
    """Multi-tile sequential-grid fold must equal the global argmin."""
    from repro.kernels.atmolight import atmolight_pallas
    img, t = _img((2, 32, 16), jnp.float32), _map((2, 32, 16), jnp.float32)
    got = atmolight_pallas(img, t, tile_h=8, interpret=True)
    want = ref.atmospheric_light(img, t, k=1)
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("gamma", [1.0, 2.2])
def test_recover_matches_oracle(shape, dtype, gamma):
    img = _img(shape, dtype)
    t = _map(shape, dtype)
    A = jnp.asarray(np.random.default_rng(2).random((shape[0], 3)),
                    dtype)
    got = ops.recover(img, t, A, gamma=gamma, mode="interpret")
    want = ref.recover(img, t, A)
    if gamma != 1.0:
        want = want ** gamma
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype) * 4)


@pytest.mark.parametrize("radius", [2, 6])
def test_guided_filter_matches_oracle(radius):
    g = _map((2, 32, 24), jnp.float32)
    p = _map((2, 32, 24), jnp.float32, seed=3)
    got = ops.guided_filter(g, p, radius, 1e-3, mode="interpret")
    want = ref.guided_filter(g, p, radius, 1e-3)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("radius", [1, 3, 7])
def test_masked_kernels_match_spatial_reference(radius):
    """The halo-path masked kernels (row-validity masks) must match the
    reduce_window reference used by the sharded pipeline."""
    from repro.core import spatial
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random((2, 24, 32), np.float32))
    valid = jnp.asarray(
        np.concatenate([np.zeros(5), np.ones(14), np.zeros(5)]).astype(bool))
    got = ops.masked_min_filter_2d(x, valid, radius, mode="interpret")
    want = spatial.masked_min_filter_2d(x, valid, radius)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    got = ops.masked_box_filter_2d(x, valid, radius, mode="interpret")
    want = spatial.masked_box_filter_2d(x, valid, radius)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_masked_kernels_all_valid_equal_unmasked():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((1, 16, 16), np.float32))
    valid = jnp.ones((16,), bool)
    np.testing.assert_allclose(
        np.asarray(ops.masked_min_filter_2d(x, valid, 3, mode="interpret")),
        np.asarray(ref.min_filter_2d(x, 3)), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ops.masked_box_filter_2d(x, valid, 3, mode="interpret")),
        np.asarray(ref.box_filter_2d(x, 3)), atol=1e-5)


# --- hypothesis sweeps -----------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 40), w=st.integers(4, 40), r=st.integers(0, 8),
       seed=st.integers(0, 2 ** 16))
def test_min_filter_property(h, w, r, seed):
    x = _map((1, h, w), jnp.float32, seed)
    got = np.asarray(ops.min_filter_2d(x, r, mode="interpret"))[0]
    xn = np.asarray(x)[0]
    # Oracle-by-definition: brute-force clipped window min.
    i, j = np.random.default_rng(seed).integers(0, (h, w))
    want = xn[max(0, i - r):i + r + 1, max(0, j - r):j + r + 1].min()
    np.testing.assert_allclose(got[i, j], want, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 32), w=st.integers(4, 32), r=st.integers(0, 6),
       seed=st.integers(0, 2 ** 16))
def test_box_filter_property(h, w, r, seed):
    x = _map((1, h, w), jnp.float32, seed)
    got = np.asarray(ops.box_filter_2d(x, r, mode="interpret"))[0]
    xn = np.asarray(x)[0]
    i, j = np.random.default_rng(seed).integers(0, (h, w))
    win = xn[max(0, i - r):i + r + 1, max(0, j - r):j + r + 1]
    np.testing.assert_allclose(got[i, j], win.mean(), rtol=1e-5, atol=1e-5)


# --- top-k atmospheric-light selector (kernels.atmolight.topk_select) ------

def _distinct_tmap(h, w, seed):
    """A transmission map with pairwise-distinct values (a scaled
    permutation of arange), so top-k selection is order-unambiguous."""
    perm = np.random.default_rng(seed).permutation(h * w)
    return jnp.asarray(perm.reshape(1, h, w).astype(np.float32) / (h * w))


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 24), w=st.integers(4, 24), k=st.integers(1, 16),
       seed=st.integers(0, 2 ** 16))
def test_topk_selector_permutation_invariant(h, w, k, seed):
    """Permuting the pixels (jointly in t and I) must not change the
    mean-of-top-k A: the selected (t, rgb) multiset is permutation-
    invariant when the t values are distinct."""
    img = _img((1, h, w), jnp.float32, seed)
    t = _distinct_tmap(h, w, seed)
    perm = np.random.default_rng(seed + 1).permutation(h * w)
    img_p = jnp.asarray(np.asarray(img).reshape(1, -1, 3)[:, perm]
                        ).reshape(1, h, w, 3)
    t_p = jnp.asarray(np.asarray(t).reshape(1, -1)[:, perm]).reshape(1, h, w)
    a = ops.atmospheric_light(img, t, k=k, mode="interpret")
    a_p = ops.atmospheric_light(img_p, t_p, k=k, mode="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_p), atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 24), w=st.integers(4, 24),
       seed=st.integers(0, 2 ** 16))
def test_topk_selector_k1_reduces_to_argmin(h, w, seed):
    """k=1 must be the Eq. 6 argmin-t pixel — identical to both the
    dedicated argmin kernel and the direct gather, including ties (ties
    resolve to the lowest flat index, so a tie-heavy quantized map is used
    half the time)."""
    img = _img((1, h, w), jnp.float32, seed)
    t = _map((1, h, w), jnp.float32, seed + 1)
    if seed % 2:
        t = jnp.round(t * 4) / 4                      # force ties
    from repro.kernels.atmolight import atmolight_topk_pallas
    got = atmolight_topk_pallas(img, t, k=1, interpret=True)
    want = ops.atmospheric_light(img, t, k=1, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)
    j = int(np.argmin(np.asarray(t).reshape(-1)))
    np.testing.assert_allclose(np.asarray(got)[0],
                               np.asarray(img).reshape(-1, 3)[j], atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 16), w=st.integers(4, 16),
       seed=st.integers(0, 2 ** 16))
def test_topk_selector_full_k_is_global_mean(h, w, seed):
    """k = H*W selects every pixel: A must equal the full image mean."""
    img = _img((1, h, w), jnp.float32, seed)
    t = _map((1, h, w), jnp.float32, seed + 1)
    got = ops.atmospheric_light(img, t, k=h * w, mode="interpret")
    want = np.asarray(img).reshape(-1, 3).mean(axis=0)
    np.testing.assert_allclose(np.asarray(got)[0], want, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 24), w=st.integers(4, 24), k=st.integers(2, 8),
       tile=st.integers(1, 6), seed=st.integers(0, 2 ** 16))
def test_topk_selector_tiled_fold_matches_oracle(h, w, k, tile, seed):
    """The k-row running selection folded across row tiles (the atmolight
    grid carry) must equal the whole-frame lax.top_k oracle, ties included."""
    img = _img((1, h, w), jnp.float32, seed)
    t = jnp.round(_map((1, h, w), jnp.float32, seed + 1) * 8) / 8
    from repro.kernels.atmolight import atmolight_topk_pallas
    got = atmolight_topk_pallas(img, t, k=k, tile_h=tile, interpret=True)
    want = ref.atmospheric_light(img, t, k=k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# --- 2-D (H x W) masked box mean (kernels.boxfilter._masked_box_mean) ------

@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 24), w=st.integers(4, 24), r=st.integers(0, 6),
       seed=st.integers(0, 2 ** 16))
def test_masked_box_mean_all_valid_equals_unmasked(h, w, r, seed):
    """A mask of all-valid rows AND columns must reproduce the unmasked
    kernel exactly — the column-count fix must not perturb the interior."""
    x = _map((1, h, w), jnp.float32, seed)
    got = ops.masked_box_filter_2d(x, jnp.ones((h,), bool), r,
                                   jnp.ones((w,), bool), mode="interpret")
    want = ref.box_filter_2d(x, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    got = ops.masked_min_filter_2d(x, jnp.ones((h,), bool), r,
                                   jnp.ones((w,), bool), mode="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.min_filter_2d(x, r)), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(6, 24), w=st.integers(6, 24), r=st.integers(1, 5),
       lo_h=st.integers(0, 3), hi_h=st.integers(0, 3),
       lo_w=st.integers(0, 3), hi_w=st.integers(0, 3),
       seed=st.integers(0, 2 ** 16))
def test_masked_box_mean_2d_matches_spatial_reference(h, w, r, lo_h, hi_h,
                                                      lo_w, hi_w, seed):
    """Random separable edge masks (the halo-exchange shapes): the in-VMEM
    separable row x column divisor must match the reduce_window reference
    that sums the full 2-D mask."""
    x = _map((1, h, w), jnp.float32, seed)
    valid_h = (jnp.arange(h) >= lo_h) & (jnp.arange(h) < h - hi_h)
    valid_w = (jnp.arange(w) >= lo_w) & (jnp.arange(w) < w - hi_w)
    from repro.core import spatial
    got = ops.masked_box_filter_2d(x, valid_h, r, valid_w, mode="interpret")
    want = spatial.masked_box_filter_2d(x, valid_h, r, valid_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
