"""Measured-search autotuner + device-kind-keyed table tests.

Covers: the successive-halving search (same winner as the exhaustive
sweep at strictly fewer timed runs, on a deterministic fake timer),
schema-2 persistence (device-kind keying, provenance, legacy-v1
migration), the layered ``get_params`` resolution, ``_TABLE_CACHE``
invalidation (mtime bump, path switch mid-process), the
``REPRO_TUNE_REQUIRE_TABLE`` knob, and ``validate_table``.
"""
import json
import os

import pytest

from repro.kernels import tuning
from repro.kernels.tuning import (AutotuneError, TuneStats, autotune,
                                  measured_search)


class FakeBench:
    """Deterministic virtual-time benchmark: candidate ``x`` costs
    ``costs[x]`` virtual seconds per run, the timer reads the virtual
    clock — so ``_time_callable`` measures each candidate's cost exactly,
    independent of the timing iteration count (fidelity-stable ranking,
    the regime where the search provably returns the exhaustive winner).
    """

    def __init__(self, costs, fail=()):
        self.costs = costs
        self.fail = set(fail)
        self.clock = 0.0
        self.runs = {}

    def timer(self):
        return self.clock

    def build(self, params):
        x = params["x"]
        if x in self.fail:
            raise ValueError(f"candidate {x} cannot build")

        def run():
            self.clock += self.costs[x]
            self.runs[x] = self.runs.get(x, 0) + 1
            return None
        return run


def _candidates(n):
    return [{"x": i} for i in range(n)]


@pytest.fixture()
def table_path(tmp_path, monkeypatch):
    p = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(p))
    return p


# ---------------------------------------------------------------------------
# The headline claim: same winner, strictly fewer timed runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,iters", [(9, 3), (12, 3), (2, 3), (3, 2),
                                     (18, 2), (7, 5)])
def test_search_matches_exhaustive_winner_at_fewer_runs(table_path, n, iters):
    costs = {i: 10.0 + ((i * 7) % n) for i in range(n)}   # distinct ranks
    ex = FakeBench(costs)
    ex_stats = TuneStats()
    ex_best = autotune("fused_dcp", (2, 8, 8), _candidates(n), ex.build,
                       iters=iters, persist=False, timer=ex.timer,
                       stats=ex_stats)
    se = FakeBench(costs)
    se_stats = TuneStats()
    se_best = measured_search("fused_dcp", (2, 8, 8), _candidates(n),
                              se.build, iters=iters, persist=False,
                              timer=se.timer, stats=se_stats)
    assert se_best == ex_best
    assert ex_stats.timed_runs == n * iters == se_stats.exhaustive_runs
    assert se_stats.timed_runs < ex_stats.timed_runs


def test_search_tie_breaks_toward_earlier_candidate(table_path):
    costs = {0: 5.0, 1: 1.0, 2: 3.0, 3: 1.0}              # 1 and 3 tie
    ex, se = FakeBench(costs), FakeBench(costs)
    ex_best = autotune("fused_dcp", (2, 8, 8), _candidates(4), ex.build,
                       persist=False, timer=ex.timer)
    se_best = measured_search("fused_dcp", (2, 8, 8), _candidates(4),
                              se.build, persist=False, timer=se.timer)
    assert ex_best == se_best == {"x": 1}


def test_search_rejects_bad_fidelity_args(table_path):
    fb = FakeBench({0: 1.0})
    with pytest.raises(ValueError):
        measured_search("fused_dcp", (2, 8, 8), _candidates(1), fb.build,
                        iters=0, timer=fb.timer)
    with pytest.raises(ValueError):
        measured_search("fused_dcp", (2, 8, 8), _candidates(1), fb.build,
                        eta=1, timer=fb.timer)


# ---------------------------------------------------------------------------
# Persistence: device-kind keying + provenance
# ---------------------------------------------------------------------------

def test_search_persists_device_kind_and_provenance(table_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", "TPU v5e")
    fb = FakeBench({0: 3.0, 1: 1.0, 2: 2.0}, fail=(2,))
    best = measured_search("fused_dcp", (2, 8, 8), _candidates(3), fb.build,
                           timer=fb.timer)
    assert best == {"x": 1}
    raw = json.loads(table_path.read_text())
    assert raw["schema"] == tuning.SCHEMA_VERSION
    entry = raw["device_kinds"]["TPU v5e"]["fused_dcp"]["2x8x8"]
    assert entry["params"] == {"x": 1}
    prov = entry["provenance"]
    assert prov["method"] == "successive_halving"
    assert prov["device_kind"] == "TPU v5e"
    assert prov["considered"] == 3
    assert prov["skipped"] == {"ValueError": 1}
    assert prov["iters"] >= 1 and prov["time_us"] >= 0
    # ...and the same process resolves it back (device kind still TPU v5e).
    assert tuning.get_params("fused_dcp", (2, 8, 8))["x"] == 1


def test_persist_keeps_other_device_kinds(table_path, monkeypatch):
    for kind, costs in [("kindA", {0: 1.0, 1: 2.0}),
                        ("kindB", {0: 2.0, 1: 1.0})]:
        monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", kind)
        fb = FakeBench(costs)
        measured_search("fused_dcp", (2, 8, 8), _candidates(2), fb.build,
                        timer=fb.timer)
    raw = json.loads(table_path.read_text())
    assert raw["device_kinds"]["kindA"]["fused_dcp"]["2x8x8"]["params"] \
        == {"x": 0}
    assert raw["device_kinds"]["kindB"]["fused_dcp"]["2x8x8"]["params"] \
        == {"x": 1}


def test_all_candidates_fail_raises_and_persists_nothing(table_path):
    fb = FakeBench({}, fail=(0, 1, 2))
    stats = TuneStats()
    with pytest.raises(AutotuneError, match="all 3 candidates"):
        measured_search("fused_dcp", (2, 8, 8), _candidates(3), fb.build,
                        timer=fb.timer, stats=stats)
    assert stats.skipped == {"ValueError": 3}
    assert not table_path.exists()


# ---------------------------------------------------------------------------
# get_params layering + legacy migration
# ---------------------------------------------------------------------------

def _write(path, table):
    path.write_text(json.dumps(table))


def test_get_params_layering_device_kind_over_legacy(table_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", "kindA")
    _write(table_path, {
        "schema": 2,
        "device_kinds": {
            "kindA": {"fused_dcp": {"2x8x8": {
                "params": {"frames_per_block": 4}, "provenance": {}}}},
            "kindB": {"fused_dcp": {"2x8x8": {
                "params": {"frames_per_block": 9}, "provenance": {}}}}},
        "legacy": {"fused_dcp": {"2x8x8": {"frames_per_block": 2,
                                           "buffer_depth": 3}}}})
    p = tuning.get_params("fused_dcp", (2, 8, 8))
    assert p["frames_per_block"] == 4          # kindA beats legacy & kindB
    assert p["buffer_depth"] == 3              # legacy fills unset keys
    monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", "kindC")
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 2


def test_get_params_dtype_tag_layers_within_kind(table_path, monkeypatch):
    import jax.numpy as jnp
    monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", "kindA")
    _write(table_path, {
        "schema": 2,
        "device_kinds": {"kindA": {"fused_dcp": {
            "2x8x8": {"params": {"frames_per_block": 2}, "provenance": {}},
            "2x8x8xu8": {"params": {"frames_per_block": 8},
                         "provenance": {}}}}},
        "legacy": {}})
    assert tuning.get_params("fused_dcp", (2, 8, 8),
                             dtype=jnp.float32)["frames_per_block"] == 2
    assert tuning.get_params("fused_dcp", (2, 8, 8),
                             dtype=jnp.uint8)["frames_per_block"] == 8


def test_legacy_v1_table_still_loads(table_path):
    _write(table_path, {"fused_dcp": {"2x8x8": {"frames_per_block": 7}}})
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 7


def test_env_override_beats_every_table_layer(table_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DEVICE_KIND", "kindA")
    _write(table_path, {
        "schema": 2,
        "device_kinds": {"kindA": {"fused_dcp": {"2x8x8": {
            "params": {"frames_per_block": 4}, "provenance": {}}}}},
        "legacy": {}})
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 16}')
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 16


def test_migrate_table_moves_v1_ops_to_legacy(table_path):
    v1 = {"fused_dcp": {"2x8x8": {"frames_per_block": 7}}}
    m = tuning.migrate_table(v1)
    assert m["schema"] == tuning.SCHEMA_VERSION
    assert m["legacy"] == v1 and m["device_kinds"] == {}
    assert tuning.migrate_table(m) is m        # idempotent on schema-2
    # Persisting a measured winner migrates the on-disk v1 table in place.
    _write(table_path, v1)
    fb = FakeBench({0: 1.0})
    measured_search("fused_cap", (2, 8, 8), _candidates(1), fb.build,
                    timer=fb.timer)
    raw = json.loads(table_path.read_text())
    assert raw["schema"] == tuning.SCHEMA_VERSION
    assert raw["legacy"]["fused_dcp"]["2x8x8"]["frames_per_block"] == 7
    # ...and both layers resolve afterwards.
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 7
    assert tuning.get_params("fused_cap", (2, 8, 8))["x"] == 0


# ---------------------------------------------------------------------------
# _TABLE_CACHE invalidation
# ---------------------------------------------------------------------------

def test_table_cache_invalidates_on_mtime_bump(table_path):
    _write(table_path, {"fused_dcp": {"2x8x8": {"frames_per_block": 1}}})
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 1
    _write(table_path, {"fused_dcp": {"2x8x8": {"frames_per_block": 5}}})
    st = os.stat(table_path)
    os.utime(table_path, ns=(st.st_atime_ns, st.st_mtime_ns + 10_000_000))
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 5


def test_table_cache_path_switch_mid_process(tmp_path, monkeypatch):
    p1, p2 = tmp_path / "t1.json", tmp_path / "t2.json"
    _write(p1, {"fused_dcp": {"2x8x8": {"frames_per_block": 3}}})
    _write(p2, {"fused_dcp": {"2x8x8": {"frames_per_block": 6}}})
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(p1))
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 3
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(p2))
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 6
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(p1))
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 3


def test_save_table_refreshes_cache_same_process(table_path):
    tuning.save_table({"fused_dcp": {"2x8x8": {"frames_per_block": 2}}})
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 2
    tuning.save_table({"fused_dcp": {"2x8x8": {"frames_per_block": 4}}})
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 4


# ---------------------------------------------------------------------------
# REPRO_TUNE_REQUIRE_TABLE
# ---------------------------------------------------------------------------

def test_require_table_raises_on_default_resolution(table_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_REQUIRE_TABLE", "1")
    with pytest.raises(AutotuneError, match="REPRO_TUNE_REQUIRE_TABLE"):
        tuning.get_params("fused_dcp", (2, 8, 8))
    # A table entry satisfies it...
    _write(table_path, {"fused_dcp": {"2x8x8": {"frames_per_block": 2}}})
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] == 2
    # ...and so does an env override for an uncovered op.
    monkeypatch.setenv("REPRO_TUNE_FUSED_CAP", '{"frames_per_block": 2}')
    assert tuning.get_params("fused_cap", (2, 8, 8))["frames_per_block"] == 2
    with pytest.raises(AutotuneError):
        tuning.get_params("fused_halo_2d", (2, 8, 8))


# ---------------------------------------------------------------------------
# validate_table
# ---------------------------------------------------------------------------

def _valid_entry():
    return {"params": {"frames_per_block": 2},
            "provenance": {"time_us": 1.0, "iters": 3, "considered": 4,
                           "skipped": {}, "method": "successive_halving"}}


def test_validate_table_accepts_generated_schema(table_path):
    fb = FakeBench({0: 2.0, 1: 1.0})
    measured_search("fused_dcp", (2, 8, 8), _candidates(2), fb.build,
                    timer=fb.timer)
    assert tuning.validate_table(tuning.load_table()) == []


def test_validate_table_flags_defects():
    assert tuning.validate_table({}) == ["table is empty or unreadable"]
    errs = tuning.validate_table(
        {"fused_dcp": {"2x8x8": {"frames_per_block": 1}}})
    assert any("schema" in e for e in errs)
    errs = tuning.validate_table({
        "schema": 2,
        "device_kinds": {"cpu": {
            "no_such_op": {"2x8x8": _valid_entry()},
            "fused_dcp": {"bad bucket!": _valid_entry(),
                          "2x8x8": {"params": {"frames_per_block": 1},
                                    "provenance": {"time_us": 1.0}},
                          "4x8x8": {"frames_per_block": 1}}}},
        "legacy": {}})
    joined = "\n".join(errs)
    assert "unknown op" in joined
    assert "malformed bucket key" in joined
    assert "provenance lacks" in joined
    assert "must wrap a params dict" in joined


# ---------------------------------------------------------------------------
# Driver smoke against real kernels (tiny shapes)
# ---------------------------------------------------------------------------

def test_driver_smoke_persists_measured_entry(table_path):
    stats = TuneStats()
    out = tuning.autotune_fused(shapes=((2, 8, 8),), candidates=(1, 2),
                                depths=(1,), io_dtypes=("float32",),
                                algorithms=("dcp",), topks=(1,), iters=2,
                                method="search", stats=stats)
    assert out["fused_dcp"]["2x8x8"]["frames_per_block"] in (1, 2)
    assert stats.timed_runs < stats.exhaustive_runs or \
        stats.exhaustive_runs <= 2   # single-survivor edge: still cheaper
    raw = json.loads(table_path.read_text())
    entry = raw["device_kinds"][tuning.device_kind()]["fused_dcp"]["2x8x8"]
    assert entry["provenance"]["method"] == "successive_halving"
    # The dispatch path resolves the measured winner end-to-end.
    assert tuning.get_params("fused_dcp", (2, 8, 8))["frames_per_block"] \
        == entry["params"]["frames_per_block"]
