"""Registry + cell-table invariants: 40 assigned cells, documented skips,
exact assigned configurations."""
import pytest

from repro import configs as cfgreg


def test_forty_assigned_cells():
    cells = cfgreg.all_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert archs == set(cfgreg.ASSIGNED_ARCHS)


def test_documented_skips_are_exactly_three():
    skips = [(a, s) for a, s in cfgreg.all_cells()
             if cfgreg.cell_skip_reason(a, s)]
    assert sorted(skips) == [
        ("granite-20b", "long_500k"),
        ("llama3-8b", "long_500k"),
        ("moonshot-v1-16b-a3b", "long_500k"),
    ]


def test_llama4_long500k_runs():
    assert cfgreg.cell_skip_reason("llama4-scout-17b-a16e", "long_500k") is None


@pytest.mark.parametrize("arch", list(cfgreg.ASSIGNED_ARCHS)
                         + ["dehaze-dcp", "dehaze-cap"])
def test_every_arch_has_config_and_smoke(arch):
    mod = cfgreg.get_module(arch)
    assert mod.ARCH_ID == arch
    cfg = mod.config()
    smoke = mod.smoke_config()
    assert cfg is not None and smoke is not None


def test_exact_assigned_configs():
    """Spot-check the published numbers (the assignment block verbatim)."""
    c = cfgreg.get_module("moonshot-v1-16b-a3b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.moe_experts, c.moe_topk) == \
        (48, 2048, 16, 16, 1408, 163840, 64, 6)
    c = cfgreg.get_module("llama4-scout-17b-a16e").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.moe_experts, c.moe_topk) == \
        (48, 5120, 40, 8, 8192, 202048, 16, 1)
    c = cfgreg.get_module("granite-20b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (52, 6144, 48, 1, 24576, 49152)
    c = cfgreg.get_module("llama3-8b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 14336, 128256)
    c = cfgreg.get_module("dit-l2").config()
    assert (c.img_res, c.patch, c.n_layers, c.d_model, c.n_heads) == \
        (256, 2, 24, 1024, 16)
    c = cfgreg.get_module("unet-sdxl").config()
    assert (c.img_res, c.ch, c.ch_mult, c.n_res_blocks, c.transformer_depth,
            c.ctx_dim) == (1024, 320, (1, 2, 4), 2, (1, 2, 10), 2048)
    c = cfgreg.get_module("vit-l16").config()
    assert (c.img_res, c.patch, c.n_layers, c.d_model, c.n_heads, c.d_ff) \
        == (224, 16, 24, 1024, 16, 4096)
    c = cfgreg.get_module("efficientnet-b7").config()
    assert (c.img_res, c.width_mult, c.depth_mult) == (600, 2.0, 3.1)
    c = cfgreg.get_module("resnet-50").config()
    assert (c.img_res, c.depths, c.width) == (224, (3, 4, 6, 3), 64)
    c = cfgreg.get_module("convnext-b").config()
    assert (c.img_res, c.depths, c.dims) == \
        (224, (3, 3, 27, 3), (128, 256, 512, 1024))


def test_lm_param_counts_consistent_with_assigned_configs():
    """Param-count arithmetic of the assigned configs (note: the assigned
    granite/moonshot configs compute to ~28B — we implement the assignment
    verbatim, not the marketing name)."""
    for arch, lo, hi in [("llama3-8b", 7.5e9, 8.5e9),
                         ("granite-20b", 26e9, 30e9),
                         ("moonshot-v1-16b-a3b", 26e9, 30e9),
                         ("llama4-scout-17b-a16e", 95e9, 110e9)]:
        n = cfgreg.get_module(arch).config().param_count()
        assert lo < n < hi, (arch, n)


def test_active_params_moe():
    c = cfgreg.get_module("moonshot-v1-16b-a3b").config()
    n_act = c.active_param_count()
    # ~3B-class active (name says a3b; active incl. embeddings)
    assert 1.5e9 < n_act < 4.5e9, n_act


def test_head_dims_all_128():
    for arch in ("moonshot-v1-16b-a3b", "llama4-scout-17b-a16e",
                 "granite-20b", "llama3-8b"):
        c = cfgreg.get_module(arch).config()
        assert c.head_dim == 128
        assert c.d_model == c.n_heads * 128


def test_shapes_tables():
    assert set(cfgreg.LM_SHAPES) == {"train_4k", "prefill_32k",
                                     "decode_32k", "long_500k"}
    assert set(cfgreg.DIFFUSION_SHAPES) == {"train_256", "gen_1024",
                                            "gen_fast", "train_1024"}
    assert set(cfgreg.VISION_SHAPES) == {"cls_224", "cls_384",
                                         "serve_b1", "serve_b128"}
