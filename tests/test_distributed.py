"""Multi-device semantics, validated in subprocesses with 8 host devices.

conftest must NOT set --xla_force_host_platform_device_count globally (the
smoke tests need the real single device), so every test here launches a
fresh python with the flag and asserts inside the child.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(body: str, devices: int = 8) -> None:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(REPO_SRC))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"


def test_sharded_dehaze_matches_single_device():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        from repro.core.physics import synthesize_haze, transmission_from_depth
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        B, H, W = 4, 64, 48
        J = jnp.asarray(rng.random((B, H, W, 3), np.float32)) * 0.8
        t = transmission_from_depth(
            jnp.asarray(rng.random((B, H, W), np.float32)) * 2 + 0.2, 1.0)
        I = synthesize_haze(J, t, jnp.asarray([0.9, 0.85, 0.95]))
        ids = jnp.arange(B, dtype=jnp.int32)
        for algo in ("dcp", "cap"):
            cfg = DehazeConfig(algorithm=algo, kernel_mode="ref", gf_radius=8)
            ref = jax.jit(make_dehaze_step(cfg))(I, ids, init_atmo_state())
            step, _, _ = make_sharded_dehaze_step(cfg, mesh)
            with mesh:
                out = jax.jit(step)(I, ids, init_atmo_state())
            np.testing.assert_allclose(np.asarray(out.frames),
                                       np.asarray(ref.frames), atol=2e-5)
            np.testing.assert_allclose(np.asarray(out.transmission),
                                       np.asarray(ref.transmission), atol=2e-5)
            np.testing.assert_allclose(np.asarray(out.atmo_light),
                                       np.asarray(ref.atmo_light), atol=1e-5)
            np.testing.assert_allclose(np.asarray(out.state.A),
                                       np.asarray(ref.state.A), atol=1e-5)
        print("ok")
    """)


def test_sharded_dehaze_multihop_halo():
    """Halo larger than the per-shard height -> multi-hop ppermute path."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((1, 8), ("data", "model"))
        rng = np.random.default_rng(3)
        B, H, W = 2, 64, 32          # 8 rows/shard
        I = jnp.asarray(rng.random((B, H, W, 3), np.float32))
        ids = jnp.arange(B, dtype=jnp.int32)
        # patch 7 + 2*gf 12 = halo 31 -> 4 hops over 8-row shards
        cfg = DehazeConfig(algorithm="dcp", kernel_mode="ref",
                           patch_radius=7, gf_radius=12)
        ref = jax.jit(make_dehaze_step(cfg))(I, ids, init_atmo_state())
        step, _, _ = make_sharded_dehaze_step(cfg, mesh)
        with mesh:
            out = jax.jit(step)(I, ids, init_atmo_state())
        np.testing.assert_allclose(np.asarray(out.frames),
                                   np.asarray(ref.frames), atol=2e-5)
        print("ok")
    """)


def test_packed_halo_matches_rgb_halo():
    """Perf lever (EXPERIMENTS §Perf): exchanging the packed 2-channel
    (pre-map, guide) halo — optionally in bf16 — must match the full-RGB
    halo path within dtype tolerance."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        I = jnp.asarray(rng.random((4, 64, 48, 3), np.float32))
        ids = jnp.arange(4, dtype=jnp.int32)
        for algo in ("dcp", "cap"):
            base = DehazeConfig(algorithm=algo, kernel_mode="ref", gf_radius=8)
            ref = jax.jit(make_dehaze_step(base))(I, ids, init_atmo_state())
            for hdt, tol in (("float32", 3e-5), ("bfloat16", 2e-2)):
                cfg = DehazeConfig(algorithm=algo, kernel_mode="ref",
                                   gf_radius=8, halo_packed=True,
                                   halo_dtype=hdt)
                step, _, _ = make_sharded_dehaze_step(cfg, mesh)
                with mesh:
                    out = jax.jit(step)(I, ids, init_atmo_state())
                np.testing.assert_allclose(np.asarray(out.frames),
                                           np.asarray(ref.frames), atol=tol)
        print("ok")
    """)


def test_sharded_fused_halo_matches_staged_chain():
    """Height sharding (n_h > 1) keeps ``use_fused``: the halo-aware fused
    kernel (fed by the packed (pre-map, guide) exchange + row-validity
    masking) must match the single-device per-stage chain — including the
    mesh-edge shards — on both the XLA oracle and the interpreted kernel
    body. A spy asserts the fused halo op is actually what ran."""
    run_child("""
        import os
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        import repro.kernels.ops as kops

        mesh = compat.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        I = jnp.asarray(rng.random((4, 64, 48, 3), np.float32))
        ids = jnp.arange(4, dtype=jnp.int32)

        calls = []
        orig = kops.fused_transmission_halo
        def spy(*a, **k):
            calls.append(1)
            return orig(*a, **k)
        kops.fused_transmission_halo = spy

        for algo in ("dcp", "cap"):
            base = DehazeConfig(algorithm=algo, kernel_mode="ref",
                                gf_radius=8, update_period=2)
            want = jax.jit(make_dehaze_step(base))(I, ids, init_atmo_state())
            for env, packed in (("", False), ("", True), ("interpret", False)):
                if env:
                    os.environ["REPRO_KERNEL_MODE"] = env
                else:
                    os.environ.pop("REPRO_KERNEL_MODE", None)
                cfg = DehazeConfig(algorithm=algo, kernel_mode="fused",
                                   gf_radius=8, update_period=2,
                                   halo_packed=packed)
                n0 = len(calls)
                step, _, _ = make_sharded_dehaze_step(cfg, mesh)
                with mesh:
                    out = jax.jit(step)(I, ids, init_atmo_state())
                assert len(calls) > n0, "fused halo path was not taken"
                np.testing.assert_allclose(np.asarray(out.frames),
                                           np.asarray(want.frames), atol=1e-5)
                np.testing.assert_allclose(
                    np.asarray(out.transmission),
                    np.asarray(want.transmission), atol=1e-5)
                np.testing.assert_allclose(np.asarray(out.atmo_light),
                                           np.asarray(want.atmo_light),
                                           atol=1e-5)
                np.testing.assert_allclose(np.asarray(out.state.A),
                                           np.asarray(want.state.A),
                                           atol=1e-5)
        print("ok")
    """)


def test_sharded_fused_halo_multihop():
    """Fused halo path when the halo spans multiple shards (multi-hop
    ppermute) — the extended block is mostly neighbor rows."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((1, 8), ("data", "model"))
        rng = np.random.default_rng(3)
        I = jnp.asarray(rng.random((2, 64, 32, 3), np.float32))
        ids = jnp.arange(2, dtype=jnp.int32)
        # patch 7 + 2*gf 12 = halo 31 -> 4 hops over 8-row shards
        base = DehazeConfig(algorithm="dcp", kernel_mode="ref",
                            patch_radius=7, gf_radius=12)
        want = jax.jit(make_dehaze_step(base))(I, ids, init_atmo_state())
        cfg = DehazeConfig(algorithm="dcp", kernel_mode="fused",
                           patch_radius=7, gf_radius=12)
        step, _, _ = make_sharded_dehaze_step(cfg, mesh)
        with mesh:
            out = jax.jit(step)(I, ids, init_atmo_state())
        np.testing.assert_allclose(np.asarray(out.frames),
                                   np.asarray(want.frames), atol=1e-5)
        print("ok")
    """)


def test_moe_ep_matches_single_device():
    """Expert-parallel all-to-all MoE == single-device execution."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.models import transformer as T
        from repro.models import common as cm
        cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, vocab=64, moe_experts=8,
                         moe_topk=2, moe_capacity_factor=8.0,
                         dtype="float32", kv_block=16, remat=False)
        params = cm.init_params(jax.random.key(0), T.lm_param_table(cfg))
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0, 64)
        ref_logits, _ = jax.jit(T.make_forward(cfg))(params, toks)

        mesh = compat.make_mesh((2, 4), ("data", "model"))
        fwd = T.make_forward(cfg, mesh, ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        pspecs = cm.param_pspecs(T.lm_param_table(cfg), mesh=mesh)
        shard = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        with mesh:
            jf = jax.jit(fwd, in_shardings=(shard,
                         NamedSharding(mesh, P("data", None))))
            logits, _ = jf(params, toks)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits), atol=3e-4)
        print("ok")
    """)


def test_ema_state_sync_across_batches_sharded():
    """The EMA chain must continue across batches when frames are sharded
    over the data axis (collective state synchronization)."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, make_dehaze_step,
                                make_sharded_dehaze_step, init_atmo_state)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(5)
        cfg = DehazeConfig(kernel_mode="ref", gf_radius=4, update_period=3)
        step_ref = jax.jit(make_dehaze_step(cfg))
        step_sh, _, _ = make_sharded_dehaze_step(cfg, mesh)
        state_r = state_s = init_atmo_state()
        for chunk in range(3):
            I = jnp.asarray(rng.random((8, 32, 32, 3), np.float32))
            ids = jnp.arange(chunk * 8, chunk * 8 + 8, dtype=jnp.int32)
            out_r = step_ref(I, ids, state_r); state_r = out_r.state
            with mesh:
                out_s = jax.jit(step_sh)(I, ids, state_s); state_s = out_s.state
            np.testing.assert_allclose(np.asarray(out_s.atmo_light),
                                       np.asarray(out_r.atmo_light), atol=1e-5)
        assert int(state_s.last_update) == int(state_r.last_update)
        print("ok")
    """)


def test_seqpar_flash_decode_matches_standard():
    """Distributed flash-decoding (KV cache sequence-sharded over the
    model axis, pmax/psum softmax combine) == standard decode, for both
    full and chunked attention (EXPERIMENTS §Perf / long_500k)."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import transformer as T, common as cm
        for chunk in (0, 8):
            cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                             head_dim=8, d_ff=64, vocab=64, dtype="float32",
                             kv_block=16, remat=False, chunk_attn=chunk,
                             global_every=2)
            params = cm.init_params(jax.random.key(0), T.lm_param_table(cfg))
            toks = jax.random.randint(jax.random.key(1), (2, 17), 0, 64)
            pre = jax.jit(T.make_prefill(cfg, max_len=32))
            dec = jax.jit(T.make_decode_step(cfg))
            last, cache = pre(params, toks[:, :16])
            ref_lg, ref_cache = dec(params, cache, toks[:, 16:17])
            mesh = compat.make_mesh((2, 4), ("data", "model"))
            cfg2 = T.LMConfig(**{**cfg.__dict__, "decode_seq_shard": True})
            dec2 = T.make_decode_step(cfg2, mesh, ("data",))
            spec = {"k": P(None, "data", "model", None, None),
                    "v": P(None, "data", "model", None, None), "pos": P()}
            sc = jax.tree.map(lambda x, sp: jax.device_put(
                x, NamedSharding(mesh, sp)), cache, spec)
            with mesh:
                lg2, c2 = jax.jit(dec2)(params, sc, toks[:, 16:17])
            np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref_lg),
                                       atol=3e-4)
            np.testing.assert_allclose(np.asarray(c2["k"]),
                                       np.asarray(ref_cache["k"]), atol=1e-5)
        print("ok")
    """)


def test_seq_sharded_lm_forward_matches():
    """LM forward with batch+TP sharding == single device (numerics)."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.models import transformer as T
        from repro.models import common as cm
        from jax.sharding import NamedSharding, PartitionSpec as P
        cfg = T.LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                         head_dim=8, d_ff=64, vocab=64, dtype="float32",
                         kv_block=16, remat=False)
        params = cm.init_params(jax.random.key(0), T.lm_param_table(cfg))
        toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 64)
        ref, _ = jax.jit(T.make_forward(cfg))(params, toks)
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        pspecs = cm.param_pspecs(T.lm_param_table(cfg), mesh=mesh)
        shard = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        with mesh:
            jf = jax.jit(T.make_forward(cfg, mesh, ("data",)),
                         in_shardings=(shard, NamedSharding(mesh, P("data", None))))
            got, _ = jf(params, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-4)
        print("ok")
    """)


def test_batch_axes_for_pod_prefix():
    """Regression: the prefix walk must try ("pod", "data") THEN ("pod",) —
    the old loop built prefixes back-to-front and could never return the
    pod-only prefix, silently replicating pod-divisible batches."""
    from repro.launch.mesh import batch_axes_for

    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 3, "model": 4}

    class DataMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    assert batch_axes_for(PodMesh(), 6) == ("pod", "data")
    assert batch_axes_for(PodMesh(), 12) == ("pod", "data")
    assert batch_axes_for(PodMesh(), 4) == ("pod",)     # the fixed case
    assert batch_axes_for(PodMesh(), 2) == ("pod",)
    assert batch_axes_for(PodMesh(), 9) is None         # divides neither
    assert batch_axes_for(PodMesh(), 3) is None         # data alone: no prefix
    assert batch_axes_for(DataMesh(), 8) == ("data",)
    assert batch_axes_for(DataMesh(), 3) is None


def test_lane_sharded_step_matches_per_lane_single_device():
    """The tentpole composition: lanes sharded over the data axis (each
    lane's causal chain shard-local, EMA rows co-placed via P(lane_axis)),
    with and without simultaneous height-halo sharding (n_h 2) — both the
    lane-native/fused and the staged ref substrate must match L independent
    single-device ``make_dehaze_step`` chains."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import compat
        from repro.core import (DehazeConfig, PlacementSpec, make_step,
                                make_dehaze_step, init_atmo_state,
                                init_atmo_state_lanes, get_lane_state)
        mesh = compat.make_mesh((2, 2), ("data", "model"))
        rng = np.random.default_rng(7)
        L, B, H, W = 4, 3, 32, 32
        frames = jnp.asarray(rng.random((L, B, H, W, 3), np.float32))
        ids = jnp.tile(jnp.arange(B, dtype=jnp.int32), (L, 1))
        placements = [
            PlacementSpec.lane_sharded(lane_axis="data"),
            PlacementSpec.lane_sharded(lane_axis="data",
                                       height_axis="model"),   # n_h 2
        ]
        for mode, tol in (("ref", 3e-5), ("fused", 2e-4)):
            cfg = DehazeConfig(kernel_mode=mode, patch_radius=3,
                               gf_radius=4, update_period=2, topk=4)
            ref_step = jax.jit(make_dehaze_step(
                DehazeConfig(kernel_mode="ref", patch_radius=3,
                             gf_radius=4, update_period=2, topk=4)))
            refs = [ref_step(frames[l], ids[l], init_atmo_state())
                    for l in range(L)]
            for place in placements:
                step = make_step(cfg, place, mesh)
                with mesh:
                    out = jax.jit(step)(frames, ids,
                                        init_atmo_state_lanes(L))
                for l in range(L):
                    np.testing.assert_allclose(
                        np.asarray(out.frames[l]),
                        np.asarray(refs[l].frames), atol=tol)
                    np.testing.assert_allclose(
                        np.asarray(out.atmo_light[l]),
                        np.asarray(refs[l].atmo_light), atol=tol)
                    st = get_lane_state(out.state, l)
                    np.testing.assert_allclose(
                        np.asarray(st.A), np.asarray(refs[l].state.A),
                        atol=tol)
                    assert int(st.last_update) \\
                        == int(refs[l].state.last_update)
        print("ok")
    """, devices=4)
