"""The single env-knob surface (repro.core.env): typed accessors that
reject junk values loudly, plus snapshot/restore for test isolation.

Every ``REPRO_*`` read in the codebase goes through this module — a
regression test greps the source tree to keep it that way.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import env

SRC = Path(__file__).resolve().parent.parent / "src"


def test_kernel_mode(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    assert env.kernel_mode() == ""
    for v in env.KERNEL_MODES:
        monkeypatch.setenv("REPRO_KERNEL_MODE", v)
        assert env.kernel_mode() == v
    monkeypatch.setenv("REPRO_KERNEL_MODE", "tpu_magic")
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        env.kernel_mode()


def test_lane_native(monkeypatch):
    monkeypatch.delenv("REPRO_LANE_NATIVE", raising=False)
    assert env.lane_native() is None
    monkeypatch.setenv("REPRO_LANE_NATIVE", "1")
    assert env.lane_native() is True
    monkeypatch.setenv("REPRO_LANE_NATIVE", "0")
    assert env.lane_native() is False
    monkeypatch.setenv("REPRO_LANE_NATIVE", "yes")
    with pytest.raises(ValueError, match="REPRO_LANE_NATIVE"):
        env.lane_native()


def test_tick_overlap(monkeypatch):
    monkeypatch.delenv("REPRO_TICK_OVERLAP", raising=False)
    assert env.tick_overlap() is None
    monkeypatch.setenv("REPRO_TICK_OVERLAP", "1")
    assert env.tick_overlap() is True
    monkeypatch.setenv("REPRO_TICK_OVERLAP", "0")
    assert env.tick_overlap() is False
    monkeypatch.setenv("REPRO_TICK_OVERLAP", "on")
    with pytest.raises(ValueError, match="REPRO_TICK_OVERLAP"):
        env.tick_overlap()


def test_step_cache_size(monkeypatch):
    monkeypatch.delenv("REPRO_STEP_CACHE_SIZE", raising=False)
    assert env.step_cache_size() == 8
    assert env.step_cache_size(default=3) == 3
    monkeypatch.setenv("REPRO_STEP_CACHE_SIZE", "16")
    assert env.step_cache_size() == 16
    for bad in ("zero", "0", "-2"):
        monkeypatch.setenv("REPRO_STEP_CACHE_SIZE", bad)
        with pytest.raises(ValueError, match="REPRO_STEP_CACHE_SIZE"):
            env.step_cache_size()


def test_tuning_table_path(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_KERNEL_TUNING", raising=False)
    assert env.tuning_table_path().name == "kernel_tuning.json"
    monkeypatch.setenv("REPRO_KERNEL_TUNING", str(tmp_path / "t.json"))
    assert env.tuning_table_path() == tmp_path / "t.json"


def test_tune_override_ignores_malformed_json(monkeypatch):
    """The one deliberate exception to raise-on-junk: a tuning override is
    a performance hint, and a typo in it must never take serving down."""
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '{"frames_per_block": 4}')
    assert env.tune_override("fused_dcp") == {"frames_per_block": 4}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", "not json")
    assert env.tune_override("fused_dcp") == {}
    monkeypatch.setenv("REPRO_TUNE_FUSED_DCP", '["a", "list"]')
    assert env.tune_override("fused_dcp") == {}
    monkeypatch.delenv("REPRO_TUNE_FUSED_DCP")
    assert env.tune_override("fused_dcp") == {}


def test_bench_smoke(monkeypatch):
    monkeypatch.delenv("REPRO_BENCH_SMOKE", raising=False)
    assert env.bench_smoke() is False
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    assert env.bench_smoke() is True
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "")
    assert env.bench_smoke() is False


def test_snapshot_restore(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    monkeypatch.setenv("REPRO_STEP_CACHE_SIZE", "4")
    snap = env.snapshot()
    assert snap["REPRO_KERNEL_MODE"] == "ref"
    monkeypatch.setenv("REPRO_KERNEL_MODE", "pallas")
    monkeypatch.delenv("REPRO_STEP_CACHE_SIZE")
    monkeypatch.setenv("REPRO_LANE_NATIVE", "1")       # not in the snapshot
    env.restore(snap)
    assert env.kernel_mode() == "ref"
    assert env.step_cache_size() == 4
    assert env.lane_native() is None                   # stray var removed


def test_no_environ_reads_outside_env_module():
    """Satellite guarantee: ``os.environ`` access for REPRO_* knobs lives
    only in repro/core/env.py (non-knob uses like the dry-run's XLA_FLAGS
    export are fine)."""
    hits = subprocess.run(
        ["grep", "-rn", "environ", str(SRC / "repro")],
        capture_output=True, text=True).stdout.splitlines()
    offenders = [h for h in hits
                 if "core/env.py" not in h.split(":", 1)[0]
                 and "REPRO_" in h]
    assert offenders == [], f"REPRO_* environ reads outside env.py: {offenders}"


def test_benchmarks_use_env_module():
    for bench in ("kernels_bench.py", "table1_throughput.py"):
        text = (SRC.parent / "benchmarks" / bench).read_text()
        assert "environ" not in text, f"{bench} bypasses repro.core.env"
