"""llama3-8b [dense] — GQA, 128k vocab.

[arXiv:2407.21783; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.models.transformer import LMConfig

FAMILY = "lm"
ARCH_ID = "llama3-8b"


def config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=14336, vocab=128256, **kw)


def smoke_config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=128, dtype="float32",
        kv_block=32, remat=False, **kw)
