"""dehaze-cap — the paper's own pipeline with the CAP T-estimator.

Zhu et al. color attenuation prior [23] projected onto the component
framework (paper §3.1), with the §3.3 update strategy.
"""
from repro.core import DehazeConfig

FAMILY = "dehaze"
ARCH_ID = "dehaze-cap"


def config(**kw) -> DehazeConfig:
    return DehazeConfig(algorithm="cap", **kw)


def smoke_config(**kw) -> DehazeConfig:
    return DehazeConfig(algorithm="cap", gf_radius=4, kernel_mode="ref", **kw)
