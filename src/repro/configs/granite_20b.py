"""granite-20b [dense] — llama-arch code model, MQA (kv=1).

[arXiv:2405.04324; hf]
52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.models.transformer import LMConfig

FAMILY = "lm"
ARCH_ID = "granite-20b"


def config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        head_dim=128, d_ff=24576, vocab=49152, **kw)


def smoke_config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=1, head_dim=8, d_ff=128, vocab=128, dtype="float32",
        kv_block=32, remat=False, **kw)
