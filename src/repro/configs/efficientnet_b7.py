"""efficientnet-b7 [vision] — compound-scaled MBConv network.

[arXiv:1905.11946; paper]
img_res=600 width_mult=2.0 depth_mult=3.1.
"""
from repro.models.efficientnet import EfficientNetConfig

FAMILY = "vision"
ARCH_ID = "efficientnet-b7"


def config(**kw) -> EfficientNetConfig:
    return EfficientNetConfig(name=ARCH_ID, img_res=600, width_mult=2.0,
                              depth_mult=3.1, **kw)


def smoke_config(**kw) -> EfficientNetConfig:
    return EfficientNetConfig(name=ARCH_ID + "-smoke", img_res=32,
                              width_mult=0.35, depth_mult=0.35, **kw)
