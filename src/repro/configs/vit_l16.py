"""vit-l16 [vision] — ViT-L/16 classifier.

[arXiv:2010.11929; paper]
img_res=224 patch=16 n_layers=24 d_model=1024 n_heads=16 d_ff=4096.
"""
from repro.models.vit import ViTConfig

FAMILY = "vision"
ARCH_ID = "vit-l16"


def config(**kw) -> ViTConfig:
    return ViTConfig(name=ARCH_ID, img_res=224, patch=16, n_layers=24,
                     d_model=1024, n_heads=16, d_ff=4096, **kw)


def smoke_config(**kw) -> ViTConfig:
    return ViTConfig(name=ARCH_ID + "-smoke", img_res=32, patch=8,
                     n_layers=2, d_model=64, n_heads=4, d_ff=128,
                     n_classes=16, dtype="float32", remat=False, **kw)
