"""dit-l2 [diffusion] — DiT-L/2 latent diffusion transformer.

[arXiv:2212.09748; paper]
img_res=256 patch=2 n_layers=24 d_model=1024 n_heads=16.
"""
from repro.models.dit import DiTConfig

FAMILY = "diffusion"
ARCH_ID = "dit-l2"


def config(**kw) -> DiTConfig:
    return DiTConfig(name=ARCH_ID, img_res=256, patch=2, n_layers=24,
                     d_model=1024, n_heads=16, **kw)


def smoke_config(**kw) -> DiTConfig:
    return DiTConfig(name=ARCH_ID + "-smoke", img_res=32, patch=2,
                     n_layers=2, d_model=64, n_heads=4, n_classes=16,
                     dtype="float32", remat=False, **kw)
