"""Architecture registry: ``--arch <id>`` resolution + shape-cell table.

Every assigned architecture is a module exposing ``ARCH_ID``, ``FAMILY``,
``config()`` (the exact published configuration) and ``smoke_config()``
(a reduced same-family configuration for CPU tests). The shape sets below
are the assigned input-shape cells per family; ``CELLS`` enumerates all
(arch x shape) pairs including documented skips (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs import (convnext_b, dehaze_cap, dehaze_dcp, dit_l2,
                           efficientnet_b7, granite_20b, llama3_8b,
                           llama4_scout_17b_a16e, moonshot_v1_16b_a3b,
                           resnet_50, unet_sdxl, vit_l16)

ARCH_MODULES = {
    m.ARCH_ID: m for m in [
        moonshot_v1_16b_a3b, llama4_scout_17b_a16e, granite_20b, llama3_8b,
        dit_l2, unet_sdxl,
        vit_l16, efficientnet_b7, resnet_50, convnext_b,
        dehaze_dcp, dehaze_cap,
    ]
}

ASSIGNED_ARCHS: Tuple[str, ...] = (
    "moonshot-v1-16b-a3b", "llama4-scout-17b-a16e", "granite-20b",
    "llama3-8b", "dit-l2", "unet-sdxl", "vit-l16", "efficientnet-b7",
    "resnet-50", "convnext-b")

# shape name -> dict of shape parameters (per family)
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1,
                      needs_subquadratic=True),
}
DIFFUSION_SHAPES = {
    "train_256": dict(kind="train", img_res=256, batch=256, steps=1000),
    "gen_1024": dict(kind="sample", img_res=1024, batch=4, steps=50),
    "gen_fast": dict(kind="sample", img_res=512, batch=16, steps=4),
    "train_1024": dict(kind="train", img_res=1024, batch=32, steps=1000),
}
VISION_SHAPES = {
    "cls_224": dict(kind="train", img_res=224, batch=256),
    "cls_384": dict(kind="train", img_res=384, batch=64),
    "serve_b1": dict(kind="serve", img_res=224, batch=1),
    "serve_b128": dict(kind="serve", img_res=224, batch=128),
}
# The paper's own pipeline: shapes mirror Table 1's three resolutions plus
# a high-res stress shape for spatial parallelism (extra, beyond the 40).
DEHAZE_SHAPES = {
    "stream_240p": dict(kind="dehaze", height=240, width=320, batch=256),
    "stream_480p": dict(kind="dehaze", height=480, width=640, batch=256),
    "stream_576p": dict(kind="dehaze", height=576, width=1024, batch=128),
    "stream_2160p": dict(kind="dehaze", height=2160, width=3840, batch=32),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "diffusion": DIFFUSION_SHAPES,
    "vision": VISION_SHAPES,
    "dehaze": DEHAZE_SHAPES,
}

# Pure full-attention LM archs skip long_500k (documented; DESIGN.md §4).
SUBQUADRATIC_LMS = {"llama4-scout-17b-a16e"}


def get_module(arch_id: str):
    try:
        return ARCH_MODULES[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{sorted(ARCH_MODULES)}") from None


def shapes_for(arch_id: str) -> Dict[str, dict]:
    return FAMILY_SHAPES[get_module(arch_id).FAMILY]


def cell_skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    shape = shapes_for(arch_id)[shape_name]
    if shape.get("needs_subquadratic") and arch_id not in SUBQUADRATIC_LMS:
        return ("pure full-attention arch: long_500k requires "
                "sub-quadratic attention (DESIGN.md §4)")
    return None


def all_cells(include_dehaze: bool = False) -> List[Tuple[str, str]]:
    """The assigned 40 (arch x shape) cells, in registry order."""
    archs = list(ASSIGNED_ARCHS)
    if include_dehaze:
        archs += ["dehaze-dcp", "dehaze-cap"]
    return [(a, s) for a in archs for s in shapes_for(a)]
