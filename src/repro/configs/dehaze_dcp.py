"""dehaze-dcp — the paper's own pipeline with the DCP T-estimator.

He et al. dark channel prior [13] projected onto the component framework
(paper §3.1), with the §3.3 atmospheric-light update strategy.
"""
from repro.core import DehazeConfig

FAMILY = "dehaze"
ARCH_ID = "dehaze-dcp"


def config(**kw) -> DehazeConfig:
    return DehazeConfig(algorithm="dcp", **kw)


def smoke_config(**kw) -> DehazeConfig:
    return DehazeConfig(algorithm="dcp", gf_radius=4, kernel_mode="ref", **kw)
