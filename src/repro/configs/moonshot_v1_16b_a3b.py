"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.models.transformer import LMConfig

FAMILY = "lm"
ARCH_ID = "moonshot-v1-16b-a3b"


def config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1408, vocab=163840, moe_experts=64, moe_topk=6,
        **kw)


def smoke_config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab=128, moe_experts=8,
        moe_topk=2, dtype="float32", kv_block=32, remat=False, **kw)
