"""resnet-50 [vision] — bottleneck residual network.

[arXiv:1512.03385; paper]
img_res=224 depths=3-4-6-3 width=64 bottleneck expansion 4.
"""
from repro.models.resnet import ResNetConfig

FAMILY = "vision"
ARCH_ID = "resnet-50"


def config(**kw) -> ResNetConfig:
    return ResNetConfig(name=ARCH_ID, img_res=224, depths=(3, 4, 6, 3),
                        width=64, **kw)


def smoke_config(**kw) -> ResNetConfig:
    return ResNetConfig(name=ARCH_ID + "-smoke", img_res=32, depths=(2, 2),
                        width=8, n_classes=16, **kw)
