"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, chunked local attention.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
Chunked attention (8192-token chunks) with a RoPE-less global layer every
4th layer — this is what makes the long_500k cell sub-quadratic
(DESIGN.md §4). The assigned config has no shared expert; noted there.
"""
from repro.models.transformer import LMConfig

FAMILY = "lm"
ARCH_ID = "llama4-scout-17b-a16e"


def config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=202048, moe_experts=16, moe_topk=1,
        chunk_attn=8192, global_every=4, **kw)


def smoke_config(**kw) -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=32, vocab=128, moe_experts=4,
        moe_topk=1, chunk_attn=8, global_every=4, dtype="float32",
        kv_block=32, remat=False, **kw)
