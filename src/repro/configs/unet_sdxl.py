"""unet-sdxl [diffusion] — SDXL-class latent U-Net.

[arXiv:2307.01952; paper]
img_res=1024 latent_res=128 ch=320 ch_mult=1-2-4 n_res_blocks=2
transformer_depth=1-2-10 ctx_dim=2048.
"""
from repro.models.unet import UNetConfig

FAMILY = "diffusion"
ARCH_ID = "unet-sdxl"


def config(**kw) -> UNetConfig:
    base = dict(img_res=1024, ch=320, ch_mult=(1, 2, 4), n_res_blocks=2,
                transformer_depth=(1, 2, 10), ctx_dim=2048, ctx_len=77)
    base.update(kw)
    return UNetConfig(name=ARCH_ID, **base)


def smoke_config(**kw) -> UNetConfig:
    return UNetConfig(name=ARCH_ID + "-smoke", img_res=64, ch=16,
                      ch_mult=(1, 2), n_res_blocks=1,
                      transformer_depth=(1, 2), ctx_dim=32, ctx_len=7,
                      head_dim=8, dtype="float32", remat=False, **kw)
