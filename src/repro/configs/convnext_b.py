"""convnext-b [vision] — modernized convnet.

[arXiv:2201.03545; paper]
img_res=224 depths=3-3-27-3 dims=128-256-512-1024.
"""
from repro.models.convnext import ConvNeXtConfig

FAMILY = "vision"
ARCH_ID = "convnext-b"


def config(**kw) -> ConvNeXtConfig:
    return ConvNeXtConfig(name=ARCH_ID, img_res=224, depths=(3, 3, 27, 3),
                          dims=(128, 256, 512, 1024), **kw)


def smoke_config(**kw) -> ConvNeXtConfig:
    return ConvNeXtConfig(name=ARCH_ID + "-smoke", img_res=32,
                          depths=(2, 2), dims=(16, 32), n_classes=16,
                          dtype="float32", **kw)
