"""The spout (paper §3.2 layer 1): frame source, id assignment, batching."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

def _wire_frame(f) -> np.ndarray:
    """Keep native wire dtypes (README §Dtype contract) — uint8 is the
    round(v*255) quantized [0,1] image (4x less wire + HBM traffic than
    f32, upcast in-VMEM by the kernels), bfloat16/float32 pass through —
    and coerce everything else to float32."""
    arr = np.asarray(f)
    if arr.dtype == np.uint8 or arr.dtype == np.float32 \
            or arr.dtype.name == "bfloat16":
        return arr
    return arr.astype(np.float32)


@dataclasses.dataclass
class FrameBatch:
    frames: np.ndarray      # (B, H, W, 3) wire dtype: uint8 | bf16 | f32
    frame_ids: np.ndarray   # (B,) int32: consecutive ids, then -1 padding
    n_valid: int            # trailing frames may be padding on the last batch
    stream_id: str = "default"


class Spout:
    """Wraps an iterator of frames, assigns consecutive ids, emits batches.

    Frames keep their wire dtype end-to-end: uint8 / bfloat16 / float32
    pass through untouched (the device kernels upcast in-VMEM — a uint8
    camera feed stays 1 byte/channel from source to HBM), any other dtype
    is coerced to float32 here. The final partial batch is padded by
    repeating the last frame (dtype-matched by construction) so the jitted
    step always sees a static shape; ``n_valid`` tells the sink how many
    outputs are real. Padding slots carry ``frame_id = -1`` so the EMA
    scans mask them out — they must NOT get the future real ids the spout
    will later assign to real frames (that double-advanced the coherence
    state on duplicate frames).
    """

    def __init__(self, frames: Iterator[np.ndarray], batch: int,
                 start_frame: int = 0, stream_id: str = "default"):
        self._it = iter(frames)
        self._batch = batch
        self._next_id = start_frame
        self._stream_id = stream_id

    def __iter__(self) -> Iterator[FrameBatch]:
        buf = []
        for f in self._it:
            buf.append(_wire_frame(f))
            if len(buf) == self._batch:
                yield self._emit(buf)
                buf = []
        if buf:
            yield self._emit(buf)

    def _emit(self, buf) -> FrameBatch:
        n_valid = len(buf)
        while len(buf) < self._batch:
            buf.append(buf[-1])
        ids = np.full((self._batch,), -1, np.int32)
        ids[:n_valid] = np.arange(self._next_id, self._next_id + n_valid,
                                  dtype=np.int32)
        self._next_id += n_valid
        return FrameBatch(frames=np.stack(buf), frame_ids=ids,
                          n_valid=n_valid, stream_id=self._stream_id)
