"""Elastic lane autoscaling: a precompiled shape ladder with hysteresis.

The paper's cluster "automatically scales up and down based on the actual
workload" (§5). For the lane-batched serving runtime the unit of scale is
the *lane count* of the fixed-shape ``(L, B, H, W, 3)`` device batch — but
a new ``L`` is a new jitted program, and tracing it on the serve thread
stalls every live stream for the length of a compile. This module borrows
the elastic-network idiom (one max-capacity module, activate a sub-width
at runtime — see OFA's ``dynamic_layers``): a small *ladder* of lane
counts is precompiled through the bounded step cache, the scheduler walks
the ladder from pending-queue depth and lane occupancy, and the other
rungs are warmed on a background thread, so a ladder switch on the serve
thread is a dictionary lookup — never a trace.

Thrash control is hysteresis with distinct up/down conditions plus dwell
counts:

  * grow   — every lane occupied AND ≥ ``grow_pending`` streams queued,
             sustained ``dwell_up`` consecutive ticks;
  * shrink — zero streams queued AND occupancy fits the next rung down,
             sustained ``dwell_down`` consecutive ticks.

A load level that satisfies neither (e.g. all lanes busy, empty queue)
holds the current rung, and the asymmetric dwells bias toward capacity:
growing is cheap (idle padding lanes), shrinking too eagerly queues real
frames. A target rung that has not finished warming simply defers the
switch — the dwell state persists, and the switch lands on the first tick
the rung is ready.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.normalize import init_atmo_state_lanes

logger = logging.getLogger(__name__)

DEFAULT_RUNGS = (4, 8, 16, 32)

# A rung's warm-up is retried at most once (a transient allocator hiccup
# deserves a second chance; a rung whose compile genuinely OOMs should
# stop burning background compile time and be counted as failed).
WARM_MAX_ATTEMPTS = 2


@dataclasses.dataclass(frozen=True)
class ScalePolicy:
    """Ladder + hysteresis + eviction knobs for ``serve_many`` autoscaling.

    ``rungs`` is the lane-count ladder (capped by the serve call's
    ``n_lanes`` — see :func:`ladder_rungs`). ``evict_tardy_after`` is the
    deadline-aware eviction dial: a stream that is past its deadline and
    has held a lane for that many ticks while other streams queue is
    checkpointed (cursor + EMA state) and requeued as deadline-less;
    ``None`` disables eviction.
    """
    rungs: Tuple[int, ...] = DEFAULT_RUNGS
    grow_pending: int = 1       # queued streams that constitute load
    dwell_up: int = 2           # consecutive ticks of load before growing
    dwell_down: int = 4         # consecutive ticks of slack before shrinking
    evict_tardy_after: Optional[int] = 8


def ladder_rungs(rungs: Sequence[int], max_lanes: int) -> Tuple[int, ...]:
    """The ladder actually compiled: every rung below ``max_lanes`` plus
    ``max_lanes`` itself (the cap is always reachable, and a cap below the
    smallest rung degenerates to a single-rung ladder)."""
    if max_lanes < 1:
        raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
    kept = sorted({int(r) for r in rungs if 0 < r < max_lanes})
    return tuple(kept) + (max_lanes,)


class LaneAutoscaler:
    """Walks a precompiled ladder of lane counts for the scheduler.

    ``step_factory(n_lanes)`` returns the jitted multi-stream step for a
    rung (typically the bounded step cache in ``stream.elastic``). The
    scheduler calls :meth:`observe` once per tick with the pending-queue
    depth and lane occupancy; a non-``None`` return is a rung the
    scheduler may switch to *right now* — it is already warm, so
    :meth:`step_for` is a dictionary lookup. :meth:`commit` records the
    switch and resets the hysteresis state.

    Warming = actually *calling* the rung's step once with an all-padding
    lane batch (``frame_id = -1`` everywhere, which the masked EMA paths
    treat as identity), on a background thread: that populates the jit
    executable cache for the exact serving avals, so the serve thread's
    first real call at the new rung is a cache hit, not a trace. On the
    overlapped tick path ``step_factory`` hands out
    ``stream.iobuf.LaneTickStep`` adapters instead of raw steps; the same
    warm-up call then also pre-binds the rung's device-resident donated
    frame buffer and primes the lane-splice executable (the adapter's
    ``__call__`` is the full-batch compatibility path), with no autoscaler
    changes — which is why warming stays a plain step call here.
    """

    def __init__(self, step_factory: Callable[[int], Callable],
                 rungs: Sequence[int],
                 policy: ScalePolicy = ScalePolicy(),
                 state_factory: Callable[[int], Any] = init_atmo_state_lanes):
        if not rungs:
            raise ValueError("autoscale ladder must have at least one rung")
        self.rungs = tuple(sorted(set(int(r) for r in rungs)))
        if self.rungs[0] < 1:
            raise ValueError(f"lane rungs must be >= 1, got {self.rungs}")
        self.policy = policy
        self._step_factory = step_factory
        self._state_factory = state_factory
        self._idx = 0
        self._steps: Dict[int, Callable] = {}
        self._ready: set = set()
        self._lock = threading.Lock()
        self._warm_thread: Optional[threading.Thread] = None
        self._warm_errors: Dict[int, Exception] = {}
        self._warm_attempts: Dict[int, int] = {}
        # Rungs with a warm-up attempt currently executing. _retry_warm
        # must never start a second concurrent attempt for a rung: with
        # stateful tick adapters (stream.iobuf.LaneTickStep) two threads
        # warming ONE rung share its device buffer and race the donated
        # splice — one ends up passing an already-donated buffer.
        self._warming: set = set()
        self._warm_shape: Optional[Tuple[Tuple[int, ...], Any]] = None
        self._retry_threads: List[threading.Thread] = []
        self._up = 0
        self._down = 0
        # One record per committed switch: {"from", "to", "wall_s"}.
        self.switches: List[Dict[str, Any]] = []

    # -- rungs -------------------------------------------------------------

    @property
    def rung(self) -> int:
        """The active lane count."""
        return self.rungs[self._idx]

    def acquire_initial(self) -> Callable:
        """The starting rung's step (built on the caller's thread — this
        is serve start-up, not a mid-serve switch)."""
        step = self._step_factory(self.rung)
        with self._lock:
            self._steps[self.rung] = step
            self._ready.add(self.rung)
        return step

    def step_for(self, rung: int) -> Callable:
        """Warm rung -> its step. A ``KeyError`` here means a caller tried
        to switch to a rung that never finished warming — :meth:`observe`
        never returns such a rung."""
        with self._lock:
            return self._steps[rung]

    def is_ready(self, rung: int) -> bool:
        with self._lock:
            return rung in self._ready

    # -- warming -----------------------------------------------------------

    def ensure_warming(self, lane_batch_shape: Tuple[int, ...],
                       dtype=np.float32) -> None:
        """Start (once) the background thread that warms every other rung.

        ``lane_batch_shape`` is the per-lane ``(B, H, W, 3)`` batch shape —
        known at the first serve tick, which is when the scheduler calls
        this. ``dtype`` is the wire dtype of the frame stream: jit
        specializes on it, so warming must use the dtype the serve thread
        will actually feed (a uint8 stream warmed at f32 would re-trace on
        the first real batch). A rung whose warm-up raises is logged and
        retried once (lazily, the first time :meth:`observe` wants it);
        after :data:`WARM_MAX_ATTEMPTS` failures it is never offered and
        counts toward :attr:`warm_failures`."""
        with self._lock:
            if self._warm_thread is not None:
                return
            self._warm_shape = (tuple(lane_batch_shape), np.dtype(dtype))
            todo = [r for r in self.rungs if r not in self._ready]
            self._warm_thread = threading.Thread(
                target=self._warm,
                args=(tuple(lane_batch_shape), np.dtype(dtype), todo),
                daemon=True, name="lane-ladder-warm")
        self._warm_thread.start()

    def _warm(self, shape: Tuple[int, ...], dtype,
              todo: Sequence[int]) -> None:
        b, h, w, c = shape
        for rung in todo:
            with self._lock:
                if rung in self._warming or rung in self._ready:
                    continue        # another thread already owns this rung
                self._warming.add(rung)
                self._warm_attempts[rung] = \
                    self._warm_attempts.get(rung, 0) + 1
            try:
                step = self._step_factory(rung)
                frames = np.zeros((rung, b, h, w, c), dtype)
                ids = np.full((rung, b), -1, np.int32)
                out = step(frames, ids, self._state_factory(rung))
                jax.block_until_ready(out.state)
                with self._lock:
                    self._steps[rung] = step
                    self._ready.add(rung)
                    self._warm_errors.pop(rung, None)
            except Exception as e:
                with self._lock:
                    self._warm_errors[rung] = e
                    attempt = self._warm_attempts[rung]
                logger.warning(
                    "lane-ladder warm-up failed for rung %d (attempt %d/%d):"
                    " %s: %s", rung, attempt, WARM_MAX_ATTEMPTS,
                    type(e).__name__, e)
            finally:
                with self._lock:
                    self._warming.discard(rung)

    def _retry_warm(self, rung: int) -> None:
        """Kick one background re-warm of a failed rung (at most once —
        see :data:`WARM_MAX_ATTEMPTS`). Called from :meth:`observe` when
        the ladder wants a rung whose first warm-up raised."""
        with self._lock:
            if self._warm_shape is None \
                    or self._warm_attempts.get(rung, 0) >= WARM_MAX_ATTEMPTS \
                    or rung in self._ready \
                    or rung in self._warming:
                return
            shape, dtype = self._warm_shape
            th = threading.Thread(target=self._warm,
                                  args=(shape, dtype, [rung]),
                                  daemon=True, name=f"lane-warm-retry-{rung}")
            self._retry_threads.append(th)
        th.start()

    @property
    def warm_errors(self) -> Dict[int, Exception]:
        """Rung -> the exception its most recent warm-up attempt raised
        (rungs that later warmed successfully are removed)."""
        with self._lock:
            return dict(self._warm_errors)

    @property
    def warm_failures(self) -> int:
        """Rungs whose latest warm-up attempt failed (and that are hence
        not offerable) — surfaced on ``ServeReport.warm_failures`` so a
        serve that *expected* ladder switches can fail loudly instead of
        silently never scaling. A successful retry clears the rung."""
        with self._lock:
            return len(self._warm_errors)

    def wait_warm(self, timeout: Optional[float] = None,
                  raise_on_error: bool = False) -> bool:
        """Block until warm/retry threads finish (tests/benchmarks).

        With ``raise_on_error`` the recorded warm errors are re-raised
        (first one, chained) instead of staying buried on the background
        thread — the pre-fix behavior was that a rung whose warm-up
        failed was silently never offered."""
        th = self._warm_thread
        done = True
        if th is not None:
            th.join(timeout=timeout)
            done = not th.is_alive()
        with self._lock:
            retries = list(self._retry_threads)
        for th in retries:
            th.join(timeout=timeout)
            done = done and not th.is_alive()
        if raise_on_error:
            errs = self.warm_errors
            if errs:
                rung = min(errs)
                raise RuntimeError(
                    f"lane-ladder warm-up failed for rung(s) "
                    f"{sorted(errs)}: {type(errs[rung]).__name__}: "
                    f"{errs[rung]}") from errs[rung]
        return done

    # -- the ladder walk ---------------------------------------------------

    def observe(self, pending: int, occupied: int) -> Optional[int]:
        """One tick's load sample -> a warm rung to switch to, or ``None``.

        Hysteresis: the grow condition (full lanes + queued streams) and
        the shrink condition (empty queue + occupancy fitting the lower
        rung) are disjoint, each must hold for its own dwell count, and
        any tick that breaks a streak resets its counter.
        """
        p = self.policy
        cur = self.rung
        grow = (self._idx + 1 < len(self.rungs)
                and pending >= p.grow_pending and occupied >= cur)
        shrink = (self._idx > 0 and pending == 0
                  and occupied <= self.rungs[self._idx - 1])
        self._up = self._up + 1 if grow else 0
        self._down = self._down + 1 if shrink else 0
        if self._up >= p.dwell_up:
            target = self.rungs[self._idx + 1]
            if self.is_ready(target):
                return target
            self._retry_warm(target)         # no-op unless it warm-failed
        if self._down >= p.dwell_down:
            target = self.rungs[self._idx - 1]
            if self.is_ready(target):
                return target
            self._retry_warm(target)
        return None

    def commit(self, rung: int, wall_s: float = 0.0) -> None:
        """Record a completed switch and reset the hysteresis streaks."""
        prev = self.rung
        self._idx = self.rungs.index(rung)
        self._up = 0
        self._down = 0
        self.switches.append({"from": prev, "to": rung, "wall_s": wall_s})


__all__ = ["ScalePolicy", "LaneAutoscaler", "ladder_rungs", "DEFAULT_RUNGS"]
