"""Fleet-tier serving: one front door over N host-level lane schedulers.

The paper's headline result is distribution itself — three PCs over
Ethernet beating one box (§4) — and ROADMAP's pod-scale item is its
modern form: a pod should serve ``L × n_hosts`` streams behind one front
door. This module grows the single-host ``MultiStreamScheduler`` into
that front door:

  * **Global EDF ordering** — all pending streams live in ONE shared
    admission queue ordered by the same ``(priority, deadline, arrival)``
    key as the single-host heap, so the earliest deadline in the *fleet*
    is admitted next, whichever host has the free lane.
  * **Sticky stream→host placement** — the first admission pins a stream
    to its host; every re-admission (deadline preemption requeues) returns
    to the same host. A stream's EMA ``AtmoState`` therefore NEVER
    migrates between hosts: coherence state stays where it was built, and
    ``ServeReport.migrations`` is reported (and asserted in tests) as 0.
  * **Spillover admission** — a fresh stream prefers the host its
    placement policy names; when that host's lanes are all claimed it is
    admitted wherever a lane is free instead of queueing behind a full
    host. Counted in ``ServeReport.spillovers``.

Each host runs an unmodified ``MultiStreamScheduler`` serve loop
(admission chaining, deadline eviction, per-host ``LaneAutoscaler``
ladders) on its own thread — the subclass only reroutes the four
pending-queue hooks to the shared queue. On this CPU container "hosts"
are threads over one XLA device (the lane-*sharded* device step for a
real pod is ``core.pipeline.make_step`` with a ``lane_axis`` placement);
the scheduler tier is identical either way.

Placement policies: ``"first-fit"`` (default) prefers host 0 for every
fresh stream — a waterfall that fills hosts in order and makes spillover
deterministic; ``"hash"`` spreads streams by a stable CRC32 of the stream
id; a callable ``sid -> host`` plugs in anything else.

``sink`` runs on the hosts' monitor threads concurrently — a fleet sink
must be thread-safe across *different* streams (per-stream calls stay
ordered, as always).

The fleet tier is frame-dtype agnostic: lane batches keep the spout's
wire dtype (uint8 stays 1 byte/channel from front door to HBM — see
``DehazeConfig.io_dtype`` and README §Dtype contract), and padding lanes
are ``zeros_like`` the live batch, so they match by construction.
"""
from __future__ import annotations

import bisect
import threading
import time
import zlib
from typing import Callable, List, Optional, Sequence, Union

from repro.stream.monitor import DEADLINE_CLOCK
from repro.stream.scheduler import (MultiSink, MultiStreamScheduler,
                                    ServeReport, StreamEntry, StreamRequest,
                                    _coerce_request, _Resume)
from repro.stream.state import StreamStateStore

PlacementPolicy = Union[str, Callable[[str], int]]


def _resolve_policy(policy: PlacementPolicy, n_hosts: int
                    ) -> Callable[[str], int]:
    if callable(policy):
        return lambda sid: int(policy(sid)) % n_hosts
    if policy == "first-fit":
        return lambda sid: 0
    if policy == "hash":
        return lambda sid: zlib.crc32(sid.encode()) % n_hosts
    raise ValueError(
        f"placement_policy must be 'first-fit', 'hash' or a callable, "
        f"got {policy!r}")


class _FleetQueue:
    """The shared cross-host admission queue.

    One sorted list of ``(key, seq, req, resume, pin)`` entries — ``key``
    is the global EDF admission key (arrivals are assigned fleet-wide, so
    keys are unique and ordering is total), ``pin`` forces a host
    (preemption requeues pin to the placement host). ``pop_for(host)``
    returns the best entry the host may take under stickiness + spillover;
    occupancy accounting (``active`` vs per-host lane capacity) decides
    when a non-preferred host may spill."""

    def __init__(self, n_hosts: int, lanes_per_host: int,
                 prefer: Callable[[str], int]):
        self._entries: List[tuple] = []     # sorted by (key, seq)
        self._lock = threading.Lock()
        self._seq = 0
        self._arrival = 0
        self._prefer = prefer
        self._cap = [lanes_per_host] * n_hosts
        self._active = [0] * n_hosts
        # Sticky ledger: stream id -> host of first admission. Never
        # rewritten — a conflicting re-admission would be a migration.
        self.placements: dict = {}
        self.spillovers = 0
        self.migrations = 0
        # One record per admission: {"stream_id", "host", "spillover",
        # "resumed"} — the no-migration property test replays this.
        self.admission_log: List[dict] = []

    def _push(self, key, req: StreamRequest, resume: Optional[_Resume],
              pin: Optional[int]) -> None:
        bisect.insort(self._entries, (key, self._seq, req, resume, pin))
        self._seq += 1

    def seed(self, req: StreamRequest) -> None:
        with self._lock:
            self._push(req.admission_key(self._arrival), req, None, None)
            self._arrival += 1

    def push_requeue(self, req: StreamRequest, resume: _Resume,
                     pin: int) -> None:
        """Preemption requeue: re-keyed with a fleet-wide arrival (FIFO
        behind the live queue, same as single-host) and pinned to the
        stream's placement host."""
        with self._lock:
            self._active[pin] -= 1
            self._push(req.admission_key(self._arrival), req, resume, pin)
            self._arrival += 1

    def note_freed(self, host: int) -> None:
        """A lane on ``host`` was released without a requeue (stream
        exhausted or error-path eviction)."""
        with self._lock:
            self._active[host] -= 1

    def empty(self) -> bool:
        with self._lock:
            return not self._entries

    def depth_for(self, host: int) -> int:
        """Entries this host could eventually admit: pinned/placed here or
        not yet placed anywhere."""
        with self._lock:
            n = 0
            for _, _, req, _, pin in self._entries:
                target = pin if pin is not None \
                    else self.placements.get(req.stream_id)
                if target is None or target == host:
                    n += 1
            return n

    def pop_for(self, host: int):
        """Best admissible entry for ``host`` (global EDF order), or None.

        Rules, per entry in key order: draining resumes (barrier unset)
        stay queued; placed/pinned streams only go to their own host
        (stickiness); a fresh stream goes to its preferred host, or — when
        the preferred host's lanes are all claimed — spills to whichever
        host is asking."""
        with self._lock:
            for i, (key, _seq, req, resume, pin) in enumerate(self._entries):
                if resume is not None and not resume.barrier.is_set():
                    continue
                sid = req.stream_id
                target = pin if pin is not None else self.placements.get(sid)
                spill = False
                if target is not None:
                    if target != host:
                        continue
                else:
                    pref = self._prefer(sid)
                    if pref != host:
                        if self._active[pref] < self._cap[pref]:
                            continue        # preferred host still has room
                        spill = True
                prev = self.placements.get(sid)
                if prev is not None and prev != host:   # pragma: no cover
                    self.migrations += 1                # asserted impossible
                self.placements[sid] = host
                self._active[host] += 1
                if spill:
                    self.spillovers += 1
                self.admission_log.append({
                    "stream_id": sid, "host": host, "spillover": spill,
                    "resumed": resume is not None})
                del self._entries[i]
                return key, req, resume
            return None


class _HostScheduler(MultiStreamScheduler):
    """One host's serve loop, pending queue rerouted to the fleet's."""

    def __init__(self, fleet_queue: _FleetQueue, host_id: int, **kwargs):
        super().__init__(**kwargs)
        self._fleet_q = fleet_queue
        self._host_id = host_id

    def _queue_depth(self) -> int:
        return self._fleet_q.depth_for(self._host_id)

    def _pop_ready(self):
        return self._fleet_q.pop_for(self._host_id)

    def _push_requeue(self, key, req, resume) -> None:
        del key                      # re-keyed with a fleet-wide arrival
        self._fleet_q.push_requeue(req, resume, pin=self._host_id)

    def _evict(self, lane_idx: int, packed, requeue: bool = False) -> None:
        super()._evict(lane_idx, packed, requeue=requeue)
        if not requeue:              # requeue already rebalanced the count
            self._fleet_q.note_freed(self._host_id)

    def _wait_pending(self) -> bool:
        # Another host may still fill up and spill work this way; keep
        # polling until the fleet queue is fully drained.
        if self._fleet_q.empty():
            return False
        time.sleep(0.005)
        return True


class FleetScheduler:
    """``n_hosts`` lane schedulers behind one front door (module docstring).

    ``step`` is shared by every host (same jitted executable — the per-host
    batches have identical shapes); ``autoscaler_factory(host_id)``
    optionally gives each host its own ``LaneAutoscaler`` ladder (they
    share the bounded step cache, so rungs compile once fleet-wide).
    ``step_factory(host_id)`` instead gives each host its OWN step — the
    overlapped tick path uses this, because a ``LaneTickStep``'s
    device-resident frame buffer must belong to exactly one host's serve
    loop (the jitted steps underneath still dedupe via the step cache).
    ``n_lanes`` is the per-host lane count — the fleet serves up to
    ``n_hosts × n_lanes`` streams concurrently. ``tick_delay_s`` simulates
    per-tick device service time (see ``MultiStreamScheduler``).
    """

    def __init__(self, step: Callable, store: StreamStateStore,
                 n_hosts: int, n_lanes: int, batch: int = 8,
                 timeout_s: float = 0.020, max_in_flight: int = 4,
                 max_skipped_ids: int = 64,
                 autoscaler_factory: Optional[Callable[[int], object]] = None,
                 evict_tardy_after: Optional[int] = None,
                 clock: Callable[[], float] = DEADLINE_CLOCK,
                 placement_policy: PlacementPolicy = "first-fit",
                 tick_delay_s: float = 0.0,
                 step_factory: Optional[Callable[[int], Callable]] = None):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.n_hosts = n_hosts
        self.n_lanes = n_lanes
        self._prefer = _resolve_policy(placement_policy, n_hosts)
        self._autoscaler_factory = autoscaler_factory
        self._step_factory = step_factory
        self._kw = dict(step=step, store=store, batch=batch,
                        timeout_s=timeout_s, max_in_flight=max_in_flight,
                        max_skipped_ids=max_skipped_ids,
                        evict_tardy_after=evict_tardy_after, clock=clock,
                        tick_delay_s=tick_delay_s)
        self.queue: Optional[_FleetQueue] = None    # exposed for tests

    def _build_hosts(self, queue: _FleetQueue) -> List[_HostScheduler]:
        hosts = []
        for h in range(self.n_hosts):
            scaler = (self._autoscaler_factory(h)
                      if self._autoscaler_factory is not None else None)
            kw = dict(self._kw)
            if scaler is not None:
                # The autoscaler's step_factory is already per-host (see
                # ElasticServer.serve_many.mk_scaler), so its initial
                # rung supersedes both the shared step and step_factory.
                kw["step"] = scaler.acquire_initial()
            elif self._step_factory is not None:
                kw["step"] = self._step_factory(h)
            hosts.append(_HostScheduler(queue, h, n_lanes=self.n_lanes,
                                        autoscaler=scaler, **kw))
        return hosts

    def run(self, streams: Sequence[StreamEntry],
            sink: Optional[MultiSink] = None) -> ServeReport:
        requests = []
        for e in streams:       # plain loop: warning stacklevel -> caller
            requests.append(_coerce_request(e))
        sids = [r.stream_id for r in requests]
        if len(set(sids)) != len(sids):
            dupes = sorted({s for s in sids if sids.count(s) > 1})
            raise ValueError(f"duplicate stream ids in one fleet serve: "
                             f"{dupes}")
        queue = _FleetQueue(self.n_hosts, self.n_lanes, self._prefer)
        self.queue = queue
        for req in requests:
            queue.seed(req)
        hosts = self._build_hosts(queue)

        reports: List[Optional[ServeReport]] = [None] * self.n_hosts
        errors: List[BaseException] = []

        def serve_host(h: int) -> None:
            try:
                reports[h] = hosts[h].run([], sink=sink)
            except BaseException as e:          # surfaced after the join
                errors.append(e)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=serve_host, args=(h,),
                                    name=f"fleet-host-{h}", daemon=True)
                   for h in range(self.n_hosts)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]

        done = [r for r in reports if r is not None]
        per_stream = {}
        for r in done:
            per_stream.update(r.per_stream)
        phases: dict = {}
        for r in done:
            for k, v in r.phases.items():
                phases[k] = phases.get(k, 0.0) + v
        return ServeReport(
            per_stream=per_stream,
            frames=sum(r.frames for r in done),
            skipped=sum(r.skipped for r in done),
            wall_s=wall,
            n_lanes=sum(r.n_lanes for r in done),
            ticks=sum(r.ticks for r in done),
            admissions=sum(r.admissions for r in done),
            ladder_switches=sum(r.ladder_switches for r in done),
            switch_wall_s=sum(r.switch_wall_s for r in done),
            evictions=sum(r.evictions for r in done),
            warm_failures=sum(r.warm_failures for r in done),
            overlap_ticks=sum(r.overlap_ticks for r in done),
            stragglers=sum(r.stragglers for r in done),
            d2h_bytes=sum(r.d2h_bytes for r in done),
            phases=phases,
            n_hosts=self.n_hosts,
            spillovers=queue.spillovers,
            migrations=queue.migrations)


__all__ = ["FleetScheduler", "PlacementPolicy"]
