"""The dispatcher: paper §3.2 layers 2-4 collapsed onto SPMD workers.

Drives the jitted dehaze step over a stream of frame batches with:
  - a bounded in-flight window (backpressure, overlaps host I/O with device
    compute — JAX dispatch is async, so enqueueing batch k+1 while batch k
    executes gives the compute/transfer overlap the paper gets from
    component pipelining);
  - per-batch completion threads that block on device results and feed the
    Monitor out of order (exactly the paper's layer-4 → layer-5 hand-off)
    through the shared valid-only deferred-fetch helper
    (``stream.iobuf.fetch_valid`` — padding frames never cross the wire);
  - sequential state threading: the EMA state of batch k feeds batch k+1 on
    the *device* (no host round-trip), which preserves the paper's §3.3
    coherence chain across batches;
  - an optional zero-copy mode (``overlap=True``, README §Tick I/O &
    overlap): each batch is ``jax.device_put`` ahead of the call (async
    H2D, overlapping the in-flight batch's compute) and the step is built
    with full donation (``make_step(..., donate=True)``), so for aliasable
    wire dtypes (f32→f32, bf16→bf16) ``out.frames`` reuses the input
    buffer and the state chain allocates nothing per batch;
  - elastic worker simulation: N logical workers round-robin batches, a
    worker can be paused/killed to exercise straggler and failure paths.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.core.normalize import AtmoState
from repro.stream.iobuf import fetch_valid
from repro.stream.monitor import Monitor
from repro.stream.spout import FrameBatch


@dataclass
class DispatchStats:
    batches: int = 0
    frames: int = 0
    wall_s: float = 0.0
    # Batches dispatched through the zero-copy path (explicit async H2D +
    # donated step). 0 when the dispatcher runs the blocking oracle.
    overlap_batches: int = 0
    # Bytes fetched device->host by completions (valid-only always).
    d2h_bytes: int = 0
    # Serve-loop seconds by phase, same keys as ``ServeReport.phases``.
    phases: Dict[str, float] = field(default_factory=dict)

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0


class StreamDispatcher:
    """Runs ``step(frames, frame_ids, state) -> DehazeOutput`` over a stream."""

    def __init__(self, step: Callable, monitor: Monitor,
                 max_in_flight: int = 4,
                 n_workers: int = 1,
                 worker_delay_s: Optional[Callable[[int], float]] = None,
                 overlap: bool = False,
                 clock: Callable[[], float] = time.perf_counter):
        self._step = step
        self._monitor = monitor
        self._sem = threading.Semaphore(max_in_flight)
        self._n_workers = max(1, n_workers)
        self._worker_delay = worker_delay_s
        self._overlap = overlap
        self._clock = clock
        self._completions: "queue.Queue" = queue.Queue()
        self._stats_lock = threading.Lock()
        self.stats = DispatchStats(
            phases={"host_stage_s": 0.0, "device_step_s": 0.0,
                    "deliver_s": 0.0})

    def run(self, batches: Iterable[FrameBatch], state: AtmoState) -> AtmoState:
        t0 = time.perf_counter()
        threads = []
        batch_idx = 0
        for fb in batches:
            t_stage = self._clock()
            if self._overlap:
                # Async H2D ahead of the dispatch: the transfer of batch
                # k+1 overlaps batch k's compute. With a donated step the
                # device buffer is consumed by the call (out.frames
                # aliases it when the dtype contract allows), so it is
                # never reused across batches.
                frames = jax.device_put(fb.frames)
            else:
                frames = fb.frames
            self._phase("host_stage_s", self._clock() - t_stage)
            self._sem.acquire()
            # State threading is sequential by construction: the step for
            # batch k is dispatched with the (device-resident, possibly
            # not-yet-computed) state output of batch k-1. JAX's async
            # dispatch pipelines them without blocking the host. With a
            # donated step the old state is consumed by this call — it is
            # dead here anyway (rebound to out.state below).
            t_step = self._clock()
            out = self._step(frames, fb.frame_ids, state)
            self._phase("device_step_s", self._clock() - t_step)
            state = out.state
            worker = batch_idx % self._n_workers
            th = threading.Thread(
                target=self._complete, args=(fb, out, worker), daemon=True)
            th.start()
            threads.append(th)
            batch_idx += 1
            self.stats.batches += 1
            self.stats.frames += fb.n_valid
            if self._overlap:
                self.stats.overlap_batches += 1
        for th in threads:
            th.join()
        self.stats.wall_s = time.perf_counter() - t0
        return jax.device_get(state)

    def _phase(self, key: str, dt: float) -> None:
        with self._stats_lock:
            self.stats.phases[key] = self.stats.phases.get(key, 0.0) + dt

    def _complete(self, fb: FrameBatch, out: Any, worker: int) -> None:
        try:
            t0 = self._clock()
            # One completion mechanism for both serve paths: valid-only
            # deferred fetch (the old whole-batch np.asarray stalled on —
            # and shipped — the padding tail too).
            frames = fetch_valid(out.frames, fb.n_valid)
            if self._worker_delay is not None:
                time.sleep(self._worker_delay(worker))
            for i in range(fb.n_valid):
                self._monitor.put(int(fb.frame_ids[i]), frames[i])
            with self._stats_lock:
                self.stats.d2h_bytes += frames.nbytes
            self._phase("deliver_s", self._clock() - t0)
        finally:
            self._sem.release()
