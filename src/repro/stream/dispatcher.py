"""The dispatcher: paper §3.2 layers 2-4 collapsed onto SPMD workers.

Drives the jitted dehaze step over a stream of frame batches with:
  - a bounded in-flight window (backpressure, overlaps host I/O with device
    compute — JAX dispatch is async, so enqueueing batch k+1 while batch k
    executes gives the compute/transfer overlap the paper gets from
    component pipelining);
  - per-batch completion threads that block on device results and feed the
    Monitor out of order (exactly the paper's layer-4 → layer-5 hand-off);
  - sequential state threading: the EMA state of batch k feeds batch k+1 on
    the *device* (no host round-trip), which preserves the paper's §3.3
    coherence chain across batches;
  - elastic worker simulation: N logical workers round-robin batches, a
    worker can be paused/killed to exercise straggler and failure paths.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.core.normalize import AtmoState
from repro.stream.monitor import Monitor
from repro.stream.spout import FrameBatch


@dataclass
class DispatchStats:
    batches: int = 0
    frames: int = 0
    wall_s: float = 0.0

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0


class StreamDispatcher:
    """Runs ``step(frames, frame_ids, state) -> DehazeOutput`` over a stream."""

    def __init__(self, step: Callable, monitor: Monitor,
                 max_in_flight: int = 4,
                 n_workers: int = 1,
                 worker_delay_s: Optional[Callable[[int], float]] = None):
        self._step = step
        self._monitor = monitor
        self._sem = threading.Semaphore(max_in_flight)
        self._n_workers = max(1, n_workers)
        self._worker_delay = worker_delay_s
        self._completions: "queue.Queue" = queue.Queue()
        self.stats = DispatchStats()

    def run(self, batches: Iterable[FrameBatch], state: AtmoState) -> AtmoState:
        t0 = time.perf_counter()
        threads = []
        batch_idx = 0
        for fb in batches:
            self._sem.acquire()
            # State threading is sequential by construction: the step for
            # batch k is dispatched with the (device-resident, possibly
            # not-yet-computed) state output of batch k-1. JAX's async
            # dispatch pipelines them without blocking the host.
            out = self._step(fb.frames, fb.frame_ids, state)
            state = out.state
            worker = batch_idx % self._n_workers
            th = threading.Thread(
                target=self._complete, args=(fb, out, worker), daemon=True)
            th.start()
            threads.append(th)
            batch_idx += 1
            self.stats.batches += 1
            self.stats.frames += fb.n_valid
        for th in threads:
            th.join()
        self.stats.wall_s = time.perf_counter() - t0
        return jax.device_get(state)

    def _complete(self, fb: FrameBatch, out: Any, worker: int) -> None:
        try:
            frames = np.asarray(out.frames)   # blocks until device done
            if self._worker_delay is not None:
                time.sleep(self._worker_delay(worker))
            for i in range(fb.n_valid):
                self._monitor.put(int(fb.frame_ids[i]), frames[i])
        finally:
            self._sem.release()
