"""Host-side streaming runtime (paper §3.2): spout → workers → monitor,
plus the multi-tenant lane scheduler (continuous batching across videos)
and the elastic lane autoscaler (precompiled shape ladder)."""
from repro.stream.autoscale import (DEFAULT_RUNGS, LaneAutoscaler,
                                    ScalePolicy, ladder_rungs)
from repro.stream.dispatcher import DispatchStats, StreamDispatcher
from repro.stream.elastic import ElasticServer
from repro.stream.fleet import FleetScheduler
from repro.stream.iobuf import (LaneTickStep, TickBufferPool,
                                donation_supported, fetch_valid)
from repro.stream.monitor import Monitor, MonitorStats
from repro.stream.scheduler import (MultiServeReport, MultiStreamScheduler,
                                    ServeReport, StreamReport, StreamRequest)
from repro.stream.spout import FrameBatch, Spout
from repro.stream.state import StreamStateStore

__all__ = ["Monitor", "MonitorStats", "Spout", "FrameBatch",
           "StreamDispatcher", "DispatchStats", "ElasticServer",
           "ServeReport", "StreamStateStore", "MultiStreamScheduler",
           "MultiServeReport", "StreamReport", "StreamRequest",
           "FleetScheduler",
           "LaneTickStep", "TickBufferPool", "donation_supported",
           "fetch_valid",
           "ScalePolicy", "LaneAutoscaler", "ladder_rungs", "DEFAULT_RUNGS"]
