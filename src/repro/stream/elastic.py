"""Elastic scaling + fault tolerance for the serving runtime.

The paper's cluster "automatically scales up and down based on the actual
workload" (§5). On a TPU fleet the analogous operations are:

  * ``ElasticServer.resize(n)``    — rebuild the device mesh over the
    surviving/new workers and re-shard the stream state (cheap: the state
    is a few bytes; model-based pipelines also re-shard params via
    ``jax.device_put`` with the new sharding).
  * checkpoint/restart             — the stream state store + frame cursor
    are snapshotted through ``repro.checkpoint``; a restarted server
    resumes mid-stream with the SAME coherent A trajectory, and the
    monitor cursor guarantees no frame is emitted twice.
  * straggler mitigation           — inherited from the Monitor timeout
    (paper's 20 ms rule) plus the dispatcher's bounded in-flight window.

On this CPU container "workers" are logical (host threads over one XLA
device); on a real fleet the resize hook swaps the jitted executable for
one compiled against the new mesh — the dry-run in launch/dryrun.py proves
those executables compile for every mesh we claim to support.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Iterable, List, Optional, Sequence

import jax
import numpy as np

from repro.core import (DehazeConfig, PlacementSpec, make_dehaze_step,
                        make_step, resolve_lane_native)
from repro.core import env as _env
from repro.stream import iobuf
from repro.stream.autoscale import LaneAutoscaler, ScalePolicy, ladder_rungs
from repro.stream.dispatcher import StreamDispatcher
from repro.stream.fleet import FleetScheduler, PlacementPolicy
from repro.stream.monitor import DEADLINE_CLOCK, Monitor
from repro.stream.scheduler import (MultiServeReport, MultiStreamScheduler,
                                    ServeReport, StreamEntry, StreamReport,
                                    _coerce_request)
from repro.stream.spout import Spout
from repro.stream.state import StreamStateStore


class _LRUStepCache:
    """Bounded jitted-step cache. The old module-global dict grew without
    bound across config sweeps (every ``DehazeConfig`` variant pins its
    executable forever); this keeps the ``maxsize`` most recently used.
    Shared by the single-stream and the multi-stream (lane-vmapped) step
    builders — the kind of step is part of the key.

    ``hits``/``misses`` and ``built_by`` (key -> ident of the thread that
    built the entry) exist so serving code can *assert* its compile
    discipline: the autoscale tests check every ladder rung beyond the
    starting one was built by the background warm thread, never the serve
    thread."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.built_by: dict = {}

    def get(self, key, build: Callable):
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
        step = build()                       # build outside the lock (slow)
        with self._lock:
            if key not in self._d:
                self.built_by[key] = threading.get_ident()
            self._d[key] = step
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)
        return step

    def __len__(self) -> int:
        return len(self._d)


_STEP_CACHE = _LRUStepCache(maxsize=_env.step_cache_size())


def _cached_step(cfg: DehazeConfig, donate=False):
    """One jitted executable per (config, donation contract) — servers
    with the same config (e.g. benchmark sweeps over worker counts) share
    compilations. ``donate`` is the ``make_step`` donation contract; a
    donating executable must never be handed to a caller that reuses its
    input buffers, hence the key."""
    def build():
        if donate is not False:
            return make_step(cfg, PlacementSpec.single(), donate=donate)
        return jax.jit(make_dehaze_step(cfg))
    return _STEP_CACHE.get(("single", cfg, donate), build)


def _cached_multi_step(cfg: DehazeConfig, n_lanes: int, lane_native: bool,
                       placement: Optional[PlacementSpec] = None,
                       donate=False):
    """Multi-stream step (lane-native megakernel or lane-vmapped chain),
    same bounded cache.

    The key is ``(cfg, n_lanes, lane_native, placement, donate)``: a
    ``serve_many`` resize, a ``REPRO_LANE_NATIVE`` toggle, a different
    axis placement, or a different donation contract between calls must
    never reuse a stale compiled step — the old ``("multi", cfg)`` key
    did exactly that, handing a 4-lane fleet the executable (and, for
    lane-native, the grid/tuning resolution) built for a different lane
    count or the other dispatch path. ``jax.jit`` still specializes per
    input shape underneath; changing the lane count mid-fleet costs a
    recompile (see the ROADMAP lane-autoscaling follow-on).

    ``n_hosts`` is normalized out of the key: the device step is
    host-count agnostic (the fleet tier schedules hosts above it), so a
    2-host fleet reuses the executable its 1-host twin compiled.
    ``donate`` is NOT normalized out: ``"state"`` builds the tick-step
    contract the overlapped serve path donates its EMA chain through
    (``make_step`` docs)."""
    if placement is None:
        placement = PlacementSpec.lane_batched()
    if placement.n_hosts != 1:
        placement = dataclasses.replace(placement, n_hosts=1)

    def build():
        if donate is not False:
            return make_step(cfg, placement, lane_native=lane_native,
                             donate=donate)
        return jax.jit(make_step(cfg, placement, lane_native=lane_native))
    return _STEP_CACHE.get(
        ("multi", cfg, n_lanes, lane_native, placement, donate), build)


def _resolve_overlap(tick_overlap: Optional[bool]) -> bool:
    """Should this serve call take the zero-copy overlapped tick path?

    Explicit argument wins; ``None`` defers to ``REPRO_TICK_OVERLAP``
    (off when unset — the blocking path is the long-standing default and
    the parity oracle). Either way the request is honored only when the
    backend supports buffer donation; a forced-but-unsupported overlap
    falls back to blocking, which ``ServeReport.overlap_ticks`` exposes
    and ``launch/serve.py --expect-overlap`` turns into a hard failure.
    """
    req = tick_overlap if tick_overlap is not None else _env.tick_overlap()
    return bool(req) and iobuf.donation_supported()


class ElasticServer:
    """Serves dehazing streams with an elastically sized worker pool."""

    def __init__(self, cfg: DehazeConfig, n_workers: int = 1,
                 batch: int = 8, timeout_s: float = 0.020,
                 max_in_flight: int = 4,
                 worker_delay_s: Optional[Callable[[int], float]] = None):
        self.cfg = cfg
        self.batch = batch
        self.timeout_s = timeout_s
        self.max_in_flight = max_in_flight
        self.store = StreamStateStore()
        self._worker_delay = worker_delay_s
        self._step = _cached_step(cfg)
        self.n_workers = n_workers
        # Last FleetScheduler used by a multi-host serve_many — exposes the
        # sticky-placement ledger and admission log for callers/tests.
        self.last_fleet: Optional[FleetScheduler] = None

    def resize(self, n_workers: int) -> None:
        """Elastic scale up/down. State survives; executables are reused
        (single-host) or recompiled against the new mesh (fleet)."""
        self.n_workers = max(1, n_workers)

    def serve(self, frames: Iterable[np.ndarray], stream_id: str = "default",
              sink: Optional[Callable[[int, np.ndarray], None]] = None,
              tick_overlap: Optional[bool] = None) -> ServeReport:
        """Serve one stream through the dispatcher.

        ``tick_overlap`` opts this call into the zero-copy path: explicit
        async H2D per batch plus a fully donated step (state always;
        frames too when ``cfg.io_dtype`` aliases the resolved output
        dtype), with valid-only D2H on completion. ``None`` defers to
        ``REPRO_TICK_OVERLAP`` (default off). Outputs are bit-identical
        either way — donation changes buffer reuse, not values.
        """
        out_frames: List[int] = []

        def write(fid: int, payload: np.ndarray) -> None:
            out_frames.append(fid)
            if sink is not None:
                sink(fid, payload)

        overlap = _resolve_overlap(tick_overlap)
        step = _cached_step(self.cfg, donate=True) if overlap else self._step
        start = self.store.cursor(stream_id)
        monitor = Monitor(write, timeout_s=self.timeout_s, start_frame=start)
        spout = Spout(frames, batch=self.batch, start_frame=start,
                      stream_id=stream_id)
        dispatcher = StreamDispatcher(
            step, monitor, max_in_flight=self.max_in_flight,
            n_workers=self.n_workers, worker_delay_s=self._worker_delay,
            overlap=overlap)

        import threading
        mon_thread = threading.Thread(target=monitor.run, daemon=True)
        mon_thread.start()
        t0 = time.perf_counter()
        state = dispatcher.run(iter(spout), self.store.get(stream_id))
        monitor.close()
        mon_thread.join(timeout=5.0)
        monitor.drain()
        wall = time.perf_counter() - t0

        cursor = start + dispatcher.stats.frames
        self.store.update(stream_id, state, cursor)
        rep = StreamReport(stream_id=stream_id,
                           frames=dispatcher.stats.frames,
                           skipped=monitor.stats.skipped, wall_s=wall)
        return ServeReport(
            per_stream={stream_id: rep},
            frames=rep.frames, skipped=rep.skipped, wall_s=wall,
            n_lanes=self.n_workers, ticks=dispatcher.stats.batches,
            overlap_ticks=dispatcher.stats.overlap_batches,
            d2h_bytes=dispatcher.stats.d2h_bytes,
            phases=dict(dispatcher.stats.phases))

    def serve_many(self, streams: Sequence[StreamEntry],
                   n_lanes: Optional[int] = None,
                   sink: Optional[Callable[[str, int, np.ndarray], None]]
                   = None, autoscale: bool = False,
                   policy: Optional[ScalePolicy] = None,
                   clock: Callable[[], float] = DEADLINE_CLOCK,
                   n_hosts: int = 1,
                   placement: Optional[PlacementSpec] = None,
                   placement_policy: PlacementPolicy = "first-fit",
                   host_delay_s: float = 0.0,
                   tick_overlap: Optional[bool] = None) -> MultiServeReport:
        """Serve N videos concurrently via lane-batched continuous batching.

        ``streams`` is a sequence of :class:`~repro.stream.StreamRequest`
        (stream id, frames, optional ``deadline`` for
        earliest-deadline-first admission when lanes are scarce, optional
        ``priority``); legacy ``(stream_id, frames[, deadline])`` tuples
        are coerced with a ``DeprecationWarning``. All streams must share
        the same (H, W) resolution (the lane batch has one fixed device
        shape). ``n_lanes`` defaults to one lane per stream; with fewer
        lanes than streams the scheduler queues the surplus and admits
        them as lanes free up (eviction + reuse).

        ``autoscale=True`` makes the lane count elastic: ``n_lanes``
        becomes the *cap*, the serve starts at the smallest rung of
        ``policy.rungs`` (capped ladder — see ``autoscale.ladder_rungs``)
        and walks up/down with queue depth under hysteresis, with the
        other rungs precompiled on a background thread so a switch never
        traces on the serve thread. Passing a ``policy`` without
        ``autoscale`` still applies its ``evict_tardy_after``
        deadline-aware eviction at a fixed lane count.

        With a fused-covered config the device step is the *lane-native*
        megakernel — all L lanes fold into one ``pallas_call`` grid, so a
        tick costs one kernel launch instead of L (env
        ``REPRO_LANE_NATIVE=0`` forces the vmapped path back).

        Per-stream semantics match N sequential :meth:`serve` calls to
        float32 round-off (exactly, on the fused path; the vmapped staged
        XLA program may fuse FMAs differently, <= ~2 ULP) — same EMA
        trajectories (each lane scans its own causal chain), same monitor
        ordering + timeout-skip rules, same restart-safe cursors in
        ``self.store``. Stream ids must be unique per call (resume a
        stream with a follow-up call). The device sees ONE
        ``(L, B, H, W, 3)`` program per tick instead of N serialized
        streams, which is where the aggregate-fps win comes from.

        Frames travel at their wire dtype end-to-end: a uint8 stream stays
        uint8 through the spout, the scheduler's lane batches and the
        ladder warm-ups, and is only upcast in-VMEM by the kernels
        (``cfg.io_dtype`` declares the contract; ``cfg.out_dtype`` the
        output side). Both fields are part of the frozen config and hence
        of every step-cache key — toggling the ingest dtype can never
        reuse a step compiled for another dtype.

        ``n_hosts > 1`` (or a ``placement`` with ``n_hosts > 1``) serves
        the same streams through a :class:`~repro.stream.FleetScheduler`:
        ``n_hosts`` host-level schedulers behind one global-EDF front door,
        with sticky stream→host placement (EMA state never migrates) and
        spillover admission once a host's lanes fill. ``n_lanes`` is then
        the *per-host* lane count; ``placement_policy`` picks each fresh
        stream's preferred host; ``host_delay_s`` simulates per-tick device
        service time on each host (fleet benchmarks). Per-stream outputs,
        EMA trajectories and cursors stay bit-identical to the single-host
        serve — only which host runs a stream changes.

        ``tick_overlap`` opts into the zero-copy overlapped tick path
        (README §Tick I/O & overlap): the lane batch lives on device in a
        per-serve (per-host, for fleets) buffer, live lanes are staged by
        async per-lane ``device_put`` + a donated splice, the EMA state
        chain is donated tick-to-tick, and completions fetch valid frames
        only. ``None`` defers to ``REPRO_TICK_OVERLAP`` (default off —
        the blocking path stays the parity oracle). Per-stream outputs
        are bit-identical on both paths; ``ServeReport.overlap_ticks``
        records which one actually ran.
        """
        # Coerce HERE (not in the scheduler) and with a plain loop (not a
        # comprehension, which owns its own frame on CPython < 3.12): the
        # deprecation warning's stacklevel then lands on the caller that
        # actually passed the legacy tuple.
        coerced = []
        for s in streams:
            coerced.append(_coerce_request(s))
        streams = coerced
        if not streams:
            return MultiServeReport(per_stream={}, frames=0, skipped=0,
                                    wall_s=0.0, n_lanes=0, ticks=0,
                                    admissions=0)
        if placement is None:
            placement = PlacementSpec.lane_batched(n_hosts=n_hosts)
        else:
            placement.validate()
            n_hosts = placement.n_hosts
        if placement.sharded:
            raise ValueError(
                "serve_many drives local lane batches; mesh-sharded "
                "placements go through core.make_step(cfg, placement, mesh) "
                "with the launch tooling")
        if not placement.lanes:
            raise ValueError("serve_many needs a lane placement; use "
                             "PlacementSpec.lane_batched(...)")
        lanes = n_lanes if n_lanes is not None \
            else max(1, -(-len(streams) // n_hosts))
        lane_native = resolve_lane_native(self.cfg)
        scaler = None
        evict_after = policy.evict_tardy_after if policy is not None else None
        pol = policy if policy is not None else ScalePolicy()
        overlap = _resolve_overlap(tick_overlap)

        def base_step_for(n: int):
            return _cached_multi_step(self.cfg, n, lane_native, placement,
                                      donate="state" if overlap else False)

        def mk_step_for(_host: int = 0):
            """Per-host step factory. On the overlapped path each host
            gets its OWN TickBufferPool — the device frame buffer belongs
            to one serve loop — while the donated jitted steps underneath
            still share the bounded cache fleet-wide."""
            if not overlap:
                return base_step_for
            return iobuf.TickBufferPool(base_step_for).adapter

        def mk_scaler(host: int = 0) -> LaneAutoscaler:
            return LaneAutoscaler(mk_step_for(host),
                                  ladder_rungs(pol.rungs, lanes),
                                  policy=pol)

        if autoscale:
            evict_after = pol.evict_tardy_after

        if n_hosts > 1:
            factory = mk_scaler if autoscale else None
            fleet = FleetScheduler(
                base_step_for(lanes), self.store, n_hosts=n_hosts,
                n_lanes=lanes,
                batch=self.batch, timeout_s=self.timeout_s,
                max_in_flight=self.max_in_flight,
                autoscaler_factory=factory, evict_tardy_after=evict_after,
                clock=clock, placement_policy=placement_policy,
                tick_delay_s=host_delay_s,
                step_factory=((lambda h: mk_step_for(h)(lanes))
                              if overlap else None))
            self.last_fleet = fleet          # placements/log for callers
            return fleet.run(streams, sink=sink)

        if autoscale:
            scaler = mk_scaler()
            step = scaler.acquire_initial()
            lanes = scaler.rung
        else:
            step = mk_step_for()(lanes)
        scheduler = MultiStreamScheduler(
            step, self.store, n_lanes=lanes,
            batch=self.batch, timeout_s=self.timeout_s,
            max_in_flight=self.max_in_flight, autoscaler=scaler,
            evict_tardy_after=evict_after, clock=clock,
            tick_delay_s=host_delay_s)
        return scheduler.run(streams, sink=sink)
