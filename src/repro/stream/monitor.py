"""The monitor component (paper §3.2 layer 5, Fig. 5).

Parallel workers complete frames out of order; the monitor restores stream
order at the sink with a priority queue, a reader that waits up to a
timeout for a missing frame and then *skips* it (the paper's 20 ms reader
rule — the framework's built-in straggler mitigation), and a writer
callback that receives frames strictly in ascending id order.
"""
from __future__ import annotations

import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

# The one deadline/timeout timebase shared by every serving component
# (Monitor reader timeouts, scheduler/fleet EDF ordering and tardy
# eviction, ``serve_many`` wall clocks). Monotonic by design: deadline
# comparisons must not misfire when NTP steps the wall clock — the
# scheduler and the monitor previously defaulted to *different* clocks
# (``time.time`` vs ``time.monotonic``), so a wall-clock step could evict
# lanes or reorder EDF admission spuriously. Inject a fake through the
# ``clock=`` parameters for tests; ``StreamRequest.deadline`` values are
# compared against this clock, so produce them from it too.
DEADLINE_CLOCK: Callable[[], float] = time.monotonic


@dataclass
class MonitorStats:
    emitted: int = 0
    skipped: int = 0                 # running count (never truncated)
    out_of_order_arrivals: int = 0
    max_queue_depth: int = 0
    # Only the most recent ``Monitor.max_skipped_ids`` ids are kept — a
    # lossy long-running stream skips unboundedly, the full history is the
    # count above, the tail is what an operator actually pages through.
    skipped_ids: List[int] = field(default_factory=list)


class Monitor:
    """Order-restoring sink with deadline-based skip.

    Thread-safe: any number of producers call ``put``; one consumer drives
    ``poll`` (or ``run`` in a dedicated thread). ``write_fn(frame_id,
    payload)`` is invoked in order.
    """

    def __init__(self, write_fn: Callable[[int, Any], None],
                 timeout_s: float = 0.020, start_frame: int = 0,
                 clock: Callable[[], float] = DEADLINE_CLOCK,
                 max_skipped_ids: int = 64):
        self._write = write_fn
        self._timeout = timeout_s
        self._next = start_frame
        self._clock = clock
        self._heap: List[tuple] = []
        self._lock = threading.Condition()
        self._deadline: Optional[float] = None
        self._closed = False
        self.max_skipped_ids = max_skipped_ids
        self.stats = MonitorStats()

    def _record_skip_locked(self, frame_id: int) -> None:
        self.stats.skipped += 1
        ids = self.stats.skipped_ids
        ids.append(frame_id)
        if len(ids) > self.max_skipped_ids:
            del ids[:len(ids) - self.max_skipped_ids]

    def put(self, frame_id: int, payload: Any) -> None:
        with self._lock:
            if frame_id >= self._next:
                heapq.heappush(self._heap, (frame_id, payload))
                if frame_id > self._next:
                    self.stats.out_of_order_arrivals += 1
                self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                                 len(self._heap))
            # Late arrival for an already skipped/emitted id is dropped.
            self._lock.notify_all()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._lock.notify_all()

    # -- consumer side -----------------------------------------------------

    def _emit_ready_locked(self) -> None:
        while self._heap and self._heap[0][0] == self._next:
            fid, payload = heapq.heappop(self._heap)
            self._write(fid, payload)
            self.stats.emitted += 1
            self._next = fid + 1
            self._deadline = None
        # Drop stale duplicates below the cursor.
        while self._heap and self._heap[0][0] < self._next:
            heapq.heappop(self._heap)

    def poll(self) -> bool:
        """Emit everything currently possible; skip on expired deadline.

        Returns True while the stream may still produce output."""
        with self._lock:
            self._emit_ready_locked()
            if self._heap:
                # A later frame is waiting on a missing earlier one.
                now = self._clock()
                if self._deadline is None:
                    self._deadline = now + self._timeout
                elif now >= self._deadline:
                    # Paper's reader rule: skip the absent frame, move on.
                    self._record_skip_locked(self._next)
                    self._next += 1
                    self._deadline = None
                    self._emit_ready_locked()
            return not (self._closed and not self._heap)

    def run(self, idle_sleep: float = 0.05) -> None:
        """Consumer loop. ``idle_sleep`` is only a safety-net timeout: every
        state change (``put``, ``close``) notifies the condition, so the
        loop wakes immediately when there is work. The old 1 ms default
        made every idle monitor a 1 kHz GIL-contending poll storm — with
        one monitor per stream the multi-tenant scheduler paid it L-fold."""
        while self.poll():
            with self._lock:
                if not self._heap and not self._closed:
                    self._lock.wait(timeout=idle_sleep)
                elif self._heap and self._deadline is not None:
                    self._lock.wait(timeout=max(
                        0.0, self._deadline - self._clock()))

    def drain(self) -> None:
        """Flush remaining frames in order, skipping all gaps (shutdown)."""
        with self._lock:
            while self._heap:
                if self._heap[0][0] != self._next:
                    self._record_skip_locked(self._next)
                    self._next += 1
                else:
                    self._emit_ready_locked()
