"""Zero-copy tick I/O: device-resident lane buffers, donation, deferred D2H.

The serve loop's host⇄device boundary used to serialize three ways every
tick: a host-side ``np.stack`` over all L lanes, a blocking full-batch
H2D inside the jitted call, and a whole-batch ``np.asarray(out.frames)``
readback that fetched padding lanes nobody would ever look at. This
module is the overlapped replacement (README §Tick I/O & overlap):

  * :class:`LaneTickStep` keeps the ``(L, B, H, W, 3)`` wire-dtype frame
    batch *living on device*. ``stage(lane, frames)`` uploads one lane's
    batch (``jax.device_put`` — async, overlapping whatever tick is in
    flight) and splices it in with a *donated* ``dynamic_update_slice``
    (in-place on the persistent buffer: no copy of the other L-1 lanes).
    ``tick(ids, state)`` then runs the state-donated step on the buffer.
    Padding lanes are simply never staged — their rows hold stale frames
    that the ``frame_id = -1`` masking makes inert and valid-only D2H
    makes invisible.
  * :func:`fetch_valid` is the one deferred-fetch helper both serve paths
    complete through: it slices ``out.frames[lane, :n_valid]`` on device
    and fetches only those bytes.
  * :func:`donation_supported` probes (once) whether the backend honors
    ``donate_argnums`` — the serving tiers only take the overlapped path
    when it does, and ``launch/serve.py --expect-overlap`` turns the
    fallback into a hard failure.

Buffer ownership contract (who may touch what, until when):

  * the adapter owns ``self._buf`` — callers never read it, and the step
    does NOT donate it (only the state argnum), so ``out.frames`` is a
    distinct buffer the completion thread may hold for as long as it
    likes;
  * ``out.state`` belongs to the serve loop and is *donated into the next
    tick*: every read of it (eviction snapshots, rung-switch repacks)
    must be dispatched before the next ``tick()`` call — device execution
    order equals dispatch order, so anything enqueued earlier reads the
    pre-donation value;
  * a staged lane upload belongs to the adapter the moment ``stage``
    returns; the caller may free/reuse its host array immediately.
"""
from __future__ import annotations

import threading
import warnings
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

_probe_lock = threading.Lock()
_donation_supported: Optional[bool] = None


def donation_supported() -> bool:
    """Does this backend honor ``jax.jit(..., donate_argnums=...)``?

    Probed once per process with a trivial donated add: on a supporting
    backend the donated input is deleted after the call
    (``x.is_deleted()``); a backend that cannot implement donation warns
    and leaves the input alive. CPU jaxlibs historically fell in the
    second bucket; current ones alias. The serving tiers gate the
    overlapped tick path on this, keeping the blocking path as both the
    fallback and the parity oracle.
    """
    global _donation_supported
    if _donation_supported is not None:
        return _donation_supported
    with _probe_lock:
        if _donation_supported is not None:
            return _donation_supported
        try:
            f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
            x = jnp.zeros((8,), jnp.float32)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                jax.block_until_ready(f(x))
            supported = bool(x.is_deleted())
        except Exception:
            supported = False
        _donation_supported = supported
    return supported


def fetch_valid(frames, n_valid: int, lane: Optional[int] = None
                ) -> np.ndarray:
    """Valid-only D2H: fetch ``frames[lane, :n_valid]`` (or
    ``frames[:n_valid]`` when ``lane`` is None) as a host array.

    The slice is dispatched on device *before* the blocking fetch, so
    only the requested bytes cross the wire — padding frames (and, per
    lane, the other lanes) never leave HBM. This is the single completion
    mechanism shared by the lane scheduler and the single-stream
    dispatcher.
    """
    view = frames if lane is None else frames[lane]
    return np.asarray(view[:n_valid])


@partial(jax.jit, donate_argnums=(0,))
def _lane_update(buf, lane, idx):
    """In-place (donated) write of one lane's batch into the persistent
    device buffer. ``idx`` is a traced scalar — one executable per buffer
    shape/dtype, not one per lane index."""
    zeros = (0,) * (buf.ndim - 1)
    return lax.dynamic_update_slice(buf, lane[None], (idx,) + zeros)


class LaneTickStep:
    """Device-resident lane buffer + state-donated step, one lane count.

    ``step`` is the jitted lane-batched step built with
    ``make_step(..., donate="state")``. The adapter is *call-compatible*
    with the raw step (``adapter(frames, ids, state)`` uploads the full
    batch and ticks), which is exactly what the autoscaler's rung warm-up
    invokes — so warming a rung through the adapter pre-binds its donated
    buffer AND populates both executables (step + lane splice) for the
    serving avals, with zero autoscaler changes.

    ``stage``/``tick`` belong to one serve thread (the completion threads
    only ever hold ``out.frames``, never the buffer). ``__call__`` is
    additionally serialized by a lock: concurrent full-batch calls on one
    adapter (the autoscaler's warm + retry threads can overlap) would
    interleave the buffer rebind with the donated splice and hand one
    thread the other's already-donated buffer.
    """

    def __init__(self, step: Callable, n_lanes: int):
        self._step = step
        self.n_lanes = n_lanes
        self._buf = None
        self._call_lock = threading.Lock()
        self.staged_lanes = 0       # stage() calls (live-lane uploads)
        self.staged_bytes = 0       # H2D bytes actually shipped

    def ensure_buf(self, lane_shape: Tuple[int, ...], dtype) -> None:
        """(Re)allocate the persistent ``(L,) + lane_shape`` device buffer
        when the lane batch shape or wire dtype changes."""
        shape = (self.n_lanes,) + tuple(lane_shape)
        if (self._buf is None or self._buf.shape != shape
                or self._buf.dtype != np.dtype(dtype)):
            self._buf = jnp.zeros(shape, dtype)

    def stage(self, lane_idx: int, frames) -> None:
        """Upload one lane's ``(B, H, W, 3)`` batch into its buffer row.

        ``device_put`` starts the H2D transfer without blocking on
        in-flight compute; the donated splice executes in dispatch order,
        after any tick already reading the buffer.
        """
        arr = np.asarray(frames)
        self.ensure_buf(arr.shape, arr.dtype)
        dev = jax.device_put(arr)
        self._buf = _lane_update(self._buf, dev, np.int32(lane_idx))
        self.staged_lanes += 1
        self.staged_bytes += arr.nbytes

    def tick(self, frame_ids, state):
        """Run the step on the device-resident buffer. ``state`` is
        donated — the caller must not touch it after this call (reads it
        dispatched *before* the call are safe)."""
        return self._step(self._buf, np.asarray(frame_ids), state)

    def __call__(self, frames, frame_ids, state):
        """Full-batch compatibility path (rung warm-up, direct callers):
        upload the whole batch, prime the lane-splice executable, tick."""
        with self._call_lock:
            arr = np.asarray(frames)
            self._buf = jax.device_put(arr)
            if arr.shape[0] > 0:
                self.stage(0, arr[0])
            return self.tick(frame_ids, state)


class TickBufferPool:
    """Per-serve (or per-fleet-host) pool of :class:`LaneTickStep`
    adapters, one per lane count.

    ``step_factory(n_lanes)`` returns the state-donated jitted step for a
    rung (typically ``stream.elastic._cached_multi_step(...,
    donate="state")``). ``pool.adapter`` has the exact
    ``step_factory(n)`` signature the autoscaler and ``serve_many``
    already use, so the overlapped path slots in wherever a step factory
    went before. Pools are intentionally NOT shared across fleet hosts:
    each host owns its device frame buffer (the jitted steps underneath
    still share the bounded step cache).
    """

    def __init__(self, step_factory: Callable[[int], Callable]):
        self._factory = step_factory
        self._adapters: Dict[int, LaneTickStep] = {}
        self._lock = threading.Lock()

    def adapter(self, n_lanes: int) -> LaneTickStep:
        with self._lock:
            a = self._adapters.get(n_lanes)
            if a is None:
                a = LaneTickStep(self._factory(n_lanes), n_lanes)
                self._adapters[n_lanes] = a
            return a


def is_overlap_step(step) -> bool:
    """Duck-typed detection of the overlapped tick contract: anything
    with ``stage``/``tick`` (a :class:`LaneTickStep`) takes the
    zero-copy path; a plain callable takes the blocking oracle path."""
    return callable(getattr(step, "stage", None)) \
        and callable(getattr(step, "tick", None))


__all__ = ["LaneTickStep", "TickBufferPool", "donation_supported",
           "fetch_valid", "is_overlap_step"]
