"""Multi-tenant lane scheduler: continuous batching across video streams.

The paper's five-layer network (§3.2, Fig. 2) serves ONE video: spout →
transmission estimator → atmospheric-light estimator → haze-free generator
→ monitor. Its §5 future work — "coordinating atmospheric light across
multiple videos" and a cluster that "scales with the actual workload" —
is this module: N live videos multiplexed onto L *lanes* of one
fixed-shape ``(L, B, H, W, 3)`` device batch, stepped by the vmapped
component chain (``core.pipeline.make_multi_stream_step``), so the fleet
scales with users instead of serializing them.

Layer mapping, per lane:

  layer 1 (spout)        — one ``Spout`` per admitted stream assigns ids
                           from that stream's restart-safe cursor;
  layers 2-4 (components)— all lanes share ONE compiled program per tick;
                           each lane's §3.3 EMA state is one row of the
                           lane-batched ``AtmoState`` (its own coherent A
                           trajectory, bit-identical to a solo serve);
  layer 5 (monitor)      — one ``Monitor`` per stream restores that
                           stream's order and applies the paper's 20 ms
                           reader-skip rule independently of its peers.

Scheduling is *continuous batching* in the serving-system sense: a stream
is admitted into the first free lane the moment one is available, an
exhausted stream is evicted at the tick it ends (state + cursor written
back to the ``StreamStateStore``), and the freed lane is reused by the
next pending stream in the same tick. Unoccupied lanes are padded with
``frame_id = -1`` batches, which the masked EMA scans treat as identity —
a dead lane's state rides through every step unchanged and emits nothing.

**Admission policy.** The pending queue is FIFO by default. A stream may
carry an optional *deadline* (a third tuple element, any comparable
number — e.g. epoch seconds or a priority rank): when lanes are scarce,
free lanes are granted earliest-deadline-first, deadline-less streams
rank after every deadlined one, and ties (equal deadlines, and the whole
no-deadline class) break by arrival order — so a real-time stream never
queues behind a batch backfill, and plain FIFO callers see the exact
pre-deadline behavior.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.normalize import (AtmoState, get_lane_state,
                                  init_atmo_state_lanes, set_lane_state)
from repro.stream.monitor import Monitor
from repro.stream.spout import FrameBatch, Spout
from repro.stream.state import StreamStateStore

# A stream to serve: (stream_id, iterable of (H, W, 3) frames) with an
# optional per-stream deadline — (stream_id, frames, deadline) — granting
# that stream earliest-deadline-first lane admission.
StreamEntry = Union[Tuple[str, Iterable[np.ndarray]],
                    Tuple[str, Iterable[np.ndarray], Optional[float]]]
# sink(stream_id, frame_id, frame) — called in per-stream ascending order.
MultiSink = Callable[[str, int, np.ndarray], None]


@dataclasses.dataclass
class StreamReport:
    """Per-stream serving outcome (mirrors ``elastic.ServeReport``)."""
    stream_id: str
    frames: int
    skipped: int
    wall_s: float

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass
class MultiServeReport:
    per_stream: Dict[str, StreamReport]
    frames: int          # total real frames stepped, all streams
    skipped: int         # total monitor skips, all streams
    wall_s: float
    n_lanes: int
    ticks: int           # device steps issued
    admissions: int      # streams admitted (== streams completed)

    @property
    def aggregate_fps(self) -> float:
        """Fleet throughput: total frames across streams per wall second."""
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0


class _Lane:
    """Host-side bookkeeping for one occupied lane."""
    __slots__ = ("stream_id", "it", "monitor", "mon_thread", "start",
                 "frames_done", "admitted_at")

    def __init__(self, stream_id: str, it, monitor: Monitor,
                 mon_thread: threading.Thread, start: int,
                 admitted_at: float):
        self.stream_id = stream_id
        self.it = it
        self.monitor = monitor
        self.mon_thread = mon_thread
        self.start = start
        self.frames_done = 0
        self.admitted_at = admitted_at


class MultiStreamScheduler:
    """Drives ``step(frames (L,B,H,W,3), ids (L,B), state) -> DehazeOutput``
    over many live streams with lane admission/eviction/reuse.

    ``step`` is typically ``jax.jit(make_multi_stream_step(cfg))``; the
    scheduler itself is model-agnostic — it only assumes the lane axis and
    the padding-id contract (``frame_id < 0`` slots touch nothing).
    """

    def __init__(self, step: Callable, store: StreamStateStore,
                 n_lanes: int, batch: int = 8, timeout_s: float = 0.020,
                 max_in_flight: int = 4, max_skipped_ids: int = 64):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self._step = step
        self.store = store
        self.n_lanes = n_lanes
        self.batch = batch
        self.timeout_s = timeout_s
        self.max_skipped_ids = max_skipped_ids
        self._sem = threading.Semaphore(max_in_flight)

    # -- lane lifecycle ----------------------------------------------------

    def _admit(self, lane_idx: int, sid: str, frames: Iterable[np.ndarray],
               packed: AtmoState, sink: Optional[MultiSink]) -> AtmoState:
        start = self.store.cursor(sid)

        def write(fid: int, payload: np.ndarray) -> None:
            if sink is not None:
                sink(sid, fid, payload)

        monitor = Monitor(write, timeout_s=self.timeout_s, start_frame=start,
                          max_skipped_ids=self.max_skipped_ids)
        mon_thread = threading.Thread(target=monitor.run, daemon=True)
        mon_thread.start()
        spout = Spout(frames, batch=self.batch, start_frame=start,
                      stream_id=sid)
        self._lanes[lane_idx] = _Lane(sid, iter(spout), monitor, mon_thread,
                                      start, time.perf_counter())
        self._admissions += 1
        return set_lane_state(packed, lane_idx, self.store.get(sid))

    def _evict(self, lane_idx: int, packed: AtmoState) -> None:
        """Stream ended: free the lane NOW, finalize in the background.

        The lane's final EMA state is a functional snapshot of the packed
        state (safe to read later even after the lane is reassigned), so
        the expensive parts — waiting for in-flight completions that may
        still hold frames for this stream's monitor, draining it, and the
        blocking ``device_get`` — run in a finalizer thread while the main
        loop keeps ticking with the lane already reused. This is what
        keeps high-churn workloads (many short clips) pipelined instead of
        stalling every tick on an eviction barrier."""
        lane = self._lanes[lane_idx]
        self._lanes[lane_idx] = None
        final_state = get_lane_state(packed, lane_idx)
        waits = list(self._inflight)
        # Stamp the stream's wall NOW: the finalizer below also waits on
        # other lanes' in-flight ticks, which is scheduler bookkeeping, not
        # this stream's service time.
        wall_s = time.perf_counter() - lane.admitted_at

        def finalize() -> None:
            for th in waits:
                th.join()
            lane.monitor.close()
            lane.mon_thread.join(timeout=5.0)
            lane.monitor.drain()
            self.store.update(lane.stream_id, jax.device_get(final_state),
                              lane.start + lane.frames_done)
            with self._report_lock:
                self._reports[lane.stream_id] = StreamReport(
                    stream_id=lane.stream_id, frames=lane.frames_done,
                    skipped=lane.monitor.stats.skipped, wall_s=wall_s)

        th = threading.Thread(target=finalize, daemon=True)
        th.start()
        self._finalizers.append(th)

    def _fill_lane(self, lane_idx: int, packed: AtmoState,
                   sink: Optional[MultiSink]
                   ) -> Tuple[Optional[FrameBatch], AtmoState]:
        """Next batch for a lane, chaining evictions and admissions: an
        exhausted stream is evicted and the lane immediately reused by the
        next pending stream (continuous batching)."""
        while True:
            if self._lanes[lane_idx] is None:
                if not self._pending:
                    return None, packed
                # EDF pop: (deadline, arrival) heap key — FIFO when no
                # stream carries a deadline (all keys (inf, arrival)).
                _, sid, frames = heapq.heappop(self._pending)
                packed = self._admit(lane_idx, sid, frames, packed, sink)
                # Keep the shared view current immediately: if the new
                # stream's iterator raises below, the error-path eviction
                # in run() must see THIS stream's state in the lane, not
                # the previous tenant's.
                self._packed = packed
            fb = next(self._lanes[lane_idx].it, None)
            if fb is not None:
                return fb, packed
            self._evict(lane_idx, packed)

    # -- the serve loop ----------------------------------------------------

    def run(self, streams: Iterable[StreamEntry],
            sink: Optional[MultiSink] = None) -> MultiServeReport:
        streams = list(streams)
        sids = [e[0] for e in streams]
        if len(set(sids)) != len(sids):
            # A duplicate id would race its predecessor's background
            # finalizer for the store cursor and the report slot. Resume a
            # stream with a second serve_many call instead — run() joins
            # all finalizers before returning, so the cursor is settled.
            dupes = sorted({s for s in sids if sids.count(s) > 1})
            raise ValueError(f"duplicate stream ids in one serve_many call: "
                             f"{dupes}")
        # Pending heap keyed (deadline, arrival): earliest-deadline-first
        # admission, deadline-less streams (key (inf, arrival)) after every
        # deadlined one and FIFO among themselves — with no deadlines at
        # all this is exactly the old FIFO deque.
        self._pending = []
        for arrival, entry in enumerate(streams):
            sid, frames = entry[0], entry[1]
            deadline = entry[2] if len(entry) > 2 and entry[2] is not None \
                else math.inf
            heapq.heappush(self._pending, ((deadline, arrival), sid, frames))
        self._lanes: List[Optional[_Lane]] = [None] * self.n_lanes
        self._inflight: List[threading.Thread] = []
        self._finalizers: List[threading.Thread] = []
        self._reports: Dict[str, StreamReport] = {}
        self._report_lock = threading.Lock()
        self._admissions = 0

        packed = init_atmo_state_lanes(self.n_lanes)
        pad_frames: Optional[np.ndarray] = None       # (B, H, W, 3) zeros
        pad_ids = np.full((self.batch,), -1, np.int32)
        ticks = 0
        t0 = time.perf_counter()

        try:
            ticks = self._tick_loop(packed, pad_frames, pad_ids, sink)
        finally:
            # Normal exit or mid-serve error (e.g. a mismatched-resolution
            # stream): evict every live lane so already-served streams
            # flush their monitors and persist state + cursor, then wait
            # out all completion/finalizer threads.
            for i in range(self.n_lanes):
                if self._lanes[i] is not None:
                    self._evict(i, self._packed)
            for th in self._inflight:
                th.join()
            for th in self._finalizers:
                th.join()
        wall = time.perf_counter() - t0
        reports = self._reports
        return MultiServeReport(
            per_stream=reports,
            frames=sum(r.frames for r in reports.values()),
            skipped=sum(r.skipped for r in reports.values()),
            wall_s=wall, n_lanes=self.n_lanes, ticks=ticks,
            admissions=self._admissions)

    def _tick_loop(self, packed: AtmoState, pad_frames: Optional[np.ndarray],
                   pad_ids: np.ndarray, sink: Optional[MultiSink]) -> int:
        ticks = 0
        self._packed = packed

        while True:
            fbs: List[Optional[FrameBatch]] = []
            for i in range(self.n_lanes):
                fb, packed = self._fill_lane(i, packed, sink)
                self._packed = packed
                fbs.append(fb)
            live = [fb for fb in fbs if fb is not None]
            if not live:
                break

            if pad_frames is None:
                pad_frames = np.zeros_like(live[0].frames)
            for fb in live:
                if fb.frames.shape != pad_frames.shape:
                    raise ValueError(
                        f"stream {fb.stream_id!r} batch shape "
                        f"{fb.frames.shape} != lane shape {pad_frames.shape};"
                        " all multiplexed streams must share (H, W) and the"
                        " scheduler's frame batch")

            frames = np.stack([fb.frames if fb is not None else pad_frames
                               for fb in fbs])
            ids = np.stack([fb.frame_ids if fb is not None else pad_ids
                            for fb in fbs])
            metas = [(i, self._lanes[i].monitor, fb.frame_ids, fb.n_valid)
                     for i, fb in enumerate(fbs) if fb is not None]
            for i, fb in enumerate(fbs):
                if fb is not None:
                    self._lanes[i].frames_done += fb.n_valid

            self._sem.acquire()
            out = self._step(frames, ids, packed)
            packed = out.state          # device-resident, possibly in flight
            self._packed = packed
            th = threading.Thread(target=self._complete,
                                  args=(metas, out), daemon=True)
            th.start()
            self._inflight.append(th)
            self._inflight = [t for t in self._inflight if t.is_alive()]
            ticks += 1

        return ticks

    def _complete(self, metas, out) -> None:
        try:
            frames = np.asarray(out.frames)    # blocks until device done
            for lane_idx, monitor, frame_ids, n_valid in metas:
                for b in range(n_valid):
                    monitor.put(int(frame_ids[b]), frames[lane_idx, b])
        finally:
            self._sem.release()
