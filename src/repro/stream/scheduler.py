"""Multi-tenant lane scheduler: continuous batching across video streams.

The paper's five-layer network (§3.2, Fig. 2) serves ONE video: spout →
transmission estimator → atmospheric-light estimator → haze-free generator
→ monitor. Its §5 future work — "coordinating atmospheric light across
multiple videos" and a cluster that "scales with the actual workload" —
is this module: N live videos multiplexed onto L *lanes* of one
fixed-shape ``(L, B, H, W, 3)`` device batch, stepped by the vmapped
component chain (``core.pipeline.make_multi_stream_step``), so the fleet
scales with users instead of serializing them.

Layer mapping, per lane:

  layer 1 (spout)        — one ``Spout`` per admitted stream assigns ids
                           from that stream's restart-safe cursor;
  layers 2-4 (components)— all lanes share ONE compiled program per tick;
                           each lane's §3.3 EMA state is one row of the
                           lane-batched ``AtmoState`` (its own coherent A
                           trajectory, bit-identical to a solo serve);
  layer 5 (monitor)      — one ``Monitor`` per stream restores that
                           stream's order and applies the paper's 20 ms
                           reader-skip rule independently of its peers.

Scheduling is *continuous batching* in the serving-system sense: a stream
is admitted into the first free lane the moment one is available, an
exhausted stream is evicted at the tick it ends (state + cursor written
back to the ``StreamStateStore``), and the freed lane is reused by the
next pending stream in the same tick. Unoccupied lanes are padded with
``frame_id = -1`` batches, which the masked EMA scans treat as identity —
a dead lane's state rides through every step unchanged and emits nothing.

**Requests.** A stream to serve is a :class:`StreamRequest` — stream id,
frame iterable, optional ``deadline`` and optional ``priority``. Legacy
positional tuples (``(sid, frames)`` / ``(sid, frames, deadline)``) are
coerced through :func:`_coerce_request` with a ``DeprecationWarning`` and
keep working this release.

**Admission policy.** The pending queue is ordered by
``(priority, deadline, arrival)``: lower priority values admit first
(default 0; negative jumps the whole default class), then earliest
deadline first within a priority class (deadline-less streams rank after
every deadlined one), and ties break by arrival order — so plain FIFO
callers see the exact pre-deadline behavior and a real-time stream never
queues behind a batch backfill.

**Deadline-aware eviction** (``evict_tardy_after``): a stream that is
*past its deadline* (``clock() >= deadline``) and has held a lane for
that many ticks while other streams queue is preempted — its cursor and
EMA state are checkpointed (the same restart-safe snapshot a crash would
use) and it requeues as deadline-less (it already missed its deadline, so
it loses EDF privilege and falls behind the waiting streams; FIFO among
its peers). Re-admission is gated on the old monitor draining, so the
sink still sees every frame exactly once, in order, and the resumed lane
continues the identical EMA trajectory.

**Elastic lane autoscaling** (``autoscaler``): the lane count walks a
precompiled ladder (``stream.autoscale``) from pending-queue depth and
occupancy. A ladder switch repacks the live lane state row-for-row
(``unpack_atmo_states`` → compact → ``pack``-style ``set_lane_state``),
so no stream loses its EMA trajectory or emits a frame twice, and the
target rung's step is always pre-warmed on a background thread — the
switch itself is a dictionary lookup, never a trace on the serve thread.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
import warnings
from typing import (Callable, Dict, Iterable, List, Optional, Tuple, Union)

import jax
import numpy as np

from repro.core.normalize import (AtmoState, get_lane_state,
                                  init_atmo_state_lanes, set_lane_state,
                                  unpack_atmo_states)
from repro.stream.iobuf import fetch_valid, is_overlap_step
from repro.stream.monitor import DEADLINE_CLOCK, Monitor
from repro.stream.spout import FrameBatch, Spout
from repro.stream.state import StreamStateStore


@dataclasses.dataclass
class StreamRequest:
    """One stream to serve.

    ``frames`` is an iterable of ``(H, W, 3)`` float frames. ``deadline``
    is a value on the scheduler's ``clock`` timebase — by default
    :data:`repro.stream.monitor.DEADLINE_CLOCK` (``time.monotonic``
    seconds, NOT epoch seconds: produce deadlines as
    ``DEADLINE_CLOCK() + budget_s``, and note monotonic values are only
    comparable within one process). It requests earliest-deadline-first
    lane admission and, when eviction is enabled, marks when the stream
    counts as tardy. ``priority`` (lower = earlier, default 0) orders
    ahead of the deadline: a negative priority admits before the whole
    default class regardless of deadlines.
    """
    stream_id: str
    frames: Iterable[np.ndarray]
    deadline: Optional[float] = None
    priority: Optional[int] = None

    def admission_key(self, arrival: int) -> Tuple[float, float, int]:
        prio = 0 if self.priority is None else self.priority
        deadline = math.inf if self.deadline is None else self.deadline
        return (prio, deadline, arrival)


# Legacy request forms still accepted by ``serve_many`` / ``run``:
# (stream_id, frames) or (stream_id, frames, deadline). Coerced through
# ``_coerce_request`` with a DeprecationWarning.
StreamEntry = Union[StreamRequest,
                    Tuple[str, Iterable[np.ndarray]],
                    Tuple[str, Iterable[np.ndarray], Optional[float]]]
# sink(stream_id, frame_id, frame) — called in per-stream ascending order.
MultiSink = Callable[[str, int, np.ndarray], None]


def _coerce_request(entry: StreamEntry) -> StreamRequest:
    """Normalize a caller-supplied stream entry to a ``StreamRequest``.

    Positional tuples were the whole API before the request dataclass;
    they keep working this release but warn — the tuple union had already
    grown a third overload and the autoscaler needs named fields to grow
    more (priority, per-stream knobs) without another positional slot.
    """
    if isinstance(entry, StreamRequest):
        return entry
    if isinstance(entry, (tuple, list)) and len(entry) in (2, 3):
        warnings.warn(
            "positional (stream_id, frames[, deadline]) stream entries are "
            "deprecated and will be removed in v0.3; pass "
            "stream.StreamRequest(stream_id, frames, deadline=..., "
            "priority=...) instead",
            DeprecationWarning, stacklevel=3)
        return StreamRequest(entry[0], entry[1],
                             entry[2] if len(entry) > 2 else None)
    raise TypeError(
        f"expected StreamRequest or (stream_id, frames[, deadline]) tuple, "
        f"got {type(entry).__name__}")


@dataclasses.dataclass
class StreamReport:
    """Per-stream serving outcome (one row of ``ServeReport.per_stream``)."""
    stream_id: str
    frames: int
    skipped: int
    wall_s: float

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass
class ServeReport:
    """Unified serving outcome: ``serve`` is the single-lane view of
    ``serve_many`` — one report type, ``per_stream`` populated by both, so
    callers never branch on which server method produced it.

    ``ladder_switches`` counts committed autoscale rung changes and
    ``evictions`` counts deadline preemptions (both 0 outside autoscale /
    eviction serving). The fleet tier (``stream.fleet``) aggregates
    per-host reports into one: ``n_hosts`` > 1 then, ``n_lanes`` sums the
    hosts' lanes, ``spillovers`` counts admissions that landed off the
    stream's preferred host because its lanes were full, and
    ``migrations`` counts sticky-placement violations — by construction
    always 0 (a live stream's EMA state never moves hosts); it is
    reported so serving code can *assert* that.
    """
    per_stream: Dict[str, StreamReport]
    frames: int          # total real frames stepped, all streams
    skipped: int         # total monitor skips, all streams
    wall_s: float
    n_lanes: int         # lanes at the end of the call (1 worker = 1 lane)
    ticks: int           # device steps issued
    admissions: int = 0  # lane admissions (>= streams when eviction requeues)
    ladder_switches: int = 0
    switch_wall_s: float = 0.0   # serve-thread seconds spent in rung switches
    evictions: int = 0
    n_hosts: int = 1
    spillovers: int = 0
    migrations: int = 0
    # Ladder rungs whose warm-up exhausted its attempts (autoscale serving
    # only; summed across hosts by the fleet tier). Non-zero means part of
    # the ladder is unreachable — serving that *expects* switches treats
    # it as a hard error (see launch/serve.py --expect-switches).
    warm_failures: int = 0
    # Ticks that took the zero-copy overlapped path (device-resident lane
    # buffer + donated state, README §Tick I/O & overlap). 0 on the
    # blocking path; a serve that *expected* overlap treats
    # overlap_ticks < ticks as a hard error (launch/serve.py
    # --expect-overlap — the silent-fallback gate).
    overlap_ticks: int = 0
    # Completion/finalizer threads still alive when the shutdown join
    # timed out. Always 0 in a healthy serve; non-zero means a monitor or
    # device fetch wedged and the report was returned without it.
    stragglers: int = 0
    # Bytes actually fetched device->host by completions (valid-only
    # slices on the overlapped path; whole batches, padding included, on
    # the blocking path — the bench rows report the ratio).
    d2h_bytes: int = 0
    # Per-phase serve-loop seconds on the scheduler's injectable clock:
    # "host_stage_s" (lane H2D staging / batch assembly), "device_step_s"
    # (step dispatch + simulated device time), "deliver_s" (completion
    # threads' D2H + monitor delivery, summed across threads).
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def fps(self) -> float:
        """Throughput: total frames across streams per wall second."""
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0

    # Fleet-level alias; identical to fps, kept for serving-code idiom.
    aggregate_fps = fps

    @property
    def n_workers(self) -> int:
        """Back-compat alias from the pre-unification single-stream report."""
        return self.n_lanes


# Back-compat alias: the multi-stream report is the report.
MultiServeReport = ServeReport


@dataclasses.dataclass
class _Resume:
    """Checkpoint a preempted stream carries back through the pending heap.

    Admission reads state + cursor from here (not the store — the store
    write happens on the background finalizer, and racing it would resume
    from a stale cursor). ``barrier`` is set when the old monitor has
    drained: re-admission waits on it so the sink's per-stream ordering
    survives the preemption."""
    state: AtmoState
    cursor: int
    barrier: threading.Event


class _Lane:
    """Host-side bookkeeping for one occupied lane."""
    __slots__ = ("request", "raw_it", "it", "monitor", "mon_thread", "start",
                 "frames_done", "ticks", "admitted_at")

    def __init__(self, request: StreamRequest, raw_it, it, monitor: Monitor,
                 mon_thread: threading.Thread, start: int,
                 admitted_at: float):
        self.request = request
        self.raw_it = raw_it          # the underlying frame iterator (requeue)
        self.it = it                  # the Spout batch iterator
        self.monitor = monitor
        self.mon_thread = mon_thread
        self.start = start
        self.frames_done = 0
        self.ticks = 0
        self.admitted_at = admitted_at

    @property
    def stream_id(self) -> str:
        return self.request.stream_id


class MultiStreamScheduler:
    """Drives ``step(frames (L,B,H,W,3), ids (L,B), state) -> DehazeOutput``
    over many live streams with lane admission/eviction/reuse.

    ``step`` is typically ``jax.jit(make_multi_stream_step(cfg))``; the
    scheduler itself is model-agnostic — it only assumes the lane axis and
    the padding-id contract (``frame_id < 0`` slots touch nothing).

    ``autoscaler`` (a ``stream.autoscale.LaneAutoscaler``) makes the lane
    count elastic: ``n_lanes`` then gives the *starting* rung and the
    scheduler walks the precompiled ladder. ``evict_tardy_after`` enables
    deadline-aware preemption (see the module docstring); ``clock`` is
    what deadlines are compared against — default
    :data:`repro.stream.monitor.DEADLINE_CLOCK` (``time.monotonic``), the
    same timebase the Monitor uses, so EDF ordering and tardy eviction
    cannot misfire across an NTP wall-clock step.
    """

    def __init__(self, step: Callable, store: StreamStateStore,
                 n_lanes: int, batch: int = 8, timeout_s: float = 0.020,
                 max_in_flight: int = 4, max_skipped_ids: int = 64,
                 autoscaler=None, evict_tardy_after: Optional[int] = None,
                 clock: Callable[[], float] = DEADLINE_CLOCK,
                 tick_delay_s: float = 0.0,
                 shutdown_timeout_s: float = 30.0):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self._step = step
        self.store = store
        self.n_lanes = n_lanes
        self.batch = batch
        self.timeout_s = timeout_s
        self.max_skipped_ids = max_skipped_ids
        self._sem = threading.Semaphore(max_in_flight)
        self._autoscaler = autoscaler
        self._evict_tardy_after = evict_tardy_after
        self._clock = clock
        # Bound on the shutdown join over completion/finalizer threads: a
        # wedged monitor or device fetch must not hang run() forever — the
        # report returns with the straggler counted instead.
        self._shutdown_timeout_s = shutdown_timeout_s
        # Simulated per-tick device service time (seconds) on the serve
        # thread. 0 disables. The fleet benchmarks use this to model
        # device-bound hosts on the CPU container: with a fixed per-tick
        # cost, aggregate fps scales with how many hosts tick in parallel.
        self._tick_delay_s = tick_delay_s

    # -- lane lifecycle ----------------------------------------------------

    def _admit(self, lane_idx: int, req: StreamRequest,
               resume: Optional[_Resume], packed: AtmoState,
               sink: Optional[MultiSink]) -> AtmoState:
        sid = req.stream_id
        if resume is not None:
            start, state = resume.cursor, resume.state
        else:
            start, state = self.store.cursor(sid), self.store.get(sid)

        def write(fid: int, payload: np.ndarray) -> None:
            if sink is not None:
                sink(sid, fid, payload)

        monitor = Monitor(write, timeout_s=self.timeout_s, start_frame=start,
                          max_skipped_ids=self.max_skipped_ids)
        mon_thread = threading.Thread(target=monitor.run, daemon=True)
        mon_thread.start()
        raw_it = iter(req.frames)
        spout = Spout(raw_it, batch=self.batch, start_frame=start,
                      stream_id=sid)
        self._lanes[lane_idx] = _Lane(req, raw_it, iter(spout), monitor,
                                      mon_thread, start, time.perf_counter())
        self._admissions += 1
        return set_lane_state(packed, lane_idx, state)

    def _evict(self, lane_idx: int, packed: AtmoState,
               requeue: bool = False) -> None:
        """Free the lane NOW, finalize in the background.

        The lane's final EMA state is a functional snapshot of the packed
        state (safe to read later even after the lane is reassigned), so
        the expensive parts — waiting for in-flight completions that may
        still hold frames for this stream's monitor, draining it, and the
        blocking ``device_get`` — run in a finalizer thread while the main
        loop keeps ticking with the lane already reused. This is what
        keeps high-churn workloads (many short clips) pipelined instead of
        stalling every tick on an eviction barrier.

        ``requeue=True`` is the deadline-preemption path: the stream goes
        back onto the pending heap as deadline-less, carrying a ``_Resume``
        checkpoint (this same snapshot + cursor) whose barrier the
        finalizer sets once the old monitor has drained."""
        lane = self._lanes[lane_idx]
        self._lanes[lane_idx] = None
        final_state = get_lane_state(packed, lane_idx)
        cursor = lane.start + lane.frames_done
        waits = list(self._inflight)
        # Stamp the stream's wall NOW: the finalizer below also waits on
        # other lanes' in-flight ticks, which is scheduler bookkeeping, not
        # this stream's service time.
        wall_s = time.perf_counter() - lane.admitted_at
        barrier = threading.Event() if requeue else None

        def finalize() -> None:
            for th in waits:
                th.join()
            lane.monitor.close()
            lane.mon_thread.join(timeout=5.0)
            lane.monitor.drain()
            self.store.update(lane.stream_id, jax.device_get(final_state),
                              cursor)
            with self._report_lock:
                # A preempted stream serves in several segments: the
                # report accumulates frames/skips/wall across them.
                prev = self._reports.get(lane.stream_id)
                frames = lane.frames_done + (prev.frames if prev else 0)
                skipped = lane.monitor.stats.skipped \
                    + (prev.skipped if prev else 0)
                self._reports[lane.stream_id] = StreamReport(
                    stream_id=lane.stream_id, frames=frames, skipped=skipped,
                    wall_s=wall_s + (prev.wall_s if prev else 0.0))
            if barrier is not None:
                barrier.set()

        th = threading.Thread(target=finalize, daemon=True)
        th.start()
        self._finalizers.append(th)

        if requeue:
            self._evictions += 1
            # Past-deadline streams lose EDF privilege: requeue as
            # deadline-less (priority preserved), FIFO behind the class.
            req = StreamRequest(lane.stream_id, lane.raw_it, deadline=None,
                                priority=lane.request.priority)
            arrival = self._arrival
            self._arrival += 1
            self._push_requeue(req.admission_key(arrival), req,
                               _Resume(final_state, cursor, barrier))

    # -- pending-queue access (the fleet tier overrides these four to talk
    # -- to a shared cross-host queue instead of the local heap) -----------

    def _queue_depth(self) -> int:
        """Streams waiting for a lane (this scheduler's view)."""
        return len(self._pending)

    def _push_requeue(self, key, req: StreamRequest,
                      resume: "_Resume") -> None:
        """Return a preempted stream to the pending queue."""
        heapq.heappush(self._pending, (key, req, resume))

    def _wait_pending(self) -> bool:
        """No live lanes: ``True`` = pending work may still arrive, wait
        briefly and retry the admission loop; ``False`` = drained, exit.

        Single-host: every pending entry is a preempted stream still
        draining its previous segment's monitor — wait for the earliest
        barrier."""
        if self._pending:
            self._pending[0][2].barrier.wait(timeout=0.1)
            return True
        return False

    def _pop_ready(self):
        """Pop the best pending entry whose resume barrier (if any) is set;
        entries still draining their previous segment stay queued."""
        deferred, entry = [], None
        while self._pending:
            cand = heapq.heappop(self._pending)
            if cand[2] is None or cand[2].barrier.is_set():
                entry = cand
                break
            deferred.append(cand)
        for d in deferred:
            heapq.heappush(self._pending, d)
        return entry

    def _fill_lane(self, lane_idx: int, packed: AtmoState,
                   sink: Optional[MultiSink]
                   ) -> Tuple[Optional[FrameBatch], AtmoState]:
        """Next batch for a lane, chaining evictions and admissions: an
        exhausted stream is evicted and the lane immediately reused by the
        next pending stream (continuous batching)."""
        while True:
            if self._lanes[lane_idx] is None:
                entry = self._pop_ready()
                if entry is None:
                    return None, packed
                _, req, resume = entry
                packed = self._admit(lane_idx, req, resume, packed, sink)
                # Keep the shared view current immediately: if the new
                # stream's iterator raises below, the error-path eviction
                # in run() must see THIS stream's state in the lane, not
                # the previous tenant's.
                self._packed = packed
            fb = next(self._lanes[lane_idx].it, None)
            if fb is not None:
                return fb, packed
            self._evict(lane_idx, packed)

    # -- elastic lane count ------------------------------------------------

    def _switch_lanes(self, new_n: int, packed: AtmoState) -> AtmoState:
        """Repack live lane state onto a ``new_n``-lane batch.

        Occupied lanes compact to the low indices; each survivor's EMA
        state row moves with it (a functional gather/scatter — bit-exact,
        so per-stream A trajectories are indistinguishable from a serve
        that never switched). Host bookkeeping (_Lane objects, monitors,
        spouts) moves by reference. In-flight ticks are untouched: they
        hold the *old* packed arrays and their metas carry monitor
        references, not lane indices into the new layout."""
        occ = [i for i, ln in enumerate(self._lanes) if ln is not None]
        if len(occ) > new_n:
            raise ValueError(
                f"cannot shrink to {new_n} lanes with {len(occ)} occupied")
        states = unpack_atmo_states(packed)
        new_packed = init_atmo_state_lanes(new_n)
        for j, i in enumerate(occ):
            new_packed = set_lane_state(new_packed, j, states[i])
        self._lanes = [self._lanes[i] for i in occ] \
            + [None] * (new_n - len(occ))
        self.n_lanes = new_n
        return new_packed

    def _maybe_autoscale(self, packed: AtmoState) -> AtmoState:
        occupied = sum(1 for ln in self._lanes if ln is not None)
        target = self._autoscaler.observe(self._queue_depth(), occupied)
        if target is None or target == self.n_lanes or occupied > target:
            return packed
        t0 = time.perf_counter()
        # Dictionary lookup by contract: observe() only offers warm rungs.
        self._step = self._autoscaler.step_for(target)
        packed = self._switch_lanes(target, packed)
        self._autoscaler.commit(target, time.perf_counter() - t0)
        return packed

    def _evict_tardy(self, packed: AtmoState) -> None:
        """Deadline-aware preemption: a past-deadline stream that has held
        a lane for ``evict_tardy_after`` ticks while others queue is
        checkpointed and requeued (see ``_evict(requeue=True)``)."""
        for i, lane in enumerate(self._lanes):
            if self._queue_depth() == 0:
                return
            if (lane is not None and lane.request.deadline is not None
                    and lane.ticks >= self._evict_tardy_after
                    and self._clock() >= lane.request.deadline):
                self._evict(i, packed, requeue=True)

    # -- the serve loop ----------------------------------------------------

    def run(self, streams: Iterable[StreamEntry],
            sink: Optional[MultiSink] = None) -> ServeReport:
        requests = [_coerce_request(e) for e in streams]
        sids = [r.stream_id for r in requests]
        if len(set(sids)) != len(sids):
            # A duplicate id would race its predecessor's background
            # finalizer for the store cursor and the report slot. Resume a
            # stream with a second serve_many call instead — run() joins
            # all finalizers before returning, so the cursor is settled.
            dupes = sorted({s for s in sids if sids.count(s) > 1})
            raise ValueError(f"duplicate stream ids in one serve_many call: "
                             f"{dupes}")
        # Pending heap keyed (priority, deadline, arrival): lower priority
        # first, then earliest-deadline-first within the class,
        # deadline-less streams (deadline inf) after every deadlined one
        # and FIFO among themselves — with no deadlines or priorities this
        # is exactly the old FIFO deque.
        self._pending: List[tuple] = []
        for arrival, req in enumerate(requests):
            heapq.heappush(self._pending,
                           (req.admission_key(arrival), req, None))
        self._arrival = len(requests)
        self._lanes: List[Optional[_Lane]] = [None] * self.n_lanes
        self._inflight: List[threading.Thread] = []
        self._finalizers: List[threading.Thread] = []
        self._reports: Dict[str, StreamReport] = {}
        self._report_lock = threading.Lock()
        self._admissions = 0
        self._evictions = 0
        self._overlap_ticks = 0
        self._stragglers = 0
        self._d2h_bytes = 0
        self._phases: Dict[str, float] = {
            "host_stage_s": 0.0, "device_step_s": 0.0, "deliver_s": 0.0}

        packed = init_atmo_state_lanes(self.n_lanes)
        pad_frames: Optional[np.ndarray] = None       # (B, H, W, 3) zeros
        pad_ids = np.full((self.batch,), -1, np.int32)
        ticks = 0
        t0 = time.perf_counter()

        try:
            ticks = self._tick_loop(packed, pad_frames, pad_ids, sink)
        finally:
            # Normal exit or mid-serve error (e.g. a mismatched-resolution
            # stream): evict every live lane so already-served streams
            # flush their monitors and persist state + cursor, then wait
            # out all completion/finalizer threads.
            for i in range(len(self._lanes)):
                if self._lanes[i] is not None:
                    self._evict(i, self._packed)
            # Bounded join: the old code joined without a timeout, so a
            # wedged completion/finalizer daemon hung run() forever (and a
            # fast exit silently leaked them). One deadline covers the
            # whole set; survivors are counted, not waited out.
            deadline = time.perf_counter() + self._shutdown_timeout_s
            for th in self._inflight + self._finalizers:
                th.join(timeout=max(0.0, deadline - time.perf_counter()))
                if th.is_alive():
                    self._stragglers += 1
        wall = time.perf_counter() - t0
        reports = self._reports
        return ServeReport(
            per_stream=reports,
            frames=sum(r.frames for r in reports.values()),
            skipped=sum(r.skipped for r in reports.values()),
            wall_s=wall, n_lanes=self.n_lanes, ticks=ticks,
            admissions=self._admissions,
            ladder_switches=len(self._autoscaler.switches)
            if self._autoscaler is not None else 0,
            switch_wall_s=sum(s["wall_s"]
                              for s in self._autoscaler.switches)
            if self._autoscaler is not None else 0.0,
            evictions=self._evictions,
            warm_failures=self._autoscaler.warm_failures
            if self._autoscaler is not None else 0,
            overlap_ticks=self._overlap_ticks,
            stragglers=self._stragglers,
            d2h_bytes=self._d2h_bytes,
            phases=dict(self._phases))

    def _tick_loop(self, packed: AtmoState, pad_frames: Optional[np.ndarray],
                   pad_ids: np.ndarray, sink: Optional[MultiSink]) -> int:
        ticks = 0
        self._packed = packed

        while True:
            if self._evict_tardy_after is not None:
                self._evict_tardy(packed)
            fbs: List[Optional[FrameBatch]] = []
            for i in range(len(self._lanes)):
                fb, packed = self._fill_lane(i, packed, sink)
                self._packed = packed
                fbs.append(fb)
            live = [fb for fb in fbs if fb is not None]
            if not live:
                if self._wait_pending():
                    continue
                break

            if pad_frames is None:
                pad_frames = np.zeros_like(live[0].frames)
            if self._autoscaler is not None:
                self._autoscaler.ensure_warming(pad_frames.shape,
                                                pad_frames.dtype)
            for fb in live:
                if fb.frames.shape != pad_frames.shape:
                    raise ValueError(
                        f"stream {fb.stream_id!r} batch shape "
                        f"{fb.frames.shape} != lane shape {pad_frames.shape};"
                        " all multiplexed streams must share (H, W) and the"
                        " scheduler's frame batch")

            overlap = is_overlap_step(self._step)
            t_stage = self._clock()
            if overlap:
                # Zero-copy path: upload only the live lanes into the
                # persistent device buffer (padding lanes keep stale rows
                # — id-masked from the EMA, never fetched). device_put +
                # the donated splice dispatch asynchronously, so this H2D
                # overlaps the in-flight tick's compute — which is why it
                # runs BEFORE the in-flight window acquire below.
                for i, fb in enumerate(fbs):
                    if fb is not None:
                        self._step.stage(i, fb.frames)
                frames = None
            else:
                frames = np.stack([fb.frames if fb is not None else
                                   pad_frames for fb in fbs])
            ids = np.stack([fb.frame_ids if fb is not None else pad_ids
                            for fb in fbs])
            self._phases["host_stage_s"] += self._clock() - t_stage
            metas = [(i, self._lanes[i].monitor, fb.frame_ids, fb.n_valid)
                     for i, fb in enumerate(fbs) if fb is not None]
            for i, fb in enumerate(fbs):
                if fb is not None:
                    self._lanes[i].frames_done += fb.n_valid
                    self._lanes[i].ticks += 1

            self._sem.acquire()
            t_step = self._clock()
            if overlap:
                # The state input is donated into this call: every read
                # of `packed` (eviction snapshots, rung repacks) was
                # dispatched before it, and nothing touches it after.
                out = self._step.tick(ids, packed)
                self._overlap_ticks += 1
            else:
                out = self._step(frames, ids, packed)
            packed = out.state          # device-resident, possibly in flight
            self._packed = packed
            if self._tick_delay_s > 0.0:
                time.sleep(self._tick_delay_s)
            self._phases["device_step_s"] += self._clock() - t_step
            th = threading.Thread(target=self._complete,
                                  args=(metas, out, overlap), daemon=True)
            th.start()
            self._inflight.append(th)
            self._inflight = [t for t in self._inflight if t.is_alive()]
            ticks += 1

            if self._autoscaler is not None:
                packed = self._maybe_autoscale(packed)
                self._packed = packed

        return ticks

    def _complete(self, metas, out, overlap: bool = False) -> None:
        try:
            t0 = self._clock()
            d2h = 0
            if overlap:
                # Valid-only D2H: per live lane, slice on device and fetch
                # just its real frames — padding lanes (and the padded
                # tail of live ones) never cross the wire.
                for lane_idx, monitor, frame_ids, n_valid in metas:
                    lane_frames = fetch_valid(out.frames, n_valid,
                                              lane=lane_idx)
                    d2h += lane_frames.nbytes
                    for b in range(n_valid):
                        monitor.put(int(frame_ids[b]), lane_frames[b])
            else:
                frames = np.asarray(out.frames)  # blocks until device done
                d2h += frames.nbytes
                for lane_idx, monitor, frame_ids, n_valid in metas:
                    for b in range(n_valid):
                        monitor.put(int(frame_ids[b]), frames[lane_idx, b])
            dt = self._clock() - t0
            with self._report_lock:
                self._d2h_bytes += d2h
                self._phases["deliver_s"] += dt
        finally:
            self._sem.release()
