"""Keyed per-stream state store (paper §3.3 state + §5 future work).

Holds the shared atmospheric-light state and the frame cursor for every
live video stream (the paper's future-work item — coordinating A across
multiple videos — falls out of keying the store by stream id). The store
is a plain pytree-of-pytrees, so it checkpoints through
``repro.checkpoint`` and a restarted server continues the *same* coherent
A trajectory it crashed on.
"""
from __future__ import annotations

import threading
from typing import Dict

import jax

from repro.core.normalize import AtmoState, init_atmo_state


class StreamStateStore:
    def __init__(self):
        self._lock = threading.Lock()
        self._states: Dict[str, AtmoState] = {}
        self._cursors: Dict[str, int] = {}

    def get(self, stream_id: str) -> AtmoState:
        with self._lock:
            if stream_id not in self._states:
                self._states[stream_id] = init_atmo_state()
                self._cursors[stream_id] = 0
            return self._states[stream_id]

    def update(self, stream_id: str, state: AtmoState, cursor: int) -> None:
        with self._lock:
            self._states[stream_id] = state
            self._cursors[stream_id] = cursor

    def cursor(self, stream_id: str) -> int:
        with self._lock:
            return self._cursors.get(stream_id, 0)

    # -- checkpoint integration --------------------------------------------

    def to_pytree(self):
        with self._lock:
            keys = sorted(self._states)
            return {
                "keys": list(keys),
                "states": [jax.device_get(self._states[k]) for k in keys],
                "cursors": [self._cursors[k] for k in keys],
            }

    @classmethod
    def from_pytree(cls, tree) -> "StreamStateStore":
        store = cls()
        for k, s, c in zip(tree["keys"], tree["states"], tree["cursors"]):
            store._states[k] = s
            store._cursors[k] = int(c)
        return store
