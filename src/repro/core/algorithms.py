"""The three generic dehazing components (paper §3.1) + DCP/CAP instances.

Component protocol (all batched over leading frame axes, images NHWC):

  TransmissionEstimator:  (frames, A_saved, cfg) -> t_raw      (paper Fig. 3 box 1)
  AtmosphericLightEstimator: (frames, t_raw, cfg) -> A_new     (paper Fig. 3 box 2)
  HazeFreeGenerator:      (frames, t, A, cfg) -> J             (paper Fig. 3 box 3)

The estimators are black boxes to the framework (paper: "the detail of how
to compute the transmission map is a black box") — new algorithms register
via ``register_algorithm``. DCP Eq. 3 and CAP Eq. 4 ship as the two
reference instantiations, exactly as in the paper.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.core.config import DehazeConfig
from repro.kernels import ops
from repro.kernels.ref import LUMA_WEIGHTS

TransmissionEstimator = Callable[[jnp.ndarray, jnp.ndarray, DehazeConfig], jnp.ndarray]


def luminance(img: jnp.ndarray) -> jnp.ndarray:
    """Rec.601 luma, used as the guided-filter guide."""
    w = jnp.asarray(LUMA_WEIGHTS, img.dtype)
    return img @ w


# ---------------------------------------------------------------------------
# Transmission map estimators (component 1)
# ---------------------------------------------------------------------------

def transmission_dcp(frames: jnp.ndarray, a_saved: jnp.ndarray,
                     cfg: DehazeConfig) -> jnp.ndarray:
    """DCP, paper Eq. 3: t = 1 - ω · min_Ω min_c I^c/A^c.

    ``a_saved`` is the *shared* atmospheric light from the update strategy
    (paper §3.3 — the T-estimator runs before the A refresh and therefore
    uses the saved A_k; bootstrap is white light).
    """
    a = jnp.maximum(a_saved, 1e-3)                    # avoid blow-up
    norm = frames / a[..., None, None, :]
    dark = ops.dark_channel(norm, cfg.patch_radius, cfg.kernel_mode)
    return (1.0 - cfg.omega * dark).astype(frames.dtype)


def transmission_cap(frames: jnp.ndarray, a_saved: jnp.ndarray,
                     cfg: DehazeConfig) -> jnp.ndarray:
    """CAP, paper Eq. 4: t = exp(-β (ω0 + ω1 v + ω2 s)), min-filtered depth."""
    del a_saved                                        # CAP's t is A-free
    d = ops.cap_depth(frames, cfg.cap_w0, cfg.cap_w1, cfg.cap_w2)
    d = ops.min_filter_2d(d, cfg.patch_radius, cfg.kernel_mode)
    return jnp.exp(-cfg.beta * d).astype(frames.dtype)


_ALGORITHMS: Dict[str, TransmissionEstimator] = {}


def register_algorithm(name: str, estimator: TransmissionEstimator) -> None:
    _ALGORITHMS[name] = estimator


def get_transmission_estimator(name: str) -> TransmissionEstimator:
    try:
        return _ALGORITHMS[name]
    except KeyError:
        raise KeyError(f"unknown dehazing algorithm {name!r}; "
                       f"registered: {sorted(_ALGORITHMS)}") from None


register_algorithm("dcp", transmission_dcp)
register_algorithm("cap", transmission_cap)


# ---------------------------------------------------------------------------
# Atmospheric light estimator (component 2) — common to all algorithms
# ---------------------------------------------------------------------------

def estimate_atmospheric_light(frames: jnp.ndarray, t_raw: jnp.ndarray,
                               cfg: DehazeConfig) -> jnp.ndarray:
    """Paper Eq. 5/6: A = I at the pixel(s) of minimum raw transmission."""
    return ops.atmospheric_light(frames, t_raw, cfg.topk, cfg.kernel_mode)


# ---------------------------------------------------------------------------
# Transmission refinement (guided filter, He et al. [28])
# ---------------------------------------------------------------------------

def refine_transmission(frames: jnp.ndarray, t_raw: jnp.ndarray,
                        cfg: DehazeConfig) -> jnp.ndarray:
    if not cfg.refine:
        return t_raw
    guide = luminance(frames)
    t = ops.guided_filter(guide, t_raw, cfg.gf_radius, cfg.gf_eps,
                          cfg.kernel_mode)
    return jnp.clip(t, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Haze-free image generator (component 3)
# ---------------------------------------------------------------------------

def generate_haze_free(frames: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray,
                       cfg: DehazeConfig) -> jnp.ndarray:
    """Paper Eq. 8 with the serving tone-curve epilogue."""
    return ops.recover(frames, t, A, cfg.t0, cfg.gamma, cfg.kernel_mode)


# ---------------------------------------------------------------------------
# Fused megakernel path (all three components in one launch)
# ---------------------------------------------------------------------------

def supports_fused(cfg: DehazeConfig) -> bool:
    """The single-pass megakernel covers DCP *and* CAP, the Eq. 6 (k=1)
    *and* robust top-k (k > 1, in-VMEM running selection) atmospheric-light
    estimators, with or without spatial sharding (the halo-aware variant
    masks rows and columns, so height- and width-sharded meshes both stay
    fused) — every production serving config.

    The only remaining fallback is DCP with ``recompute_t_with_final_a``
    (an extra-accuracy second transmission pass that is inherently
    two-stage). CAP ignores that flag — its transmission is A-free — so it
    does not gate CAP, matching the per-stage chain.
    """
    return (cfg.algorithm in ("dcp", "cap")
            and not (cfg.algorithm == "dcp" and cfg.recompute_t_with_final_a))


def premap(frames: jnp.ndarray, a_saved: jnp.ndarray,
           cfg: DehazeConfig) -> jnp.ndarray:
    """Per-pixel stage-1 pre-map: DCP ``min_c I/A`` (Eq. 3 inner min) or CAP
    linear depth (Eq. 4). No neighborhood -> computable before a halo
    exchange; the fused halo kernel consumes it as an input plane.
    Delegates to ``kernels.ref.premap``, the single canonical form.
    """
    from repro.kernels import ref as kref
    a0 = jnp.maximum(a_saved, 1e-3)
    return kref.premap(frames, a0, cfg.algorithm,
                       (cfg.cap_w0, cfg.cap_w1, cfg.cap_w2))


def fused_dehaze(frames: jnp.ndarray, frame_ids: jnp.ndarray, state,
                 cfg: DehazeConfig):
    """Run components 1-3 + the §3.3 EMA as one fused op.

    Returns (J, t, a_seq, new AtmoState); semantics match the per-stage
    chain in ``pipeline.make_dehaze_step``. ``initialized`` only flips
    once a *valid* (non-padding, id >= 0) frame has been folded in, so an
    all-padding batch — e.g. an unoccupied scheduler lane — passes the
    state through untouched, matching ``normalize.ema_scan``.
    """
    from repro.core.normalize import AtmoState
    J, t, a_seq, a_fin, k_fin = ops.fused_dehaze(
        frames, frame_ids, state.A, state.last_update, state.initialized,
        algorithm=cfg.algorithm, radius=cfg.patch_radius, omega=cfg.omega,
        beta=cfg.beta, cap_w=(cfg.cap_w0, cfg.cap_w1, cfg.cap_w2),
        refine=cfg.refine, gf_radius=cfg.gf_radius, gf_eps=cfg.gf_eps,
        t0=cfg.t0, gamma=cfg.gamma, period=cfg.update_period, lam=cfg.lam,
        topk=cfg.topk, out_dtype=cfg.out_dtype, mode=cfg.kernel_mode)
    new_state = AtmoState(
        A=a_fin, last_update=k_fin,
        initialized=jnp.logical_or(state.initialized,
                                   jnp.any(frame_ids >= 0)))
    return J, t, a_seq, new_state


def fused_dehaze_lanes(frames: jnp.ndarray, frame_ids: jnp.ndarray, state,
                       cfg: DehazeConfig):
    """Lane-native fused path: run components 1-3 + the §3.3 EMA for L
    independent streams in ONE kernel launch.

    ``frames``: (L, B, H, W, 3); ``frame_ids``: (L, B); ``state``: a
    lane-batched ``AtmoState`` (``normalize.pack_atmo_states``). The
    packed state feeds the kernel's per-lane carry rows through
    ``normalize.lane_carry``; per lane, outputs and the returned state
    match ``fused_dehaze`` on that lane alone — padding lanes (all ids
    < 0) pass their state through untouched, exactly as under
    ``jax.vmap``.
    """
    from repro.core.normalize import lane_carry, state_from_lane_carry
    carry_f, carry_i = lane_carry(state)
    J, t, a_seq, cf, ci = ops.fused_dehaze_lanes(
        frames, frame_ids, carry_f, carry_i,
        algorithm=cfg.algorithm, radius=cfg.patch_radius, omega=cfg.omega,
        beta=cfg.beta, cap_w=(cfg.cap_w0, cfg.cap_w1, cfg.cap_w2),
        refine=cfg.refine, gf_radius=cfg.gf_radius, gf_eps=cfg.gf_eps,
        t0=cfg.t0, gamma=cfg.gamma, period=cfg.update_period, lam=cfg.lam,
        topk=cfg.topk, out_dtype=cfg.out_dtype, mode=cfg.kernel_mode)
    return J, t, a_seq, state_from_lane_carry(cf, ci)


def fused_transmission(frames: jnp.ndarray, a_saved: jnp.ndarray,
                       cfg: DehazeConfig):
    """Fused t-map + A-candidate stage for the batch-sharded step."""
    return ops.fused_transmission(
        frames, a_saved, algorithm=cfg.algorithm, radius=cfg.patch_radius,
        omega=cfg.omega, beta=cfg.beta,
        cap_w=(cfg.cap_w0, cfg.cap_w1, cfg.cap_w2), refine=cfg.refine,
        gf_radius=cfg.gf_radius, gf_eps=cfg.gf_eps, topk=cfg.topk,
        out_dtype=cfg.out_dtype, mode=cfg.kernel_mode)


def fused_transmission_lanes(frames: jnp.ndarray, a_saved: jnp.ndarray,
                             cfg: DehazeConfig):
    """Per-lane saved-A fused t-map + candidate stage: (L, B, H, W, 3) +
    (L, 3) -> (t, t_min, cand_rgb) with a leading lane axis.

    The building block of the lane-batched *sharded* step
    (``pipeline.make_step`` with a lane placement): each shard's local
    lanes divide by their own coherent A in one launch
    (``kernels.fused.fused_transmission_lanes_pallas``), and the per-lane
    EMA scan runs shard-locally — lanes are whole on their shard, so the
    candidate needs no cross-shard merge.
    """
    return ops.fused_transmission_lanes(
        frames, a_saved, algorithm=cfg.algorithm, radius=cfg.patch_radius,
        omega=cfg.omega, beta=cfg.beta,
        cap_w=(cfg.cap_w0, cfg.cap_w1, cfg.cap_w2), refine=cfg.refine,
        gf_radius=cfg.gf_radius, gf_eps=cfg.gf_eps, topk=cfg.topk,
        out_dtype=cfg.out_dtype, mode=cfg.kernel_mode)


def merge_topk_candidates(tk_t: jnp.ndarray, tk_gidx: jnp.ndarray,
                          tk_rgb: jnp.ndarray, cfg: DehazeConfig):
    """Cross-shard candidate merge (see ``ops.merge_topk_candidates``):
    gathered (B, M) lists -> (B, 3) mean of the k best rows, lex (t, index)
    tie-breaking identical on the sort and in-kernel grid-carry paths."""
    return ops.merge_topk_candidates(tk_t, tk_gidx, tk_rgb, cfg.topk,
                                     mode=cfg.kernel_mode)


def fused_transmission_halo(frames: jnp.ndarray, pre_ext: jnp.ndarray,
                            guide_ext: jnp.ndarray, valid: jnp.ndarray,
                            valid_w, cfg: DehazeConfig):
    """Halo-aware fused t-map stage for the spatially-sharded step.

    ``pre_ext``/``guide_ext`` are the halo-extended (pre-map, luma-guide)
    planes from the exchange; ``valid``/``valid_w`` are the row/column
    validity masks (``valid_w=None`` = no width sharding). The masked
    min/box filters run inside the kernel. Returns the shard-local top-k
    candidate lists (see ``ops.fused_transmission_halo``).
    """
    return ops.fused_transmission_halo(
        frames, pre_ext, guide_ext, valid, valid_w, algorithm=cfg.algorithm,
        radius=cfg.patch_radius, omega=cfg.omega, beta=cfg.beta,
        refine=cfg.refine, gf_radius=cfg.gf_radius, gf_eps=cfg.gf_eps,
        topk=cfg.topk, out_dtype=cfg.out_dtype, mode=cfg.kernel_mode)
