"""Atmospheric scattering model (paper Eq. 1-2, 8).

All images are float arrays in [0, 1], layout ``(..., H, W, 3)`` (NHWC for
batches of frames). Transmission maps are ``(..., H, W)``.
"""
from __future__ import annotations

import jax.numpy as jnp

# Lower bound on transmission used by the haze-free generator (paper Eq. 8).
DEFAULT_T0 = 0.1


def synthesize_haze(clear: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """Forward model, paper Eq. 1:  I(x) = J(x) t(x) + A (1 - t(x)).

    Args:
      clear: haze-free radiance ``J``, shape (..., H, W, 3).
      t: transmission map, shape (..., H, W).
      A: atmospheric light, shape (..., 3) or (3,).
    """
    t = t[..., None]
    A = jnp.broadcast_to(A[..., None, None, :], clear.shape)
    return clear * t + A * (1.0 - t)


def transmission_from_depth(depth: jnp.ndarray, beta: float) -> jnp.ndarray:
    """Paper Eq. 2:  t(x) = exp(-beta d(x))."""
    return jnp.exp(-beta * depth)


def recover(hazy: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray,
            t0: float = DEFAULT_T0) -> jnp.ndarray:
    """Haze-free image generator, paper Eq. 8.

    J(x) = (I(x) - A) / max(t(x), t0) + A, clipped to [0, 1].
    """
    t = jnp.maximum(t, t0)[..., None]
    A = jnp.broadcast_to(A[..., None, None, :], hazy.shape)
    return jnp.clip((hazy - A) / t + A, 0.0, 1.0)
