"""Version shims for JAX APIs that moved between releases.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to the top level (where it is
``check_vma``). The pipeline and model step builders call this wrapper so
the repo runs on both sides of the move.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, explicit: bool = False):
    """``jax.make_mesh`` across versions: pass ``axis_types`` only where the
    kwarg exists (older releases have neither it nor ``AxisType``)."""
    import inspect
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        kind = (jax.sharding.AxisType.Explicit if explicit
                else jax.sharding.AxisType.Auto)
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(kind,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    import inspect
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    # The top-level graduation and the check_rep -> check_vma rename were
    # separate changes; key the kwarg off the signature, not the location.
    kwarg = ("check_vma" if "check_vma" in inspect.signature(fn).parameters
             else "check_rep")
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kwarg: check_vma})
