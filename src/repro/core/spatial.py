"""Spatial (within-frame) parallelism primitives: halo exchange + masked filters.

The paper parallelizes only *across* frames (its unit of work is one frame
on one thread). On a TPU mesh we additionally shard the image height over
the ``model`` axis so a single high-resolution frame is processed by many
chips — the windowed min/box filters then need ``halo`` rows of context
from neighboring shards, fetched with ``lax.ppermute``.

Halo composition rule for the full DCP/CAP chain:
  halo = patch_radius (+ 2 * gf_radius when guided refinement is on),
because the guided filter consumes t_raw within 2r_gf of the core and
t_raw itself consumes the image within patch_radius of that.

Shards at the mesh edge receive no neighbor rows; a validity mask restores
the exact global border semantics (clipped windows): min filters treat
invalid rows as +inf, box filters exclude them from both sum and count, so
the sharded pipeline is bit-comparable to the single-device one (verified
in tests/test_distributed.py).

In-kernel masking contract (the fused halo path): with
``kernel_mode="fused"`` the masked filters below are *not* launched as a
per-stage XLA chain — ``halo_exchange_height``'s outputs (the packed
(pre-map, guide) planes plus ``valid``) feed
``kernels.fused.fused_transmission_halo_pallas`` directly, and the kernel
applies the identical masking rules in VMEM: rows where ``valid`` is False
become +inf before the separable min passes, and the box-filter divisor is
(windowed sum of the row mask) x (in-bounds column count), never counting
masked rows. Any change to the masking semantics here must be mirrored
there (and in ``kernels.ref.fused_transmission_halo``); parity across the
three is asserted to 1e-5 in tests/test_fused.py and
tests/test_distributed.py, including mesh-edge shards.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Masked separable filters (reduce_window based — XLA path used under
# shard_map; the unmasked Pallas kernels remain the single-shard fast path).
# ---------------------------------------------------------------------------

def masked_min_filter_2d(x: jnp.ndarray, valid: jnp.ndarray,
                         radius: int) -> jnp.ndarray:
    """Windowed min ignoring rows where ``valid`` is False.

    x: (..., H, W); valid: (H,) row validity.
    """
    big = jnp.asarray(jnp.inf, jnp.float32)
    xm = jnp.where(valid[:, None], x.astype(jnp.float32), big)
    from repro.kernels import ref
    return ref.min_filter_2d(xm, radius).astype(x.dtype)


def masked_box_filter_2d(x: jnp.ndarray, valid: jnp.ndarray,
                         radius: int) -> jnp.ndarray:
    """Windowed mean over valid rows only (count excludes invalid)."""
    from repro.kernels import ref
    v = valid.astype(jnp.float32)[:, None]
    # `where`, not multiply: invalid rows may hold ±inf from an upstream
    # masked min filter and inf * 0 would poison the sums with NaN.
    xm = jnp.where(valid[:, None], x.astype(jnp.float32), 0.0)
    k = 2 * radius + 1
    ndim = x.ndim
    dims_r = (1,) * (ndim - 2) + (k, 1)
    pads_r = ((0, 0),) * (ndim - 2) + ((radius, radius), (0, 0))
    dims_c = (1,) * (ndim - 2) + (1, k)
    pads_c = ((0, 0),) * (ndim - 2) + ((0, 0), (radius, radius))

    def wsum(a):
        s = lax.reduce_window(a, 0.0, lax.add, dims_r, (1,) * ndim, pads_r)
        return lax.reduce_window(s, 0.0, lax.add, dims_c, (1,) * ndim, pads_c)

    acc = wsum(xm)
    cnt = wsum(jnp.broadcast_to(v, x.shape).astype(jnp.float32))
    return (acc / jnp.maximum(cnt, 1.0)).astype(x.dtype)


def masked_guided_filter(guide: jnp.ndarray, src: jnp.ndarray,
                         valid: jnp.ndarray, radius: int,
                         eps: float) -> jnp.ndarray:
    """Guided filter with all five means computed over valid rows only."""
    g = guide.astype(jnp.float32)
    p = src.astype(jnp.float32)
    bf = lambda a: masked_box_filter_2d(a, valid, radius)
    mean_g = bf(g)
    mean_p = bf(p)
    corr_gp = bf(g * p)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return (bf(a) * g + bf(b)).astype(src.dtype)


# ---------------------------------------------------------------------------
# Halo exchange along a mesh axis sharding image height
# ---------------------------------------------------------------------------

def halo_exchange_height(x: jnp.ndarray, halo: int, axis_name: str,
                         n_shards: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Extend local blocks with ``halo`` rows of context from each side.

    x: (B, H_loc, W, C) local block, H globally sharded over ``axis_name``
    (shard 0 holds the top rows). Returns ``(x_ext, valid)`` where x_ext is
    (B, H_loc + 2*halo, W, C) and valid is (H_loc + 2*halo,) marking rows
    that exist in the global image.

    Rows that live ``s`` shards away arrive via a single distance-s
    ``ppermute`` (any fixed permutation is one collective on TPU), so a
    halo spanning multiple shards costs ceil(halo/H_loc) permutes per side,
    each moving only the rows actually needed.
    """
    b, h_loc, w = x.shape[:3]
    trailing = x.shape[3:]
    if halo == 0:
        return x, jnp.ones((h_loc,), bool)
    hops = math.ceil(halo / h_loc)
    idx = lax.axis_index(axis_name)

    top_parts = []   # ordered top -> bottom, total `halo` rows
    bot_parts = []
    for s in range(hops, 0, -1):
        # Rows contributed by the shard `s` above: its bottom c_s rows.
        c_s = min(h_loc, halo - (s - 1) * h_loc)
        if c_s <= 0:
            continue
        down_perm = [(j, j + s) for j in range(n_shards - s)]
        up_perm = [(j + s, j) for j in range(n_shards - s)]
        from_above = lax.ppermute(x[:, h_loc - c_s:], axis_name, down_perm)
        from_below = lax.ppermute(x[:, :c_s], axis_name, up_perm)
        top_parts.append((from_above, s, c_s))
        bot_parts.append((from_below, s, c_s))

    x_ext = jnp.concatenate([p for p, _, _ in top_parts] + [x] +
                            [p for p, _, _ in reversed(bot_parts)], axis=1)

    # Validity: a top part from distance s exists iff idx >= s; bottom iff
    # idx < n_shards - s.
    rows = []
    for _, s, c_s in top_parts:
        rows.append(jnp.broadcast_to(idx >= s, (c_s,)))
    rows.append(jnp.ones((h_loc,), bool))
    for _, s, c_s in reversed(bot_parts):
        rows.append(jnp.broadcast_to(idx < n_shards - s, (c_s,)))
    valid = jnp.concatenate(rows)
    del b, w, trailing
    return x_ext, valid
