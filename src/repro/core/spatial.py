"""Spatial (within-frame) parallelism primitives: halo exchange + masked filters.

The paper parallelizes only *across* frames (its unit of work is one frame
on one thread). On a TPU mesh we additionally shard the image height over
the ``model`` axis so a single high-resolution frame is processed by many
chips — the windowed min/box filters then need ``halo`` rows of context
from neighboring shards, fetched with ``lax.ppermute``.

Halo composition rule for the full DCP/CAP chain:
  halo = patch_radius (+ 2 * gf_radius when guided refinement is on),
because the guided filter consumes t_raw within 2r_gf of the core and
t_raw itself consumes the image within patch_radius of that.

Both spatial axes shard: image height over one mesh axis and image width
over another (``halo_exchange_height`` then ``halo_exchange_width`` — the
W exchange moves H-extended blocks, so diagonal corner halos need no extra
collective). Shards at the mesh edge receive no neighbor rows/columns; a
*separable* validity mask (per-axis row and column vectors, combined as an
outer product) restores the exact global border semantics (clipped
windows): min filters treat invalid rows/cols as +inf, box filters exclude
them from both sum and count, so the sharded pipeline is bit-comparable to
the single-device one (verified in tests/test_distributed.py and
tests/test_parity_matrix.py).

In-kernel masking contract (the fused halo path): with
``kernel_mode="fused"`` the masked filters below are *not* launched as a
per-stage XLA chain — the halo-exchange outputs (the packed (pre-map,
guide) planes plus the row/column validity vectors) feed
``kernels.fused.fused_transmission_halo_pallas`` directly, and the kernel
applies the identical masking rules in VMEM: pixels whose row *or* column
is invalid become +inf before the separable min passes, and the box-filter
divisor is (windowed sum of the row mask) x (windowed sum of the column
mask), never counting masked pixels. Any change to the masking semantics
here must be mirrored there (and in ``kernels.ref.fused_transmission_halo``
and ``kernels.boxfilter._masked_box_mean``); parity across them is
asserted to 1e-5 in tests/test_fused.py, tests/test_distributed.py and
tests/test_parity_matrix.py, including mesh-edge shards.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Masked separable filters (reduce_window based — XLA path used under
# shard_map; the unmasked Pallas kernels remain the single-shard fast path).
# ---------------------------------------------------------------------------

def _mask_2d(valid: jnp.ndarray, valid_w) -> jnp.ndarray:
    """(H,) row validity [x (W,) column validity] -> broadcastable 2-D mask.

    The halo masks are separable (outer products of per-axis validity), so
    every masked filter takes the two 1-D masks and combines them here.
    """
    mask = valid[:, None]
    if valid_w is not None:
        mask = jnp.logical_and(mask, valid_w[None, :])
    return mask


def masked_min_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         valid_w: jnp.ndarray = None) -> jnp.ndarray:
    """Windowed min ignoring rows/columns where validity is False.

    x: (..., H, W); valid: (H,) row validity; valid_w: optional (W,)
    column validity (the W-sharded halo path).
    """
    big = jnp.asarray(jnp.inf, jnp.float32)
    xm = jnp.where(_mask_2d(valid, valid_w), x.astype(jnp.float32), big)
    from repro.kernels import ref
    return ref.min_filter_2d(xm, radius).astype(x.dtype)


def masked_box_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         valid_w: jnp.ndarray = None) -> jnp.ndarray:
    """Windowed mean over valid rows/columns only (count excludes invalid)."""
    mask = _mask_2d(valid, valid_w)
    # `where`, not multiply: invalid rows may hold ±inf from an upstream
    # masked min filter and inf * 0 would poison the sums with NaN.
    xm = jnp.where(mask, x.astype(jnp.float32), 0.0)
    k = 2 * radius + 1
    ndim = x.ndim
    dims_r = (1,) * (ndim - 2) + (k, 1)
    pads_r = ((0, 0),) * (ndim - 2) + ((radius, radius), (0, 0))
    dims_c = (1,) * (ndim - 2) + (1, k)
    pads_c = ((0, 0),) * (ndim - 2) + ((0, 0), (radius, radius))

    def wsum(a):
        s = lax.reduce_window(a, 0.0, lax.add, dims_r, (1,) * ndim, pads_r)
        return lax.reduce_window(s, 0.0, lax.add, dims_c, (1,) * ndim, pads_c)

    acc = wsum(xm)
    cnt = wsum(jnp.broadcast_to(mask, x.shape).astype(jnp.float32))
    return (acc / jnp.maximum(cnt, 1.0)).astype(x.dtype)


def masked_guided_filter(guide: jnp.ndarray, src: jnp.ndarray,
                         valid: jnp.ndarray, radius: int, eps: float,
                         valid_w: jnp.ndarray = None) -> jnp.ndarray:
    """Guided filter with all five means computed over valid rows/cols only."""
    g = guide.astype(jnp.float32)
    p = src.astype(jnp.float32)
    bf = lambda a: masked_box_filter_2d(a, valid, radius, valid_w)
    mean_g = bf(g)
    mean_p = bf(p)
    corr_gp = bf(g * p)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return (bf(a) * g + bf(b)).astype(src.dtype)


# ---------------------------------------------------------------------------
# Halo exchange along a mesh axis sharding image height
# ---------------------------------------------------------------------------

def halo_exchange_along(x: jnp.ndarray, halo: int, axis_name: str,
                        n_shards: int,
                        axis: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Extend local blocks with ``halo`` slices of context from each side
    along array ``axis`` (1 = image height, 2 = image width).

    x: local block whose ``axis`` dimension is globally sharded over mesh
    axis ``axis_name`` (shard 0 holds the leading slices). Returns
    ``(x_ext, valid)`` where ``x_ext`` grows ``axis`` by ``2*halo`` and
    ``valid`` is a (size + 2*halo,) mask marking slices that exist in the
    global image.

    Slices that live ``s`` shards away arrive via a single distance-s
    ``ppermute`` (any fixed permutation is one collective on TPU), so a
    halo spanning multiple shards costs ceil(halo/size) permutes per side,
    each moving only the slices actually needed.
    """
    size = x.shape[axis]
    if halo == 0:
        return x, jnp.ones((size,), bool)
    hops = math.ceil(halo / size)
    idx = lax.axis_index(axis_name)

    lead_parts = []   # ordered first -> last, total `halo` slices
    trail_parts = []
    for s in range(hops, 0, -1):
        # Slices contributed by the shard `s` before us: its last c_s ones.
        c_s = min(size, halo - (s - 1) * size)
        if c_s <= 0:
            continue
        down_perm = [(j, j + s) for j in range(n_shards - s)]
        up_perm = [(j + s, j) for j in range(n_shards - s)]
        from_before = lax.ppermute(
            lax.slice_in_dim(x, size - c_s, size, axis=axis),
            axis_name, down_perm)
        from_after = lax.ppermute(
            lax.slice_in_dim(x, 0, c_s, axis=axis), axis_name, up_perm)
        lead_parts.append((from_before, s, c_s))
        trail_parts.append((from_after, s, c_s))

    x_ext = jnp.concatenate([p for p, _, _ in lead_parts] + [x] +
                            [p for p, _, _ in reversed(trail_parts)],
                            axis=axis)

    # Validity: a leading part from distance s exists iff idx >= s; a
    # trailing one iff idx < n_shards - s.
    parts = []
    for _, s, c_s in lead_parts:
        parts.append(jnp.broadcast_to(idx >= s, (c_s,)))
    parts.append(jnp.ones((size,), bool))
    for _, s, c_s in reversed(trail_parts):
        parts.append(jnp.broadcast_to(idx < n_shards - s, (c_s,)))
    return x_ext, jnp.concatenate(parts)


def halo_exchange_height(x: jnp.ndarray, halo: int, axis_name: str,
                         n_shards: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, H_loc, W, C) block, H sharded over ``axis_name`` -> H-extended
    block + (H_loc + 2*halo,) row validity. See ``halo_exchange_along``."""
    return halo_exchange_along(x, halo, axis_name, n_shards, axis=1)


def halo_exchange_width(x: jnp.ndarray, halo: int, axis_name: str,
                        n_shards: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, H, W_loc, C) block, W sharded over ``axis_name`` -> W-extended
    block + (W_loc + 2*halo,) column validity.

    Runs *after* the height exchange when both axes are sharded: the
    H-extended block (every shard holds one) is what rides the W-axis
    ppermute, so the diagonal corner halos arrive for free — the W-neighbor
    already concatenated its own H-neighbors' rows, and its row validity is
    identical to ours (same height-axis coordinate).
    """
    return halo_exchange_along(x, halo, axis_name, n_shards, axis=2)
