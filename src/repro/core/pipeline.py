"""Dehazing step builders: the paper's component chain as jitted SPMD steps.

``make_step(cfg, placement)`` is THE step-construction path: a
:class:`~repro.core.placement.PlacementSpec` declares once how every axis
of the serving batch maps onto mesh axes, and the builder realizes it —
the plain batched step, the lane-batched multi-stream step, the
frame/spatially sharded production step, and (new) the *lane-sharded*
pod-scale step where the lane axis shards over the ``data`` mesh axis and
composes with H/W halo sharding. The three legacy builders
(``make_dehaze_step``, ``make_multi_stream_step``,
``make_sharded_dehaze_step``) are thin views of ``make_step`` and keep
their exact signatures and semantics.

The three paper components run back-to-back inside one compiled program:
on TPU the win from the paper's operator parallelism is realized across
*frames* (data axis), *rows* (model axis) and now *streams* (lane axis),
while component handoff is a register/VMEM boundary instead of an
Ethernet hop (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import algorithms as alg
from repro.core import compat
from repro.core import env as _env
from repro.core import spatial
from repro.core.config import DehazeConfig
from repro.core.normalize import (AtmoState, ema_scan, ema_scan_associative,
                                  ema_scan_lanes, init_atmo_state,
                                  init_atmo_state_lanes, pack_atmo_states,
                                  unpack_atmo_states)
from repro.core.placement import PlacementSpec
from repro.kernels import ref as kref


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DehazeOutput:
    frames: jnp.ndarray      # (B, H, W, 3) haze-free J
    transmission: jnp.ndarray  # (B, H, W) refined t
    atmo_light: jnp.ndarray    # (B, 3) per-frame normalized A
    state: AtmoState


def _ingest(frames: jnp.ndarray, cfg: DehazeConfig):
    """Resolve the frame I/O dtype contract for one step invocation.

    Returns ``(x, odt)``: ``x`` is the compute-dtype view of ``frames``
    (float ingest passes through untouched — bit-identical to the
    pre-contract pipeline; uint8 ingest upcasts via the canonical
    ``kernels.ref.upcast_frames`` quantization map) and ``odt`` is the
    resolved output dtype for J / t / A per ``cfg.out_dtype``. The fused
    megakernels never see ``x`` — they take the raw wire-dtype frames and
    upcast in-VMEM (that is the 4x input-HBM-traffic win); ``x`` feeds the
    staged XLA chain and the host-side epilogue stages.
    """
    odt = kref.resolve_out_dtype(frames.dtype, cfg.out_dtype)
    x = frames if jnp.issubdtype(frames.dtype, jnp.floating) \
        else kref.upcast_frames(frames)
    return x, odt


# ---------------------------------------------------------------------------
# Buffer donation contract
# ---------------------------------------------------------------------------

# Step argument positions (frames, frame_ids, state) — the donation
# argnums below index into this signature.
_ARG_FRAMES, _ARG_IDS, _ARG_STATE = 0, 1, 2


def donation_spec(cfg: DehazeConfig) -> Tuple[int, ...]:
    """The step arguments eligible for ``jax.jit`` buffer donation.

    The EMA state (argnum 2) is always donatable: ``out.state`` has the
    input state's exact shape/dtype, the serve loops thread it
    sequentially, and nothing else holds the old value once the next tick
    is dispatched — donating it makes steady-state serving allocate zero
    new HBM for the state chain.

    The frame batch (argnum 0) is donatable only when the wire dtype
    equals the resolved output dtype (f32-in/f32-out, bf16-in/bf16-out):
    XLA then aliases ``out.frames`` onto the input buffer. A uint8 stream
    can never alias (J is float), and ``out_dtype`` overrides that differ
    from ``io_dtype`` break the aliasing too — donating a buffer XLA
    cannot alias is legal but wasteful (the input is freed, a fresh output
    allocated), so we only offer arguments that actually alias.
    """
    cfg = cfg.validate()
    argnums = [_ARG_STATE]
    if kref.resolve_out_dtype(jnp.dtype(cfg.io_dtype), cfg.out_dtype) \
            == jnp.dtype(cfg.io_dtype):
        argnums.insert(0, _ARG_FRAMES)
    return tuple(argnums)


# ---------------------------------------------------------------------------
# The placement-driven entry point
# ---------------------------------------------------------------------------

def make_step(cfg: DehazeConfig, placement: Optional[PlacementSpec] = None,
              mesh: Optional[jax.sharding.Mesh] = None, *,
              associative: bool = True, lane_native: Optional[bool] = None,
              donate=False):
    """Build the dehaze step a :class:`PlacementSpec` declares.

    - no mesh axes, no lanes  -> ``step(frames (B,H,W,3), ids (B,), state)``
    - ``lanes`` (no mesh axes)-> lane-batched ``(L, B, H, W, 3)`` step
      (lane-native megakernel when the config is fused-covered);
    - ``batch_axes``/spatial  -> the shard_map production step (frames over
      the data axes, H/W halo-sharded, state synchronized by collectives);
    - ``lane_axis``           -> the pod-scale lane-sharded step: the lane
      axis shards over the mesh (each shard owns whole lanes, so per-lane
      EMA rows are co-placed and scan shard-locally), optionally composed
      with H/W halo sharding inside each shard.

    ``mesh`` is required iff the placement names mesh axes. ``lane_native``
    follows :func:`resolve_lane_native` when ``None``.

    ``donate`` is the buffer-donation contract (README §Tick I/O &
    overlap). ``False`` (default) returns the un-jitted step exactly as
    before (callers jit, typically through the serving step cache which
    keys on ``(cfg, placement)``). Donation is a property of the *jitted*
    call, so a non-``False`` value returns ``jax.jit(step,
    donate_argnums=...)``:

    - ``"state"`` — donate only the EMA state (argnum 2). This is the
      tick-step contract: the serve loop owns a long-lived device frame
      buffer that must survive the call, while the state chain is
      strictly sequential and its input is dead after dispatch.
    - ``True`` — donate everything :func:`donation_spec` allows (state
      always, frames when the wire dtype aliases the output dtype). This
      is the dispatcher contract: each batch's input buffer is
      single-use, so ``out.frames`` can alias it.

    Donation with a mesh-sharded placement is not offered (the serving
    tiers drive local lane batches; a sharded step's buffers belong to
    the launch tooling) and raises.
    """
    placement = (placement if placement is not None
                 else PlacementSpec()).validate()
    cfg = cfg.validate()
    if donate is not False and placement.sharded:
        raise ValueError(
            "donate= is a serving-tier contract for local batches; "
            f"mesh-sharded placement {placement} manages its own buffers")
    if placement.sharded:
        if mesh is None:
            raise ValueError(
                f"placement {placement} names mesh axes "
                f"{placement.mesh_axes}; make_step needs the mesh")
        return _make_sharded_step(cfg, mesh, placement,
                                  associative=associative,
                                  lane_native=lane_native)
    if placement.lanes:
        step = _make_lane_step(cfg, associative=associative,
                               lane_native=lane_native)
    else:
        step = _make_single_step(cfg, associative=associative)
    if donate is False:
        return step
    if donate == "state":
        argnums: Tuple[int, ...] = (_ARG_STATE,)
    elif donate is True:
        argnums = donation_spec(cfg)
    else:
        raise ValueError(
            f"donate must be False, True or 'state', got {donate!r}")
    return jax.jit(step, donate_argnums=argnums)


# ---------------------------------------------------------------------------
# Single-shard batched step
# ---------------------------------------------------------------------------

def _make_single_step(cfg: DehazeConfig, associative: bool = True):
    if cfg.kernel_mode == "fused" and alg.supports_fused(cfg):
        def fused_step(frames: jnp.ndarray, frame_ids: jnp.ndarray,
                       state: AtmoState) -> DehazeOutput:
            # Raw wire-dtype frames go straight into the megakernel (in-VMEM
            # upcast); the kernel's J dtype IS the resolved out dtype.
            out, t, a_seq, new_state = alg.fused_dehaze(
                frames, frame_ids, state, cfg)
            return DehazeOutput(out, t, a_seq.astype(out.dtype), new_state)
        return fused_step

    t_est = alg.get_transmission_estimator(cfg.algorithm)
    scan = ema_scan_associative if associative else ema_scan

    def step(frames: jnp.ndarray, frame_ids: jnp.ndarray,
             state: AtmoState) -> DehazeOutput:
        x, odt = _ingest(frames, cfg)
        # Component 1: transmission from the *saved* shared A (paper §3.3).
        t_raw = t_est(x, state.A, cfg)
        # Component 2: per-frame candidates, then cross-frame normalization.
        a_new = alg.estimate_atmospheric_light(x, t_raw, cfg)
        a_seq, new_state = scan(a_new, frame_ids, state,
                                cfg.update_period, cfg.lam)
        a_seq = a_seq.astype(x.dtype)
        if cfg.recompute_t_with_final_a and cfg.algorithm == "dcp":
            t_raw = t_est(x, a_seq, cfg)
        t = alg.refine_transmission(x, t_raw, cfg)
        # Component 3: haze-free generation.
        out = alg.generate_haze_free(x, t, a_seq, cfg)
        return DehazeOutput(out.astype(odt), t.astype(odt),
                            a_seq.astype(odt), new_state)

    return step


def make_dehaze_step(cfg: DehazeConfig, associative: bool = True):
    """Returns step(frames (B,H,W,3), frame_ids (B,), state) -> DehazeOutput.

    Thin view of :func:`make_step` with the empty placement. With
    ``cfg.kernel_mode == "fused"`` (and a config the megakernel covers,
    see ``algorithms.supports_fused``) the whole component chain runs as
    one single-pass launch; otherwise the per-stage chain.
    """
    return make_step(cfg, PlacementSpec.single(), associative=associative)


# ---------------------------------------------------------------------------
# Multi-stream (lane-batched) step — N videos in one compiled program
# ---------------------------------------------------------------------------

def resolve_lane_native(cfg: DehazeConfig) -> bool:
    """Should the multi-stream step use the lane-native megakernel?

    Default: yes whenever the fused megakernel covers the config
    (``kernel_mode == "fused"`` and ``algorithms.supports_fused``) — the
    lane axis then folds into the pallas grid and L streams cost one
    launch. Env ``REPRO_LANE_NATIVE`` overrides: ``0`` forces the vmapped
    path (A/B benchmarking, bisection), ``1`` forces lane-native and
    *raises* if the config cannot take it — CI uses this to guarantee the
    smoke run exercised the lane-native path rather than silently falling
    back.
    """
    cfg = cfg.validate()
    fused_ok = cfg.kernel_mode == "fused" and alg.supports_fused(cfg)
    forced = _env.lane_native()             # validated; raises on junk
    if forced:
        if not fused_ok:
            raise ValueError(
                "REPRO_LANE_NATIVE=1 requires kernel_mode='fused' and a "
                "config the megakernel covers (algorithms.supports_fused); "
                f"got kernel_mode={cfg.kernel_mode!r}, "
                f"algorithm={cfg.algorithm!r}")
        return True
    if forced is not None:
        return False
    return fused_ok


def _make_lane_step(cfg: DehazeConfig, associative: bool = True,
                    lane_native: Optional[bool] = None):
    if lane_native is None:
        lane_native = resolve_lane_native(cfg)
    if lane_native:
        if not (cfg.kernel_mode == "fused" and alg.supports_fused(cfg)):
            raise ValueError(
                "lane_native=True requires kernel_mode='fused' and a config "
                "the megakernel covers (algorithms.supports_fused)")

        def lane_step(frames: jnp.ndarray, frame_ids: jnp.ndarray,
                      state: AtmoState) -> DehazeOutput:
            out, t, a_seq, new_state = alg.fused_dehaze_lanes(
                frames, frame_ids, state, cfg)
            return DehazeOutput(out, t, a_seq.astype(out.dtype), new_state)
        return lane_step
    return jax.vmap(_make_single_step(cfg, associative=associative))


def make_multi_stream_step(cfg: DehazeConfig, associative: bool = True,
                           lane_native: Optional[bool] = None):
    """Returns step(frames (L, B, H, W, 3), frame_ids (L, B), state) ->
    DehazeOutput with a leading lane axis on every field. Thin view of
    :func:`make_step` with the lane-batched placement.

    The paper's §5 future work — coordinating atmospheric light "across
    multiple videos" — realized as *continuous batching*: L independent
    streams ride one fixed-shape device batch, each lane carrying its own
    causal A trajectory (the state is a lane-batched ``AtmoState``, see
    ``normalize.pack_atmo_states``).

    Two realizations, selected by ``lane_native`` (None =
    :func:`resolve_lane_native`: lane-native whenever the megakernel
    covers the config, env ``REPRO_LANE_NATIVE`` to force):

    - *lane-native* (fused configs): the lane axis is folded into the
      megakernel's own grid (``ops.fused_dehaze_lanes``) — one
      ``pallas_call`` launch and one VMEM carry setup for all L lanes,
      instead of L kernel launches under vmap;
    - *vmapped* (staged configs, or forced): the single-stream component
      chain under ``jax.vmap`` over the lane axis.

    Lane semantics are identical in both: per-lane outputs match running
    ``make_dehaze_step`` on that lane's frames alone (neither the vmap nor
    the in-kernel lane grid reorders any within-frame reduction).
    Unoccupied (padding) lanes carry ``frame_ids == -1`` everywhere; the
    masked EMA paths pass their state through untouched and their frame
    outputs are discarded by the scheduler.
    """
    return make_step(cfg, PlacementSpec.lane_batched(),
                     associative=associative, lane_native=lane_native)


# ---------------------------------------------------------------------------
# Sharded step (production mesh)
# ---------------------------------------------------------------------------

def _local_topk_candidates(t_raw: jnp.ndarray, frames: jnp.ndarray,
                           k: int):
    """Per-frame shard-local top-k smallest-t candidates over the core
    block: ``(tk_t (B, k), tk_rgb (B, k, 3), tk_idx (B, k) int32)`` in
    ascending (t, local flat index) order — the identical selection (and
    tie-breaking) to ``kernels.ref.atmospheric_light``."""
    b_loc = frames.shape[0]
    flat_t = t_raw.reshape(b_loc, -1).astype(jnp.float32)
    _, idx = lax.top_k(-flat_t, k)                 # k smallest, ties by idx
    tk_t = jnp.take_along_axis(flat_t, idx, axis=-1)
    tk_rgb = jnp.take_along_axis(
        frames.astype(jnp.float32).reshape(b_loc, -1, 3), idx[..., None],
        axis=1)
    return tk_t, tk_rgb, idx.astype(jnp.int32)


def _merge_topk_over_spatial(tk_t: jnp.ndarray, tk_rgb: jnp.ndarray,
                             tk_gidx: jnp.ndarray, axis_names, cfg):
    """Merge per-shard top-k candidate lists into the per-frame global A
    candidate (B, 3): all-gather the (t, rgb, global flat index) lists over
    the spatial mesh axes, select the k lexicographically best (t, index)
    rows, mean their rgb. The explicit global-index key reproduces
    ``lax.top_k``'s lowest-flat-index tie-breaking even when a t plateau
    spans shard boundaries — common, since the min-filter output is
    piecewise constant — so the sharded candidate equals the single-device
    one bit-for-bit, not just in value. The selection itself dispatches
    through ``ops.merge_topk_candidates``: a two-key ``lax.sort`` on the
    ref substrate, an in-kernel grid-carry fold on the pallas ones."""
    tk_rgb = tk_rgb.astype(jnp.float32)
    for ax in axis_names:
        tk_t = lax.all_gather(tk_t, ax, axis=1, tiled=True)
        tk_rgb = lax.all_gather(tk_rgb, ax, axis=1, tiled=True)
        tk_gidx = lax.all_gather(tk_gidx, ax, axis=1, tiled=True)
    return alg.merge_topk_candidates(tk_t, tk_gidx, tk_rgb, cfg)


def _make_sharded_step(cfg: DehazeConfig, mesh: jax.sharding.Mesh,
                       placement: PlacementSpec, associative: bool = True,
                       lane_native: Optional[bool] = None):
    """Realize a mesh-sharded placement as a shard_map step.

    Non-lane placements reproduce the classic production step: frames over
    ``batch_axes``, H/W halo-sharded, AtmoState replicated and synchronized
    by an all-gather + causal EMA scan over the frame axis. Lane placements
    are the pod-scale composition: whole lanes shard over ``lane_axis``
    (state rows co-placed, per-lane EMA scans shard-locally with NO
    cross-shard sync), while H/W sharding inside each shard reuses the
    halo machinery on the lane-flattened frame axis with *per-frame saved
    A* rows — the per-lane saved-A input of
    ``fused_transmission_lanes_pallas`` generalized to the halo kernel.
    """
    lanes = placement.lanes
    lane_axis = placement.lane_axis
    batch_axes = placement.batch_axes
    height_axis, width_axis = placement.height_axis, placement.width_axis
    if not lanes and not batch_axes:
        raise ValueError(
            "a sharded non-lane placement needs batch_axes (the state sync "
            f"gathers candidates over them); got {placement}")
    n_h = mesh.shape[height_axis] if height_axis else 1
    n_w = mesh.shape[width_axis] if width_axis else 1
    shard_h = height_axis is not None and n_h > 1
    shard_w = width_axis is not None and n_w > 1
    # Mesh axes that actually split a spatial dimension — the candidate
    # merge and the halo machinery only engage for these.
    spatial_axes = tuple(ax for ax, on in ((height_axis, shard_h),
                                           (width_axis, shard_w)) if on)
    halo = cfg.patch_radius + (2 * cfg.gf_radius if cfg.refine else 0)
    # With spatial sharding the fused path switches to the halo-aware
    # megakernel: the exchanged (pre-map, guide) planes plus the
    # row/column-validity masks feed the kernel directly and the min/box
    # filters run masked in-VMEM (kernels.fused.fused_transmission_halo_pallas).
    use_fused = cfg.kernel_mode == "fused" and alg.supports_fused(cfg)
    if lanes and lane_native is None:
        # The lane-native megakernel has no halo variant: spatial sharding
        # composes through the halo kernel + shard-local lane EMA instead.
        lane_native = resolve_lane_native(cfg) and not spatial_axes

    fspec = placement.frame_spec()
    ispec = placement.ids_spec()
    state_spec = placement.state_spec()

    def halo_premap_and_guide(frames, a_saved, keep_halo_dtype=False):
        """Halo-extended (pre-map, guide) planes + row/column validity,
        honoring ``cfg.halo_packed``: either exchange the packed 2-channel
        stack (what the stencils consume — 1/3 less wire than RGB) or
        exchange RGB and compute the maps on the extended block. Both the
        staged chain and the fused halo kernel consume this, so the two
        paths see identical inputs (including bf16 halo rounding
        placement). ``a_saved`` is the saved atmospheric light, already
        broadcast-shaped against ``frames`` (replicated (3,) for the
        classic step, per-frame (B, 1, 1, 3) lane rows for the
        lane-sharded one).

        ``keep_halo_dtype`` (fused packed path): hand the exchanged planes
        onward in the wire dtype instead of re-casting at the boundary —
        the halo megakernel accepts bf16 inputs and upcasts in-VMEM, so
        ``halo_dtype="bfloat16"`` halves the exchange bytes end-to-end
        with no extra cast pass. Values are unchanged (bf16 -> f32 is
        exact; the rounding already happened before the exchange). The
        unpacked path always upcasts: its maps are *computed* from the
        exchanged RGB and must use the same f32 arithmetic as the staged
        chain."""
        hdt = jnp.dtype(cfg.halo_dtype)

        def exchange(p):
            p = p.astype(hdt)
            valid_w = None
            if shard_h:
                p, valid_h = spatial.halo_exchange_height(
                    p, halo, height_axis, n_h)
            else:
                valid_h = jnp.ones((p.shape[1],), bool)
            if shard_w:
                p, valid_w = spatial.halo_exchange_width(
                    p, halo, width_axis, n_w)
            return p, valid_h, valid_w

        if cfg.halo_packed:
            packed = jnp.stack([alg.premap(frames, a_saved, cfg),
                                alg.luminance(frames)], axis=-1)
            p_ext, valid_h, valid_w = exchange(packed)
            if not keep_halo_dtype:
                p_ext = p_ext.astype(frames.dtype)
            return p_ext[..., 0], p_ext[..., 1], valid_h, valid_w
        x_ext, valid_h, valid_w = exchange(frames)
        x_ext = x_ext.astype(frames.dtype)
        return (alg.premap(x_ext, a_saved, cfg), alg.luminance(x_ext),
                valid_h, valid_w)

    def global_flat_idx(lidx, h_loc, w_loc):
        """Shard-local flat core index -> global flat (row-major) index —
        the cross-shard tie-break key of the candidate merge."""
        row = lidx // w_loc
        col = lidx % w_loc
        if shard_h:
            row = row + lax.axis_index(height_axis) * h_loc
        if shard_w:
            col = col + lax.axis_index(width_axis) * w_loc
        return row * (w_loc * n_w) + col

    def candidates_from_local_topk(tk_t, tk_rgb, tk_idx, frames):
        """Per-frame A candidate (B, 3) from shard-local top-k lists."""
        if spatial_axes:
            gidx = global_flat_idx(tk_idx, frames.shape[1], frames.shape[2])
            return _merge_topk_over_spatial(tk_t, tk_rgb, gidx,
                                            spatial_axes, cfg)
        return tk_rgb.astype(jnp.float32).mean(axis=1)

    def staged_t_and_candidates(frames, a_saved):
        """Per-stage chain: masked filters over halo-extended blocks ->
        (refined t, per-frame A candidates)."""
        if spatial_axes:
            pre_ext, guide_ext, valid_h, valid_w = halo_premap_and_guide(
                frames, a_saved)
        else:
            valid_h = jnp.ones((frames.shape[1],), bool)
            valid_w = None
            pre_ext = alg.premap(frames, a_saved, cfg)
            guide_ext = alg.luminance(frames)

        # --- Component 1 on the halo-extended block (masked filters). ---
        from repro.kernels import ref as kref
        t_raw_ext = kref.tmap_from_dark(
            spatial.masked_min_filter_2d(pre_ext, valid_h, cfg.patch_radius,
                                         valid_w),
            cfg.algorithm, cfg.omega, cfg.beta)
        t_raw_ext = t_raw_ext.astype(frames.dtype)

        core_h = slice(halo, halo + frames.shape[1]) if shard_h \
            else slice(None)
        core_w = slice(halo, halo + frames.shape[2]) if shard_w \
            else slice(None)
        t_raw = t_raw_ext[:, core_h, core_w]

        # --- Component 2: per-frame candidates (paper Eq. 5/6). ---
        tk_t, tk_rgb, tk_idx = _local_topk_candidates(t_raw, frames, cfg.topk)
        rgb = candidates_from_local_topk(tk_t, tk_rgb, tk_idx, frames)

        # --- Refinement on the halo-extended block. ---
        if cfg.refine:
            t_ext = spatial.masked_guided_filter(
                guide_ext, t_raw_ext, valid_h, cfg.gf_radius, cfg.gf_eps,
                valid_w)
            t = jnp.clip(t_ext[:, core_h, core_w], 0.0, 1.0)
        else:
            t = t_raw
        return t, rgb

    def fused_t_and_candidates(frames, x, a_saved):
        """Fused megakernel form of ``staged_t_and_candidates``: one launch
        per block instead of the masked per-stage XLA chain. ``frames`` is
        the raw wire-dtype block (the kernels upcast in-VMEM); ``x`` its
        compute-dtype view for the XLA-side premap/guide stages."""
        if spatial_axes:
            # Halo-aware fused kernel: the exchange output is the kernel
            # input; masking (and any bf16/uint8 -> f32 upcast of wire
            # frames or packed halo planes) happens in-VMEM.
            pre_ext, guide_ext, valid_h, valid_w = halo_premap_and_guide(
                x, a_saved, keep_halo_dtype=cfg.halo_packed)
            t, tk_t, tk_rgb, tk_idx = alg.fused_transmission_halo(
                frames, pre_ext, guide_ext, valid_h, valid_w, cfg)
            rgb = candidates_from_local_topk(tk_t, tk_rgb, tk_idx, frames)
        else:
            t, _t_min, rgb = alg.fused_transmission(frames, a_saved, cfg)
        return t, rgb

    def local_step(frames, frame_ids, state):
        b_loc = frames.shape[0]
        x, odt = _ingest(frames, cfg)
        if use_fused:
            # Components 1 + 2 candidates + refinement in ONE launch.
            t, rgb = fused_t_and_candidates(frames, x, state.A)
        else:
            t, rgb = staged_t_and_candidates(x, state.A)

        # State sync: all-gather candidates over the frame axes, scan,
        # slice the local part (the paper's A broadcast, minus the race).
        a_all = lax.all_gather(rgb, batch_axes, axis=0, tiled=True)
        ids_all = lax.all_gather(frame_ids, batch_axes, axis=0, tiled=True)
        a_seq_all, new_state = ema_scan_associative(
            a_all, ids_all, state, cfg.update_period, cfg.lam)
        didx = lax.axis_index(batch_axes)
        a_seq = lax.dynamic_slice_in_dim(a_seq_all, didx * b_loc, b_loc)
        a_seq = a_seq.astype(x.dtype)

        # --- Component 3 on the core block. ---
        out = alg.generate_haze_free(x, t, a_seq,
                                     dataclasses.replace(cfg, kernel_mode="ref"))
        return DehazeOutput(out.astype(odt), t.astype(odt),
                            a_seq.astype(odt), new_state)

    def lane_local_step(frames, frame_ids, state):
        # frames (L_loc, B, h, w, 3); state rows (L_loc,) — whole lanes
        # live on this shard, so the EMA scans are shard-local and causal.
        l_loc, b = frames.shape[:2]
        if use_fused and lane_native and not spatial_axes:
            # Whole chain in one lane-native launch per shard.
            out, t, a_seq, new_state = alg.fused_dehaze_lanes(
                frames, frame_ids, state, cfg)
            return DehazeOutput(out, t, a_seq.astype(out.dtype), new_state)
        x, odt = _ingest(frames, cfg)
        if use_fused and not spatial_axes:
            # Per-lane saved-A fused t + candidates
            # (fused_transmission_lanes_pallas's building-block input).
            t, _t_min, rgb = alg.fused_transmission_lanes(frames, state.A,
                                                          cfg)
        else:
            # H/W halo sharding composes on the lane-flattened frame axis:
            # every component is frame-generic, so per-frame saved-A rows
            # (each lane's A repeated over its batch) stand in for the
            # replicated A of the classic step.
            flat = frames.reshape((l_loc * b,) + frames.shape[2:])
            flat_x = x.reshape((l_loc * b,) + x.shape[2:])
            a_pf = jnp.repeat(state.A.astype(jnp.float32), b,
                              axis=0)[:, None, None, :]
            if use_fused:
                t, rgb = fused_t_and_candidates(flat, flat_x, a_pf)
            else:
                t, rgb = staged_t_and_candidates(flat_x, a_pf)
            t = t.reshape((l_loc, b) + t.shape[1:])
            rgb = rgb.reshape(l_loc, b, 3)
        a_seq, new_state = ema_scan_lanes(rgb, frame_ids, state,
                                          cfg.update_period, cfg.lam,
                                          associative=associative)
        a_seq = a_seq.astype(x.dtype)
        out = alg.generate_haze_free(x, t, a_seq,
                                     dataclasses.replace(cfg, kernel_mode="ref"))
        return DehazeOutput(out.astype(odt), t.astype(odt),
                            a_seq.astype(odt), new_state)

    step = compat.shard_map(
        lane_local_step if lanes else local_step, mesh=mesh,
        in_specs=(fspec, ispec, state_spec),
        out_specs=DehazeOutput(frames=fspec, transmission=fspec,
                               atmo_light=ispec, state=state_spec),
        check_vma=False,
    )
    return step


def make_sharded_dehaze_step(cfg: DehazeConfig, mesh: jax.sharding.Mesh,
                             batch_axes: Tuple[str, ...] = ("data",),
                             height_axis: Optional[str] = "model",
                             width_axis: Optional[str] = None):
    """Build a shard_map dehaze step for ``mesh``. Thin view of
    :func:`make_step` with the frame-sharded placement; returns
    ``(step, frame_spec, ids_spec)`` as before.

    Sharding: frames (B, H, W, 3) with B over ``batch_axes``, H over
    ``height_axis`` and W over ``width_axis`` (None disables that spatial
    axis). frame_ids (B,) over ``batch_axes``. The AtmoState is replicated.
    With both spatial axes a 2-D (n_h x n_w) tile of shards covers each
    frame; the halo exchange runs height-then-width (corner halos ride the
    W hop for free) and every windowed filter is masked by the separable
    row x column validity mask.
    """
    placement = PlacementSpec.frame_sharded(batch_axes=tuple(batch_axes),
                                            height_axis=height_axis,
                                            width_axis=width_axis)
    step = make_step(cfg, placement, mesh)
    return step, placement.frame_spec(), placement.ids_spec()


__all__ = ["DehazeOutput", "PlacementSpec", "make_step", "donation_spec",
           "make_dehaze_step",
           "make_multi_stream_step", "make_sharded_dehaze_step",
           "resolve_lane_native", "init_atmo_state", "init_atmo_state_lanes",
           "pack_atmo_states", "unpack_atmo_states", "AtmoState", "ema_scan",
           "ema_scan_associative", "DehazeConfig"]
