"""Dehazing step builders: the paper's component chain as jitted SPMD steps.

``make_dehaze_step``        — batched single-shard step (frames over batch).
``make_sharded_dehaze_step``— shard_map step for a production mesh: frames
                              sharded over the (pod,) data axes, image
                              height sharded over the model axis with halo
                              exchange, atmospheric-light state synchronized
                              by collectives + the causal EMA scan.

The three paper components run back-to-back inside one compiled program:
on TPU the win from the paper's operator parallelism is realized across
*frames* (data axis) and *rows* (model axis), while component handoff is a
register/VMEM boundary instead of an Ethernet hop (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import algorithms as alg
from repro.core import compat
from repro.core import spatial
from repro.core.config import DehazeConfig
from repro.core.normalize import (AtmoState, ema_scan, ema_scan_associative,
                                  init_atmo_state, init_atmo_state_lanes,
                                  pack_atmo_states, unpack_atmo_states)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DehazeOutput:
    frames: jnp.ndarray      # (B, H, W, 3) haze-free J
    transmission: jnp.ndarray  # (B, H, W) refined t
    atmo_light: jnp.ndarray    # (B, 3) per-frame normalized A
    state: AtmoState


# ---------------------------------------------------------------------------
# Single-shard batched step
# ---------------------------------------------------------------------------

def make_dehaze_step(cfg: DehazeConfig, associative: bool = True):
    """Returns step(frames (B,H,W,3), frame_ids (B,), state) -> DehazeOutput.

    With ``cfg.kernel_mode == "fused"`` (and a config the megakernel covers,
    see ``algorithms.supports_fused``) the whole component chain runs as one
    single-pass launch; otherwise the per-stage chain below.
    """
    cfg = cfg.validate()
    if cfg.kernel_mode == "fused" and alg.supports_fused(cfg):
        def fused_step(frames: jnp.ndarray, frame_ids: jnp.ndarray,
                       state: AtmoState) -> DehazeOutput:
            out, t, a_seq, new_state = alg.fused_dehaze(
                frames, frame_ids, state, cfg)
            return DehazeOutput(out, t, a_seq.astype(frames.dtype), new_state)
        return fused_step

    t_est = alg.get_transmission_estimator(cfg.algorithm)
    scan = ema_scan_associative if associative else ema_scan

    def step(frames: jnp.ndarray, frame_ids: jnp.ndarray,
             state: AtmoState) -> DehazeOutput:
        # Component 1: transmission from the *saved* shared A (paper §3.3).
        t_raw = t_est(frames, state.A, cfg)
        # Component 2: per-frame candidates, then cross-frame normalization.
        a_new = alg.estimate_atmospheric_light(frames, t_raw, cfg)
        a_seq, new_state = scan(a_new, frame_ids, state,
                                cfg.update_period, cfg.lam)
        a_seq = a_seq.astype(frames.dtype)
        if cfg.recompute_t_with_final_a and cfg.algorithm == "dcp":
            t_raw = t_est(frames, a_seq, cfg)
        t = alg.refine_transmission(frames, t_raw, cfg)
        # Component 3: haze-free generation.
        out = alg.generate_haze_free(frames, t, a_seq, cfg)
        return DehazeOutput(out, t, a_seq, new_state)

    return step


# ---------------------------------------------------------------------------
# Multi-stream (lane-batched) step — N videos in one compiled program
# ---------------------------------------------------------------------------

def make_multi_stream_step(cfg: DehazeConfig, associative: bool = True):
    """Returns step(frames (L, B, H, W, 3), frame_ids (L, B), state) ->
    DehazeOutput with a leading lane axis on every field.

    The paper's §5 future work — coordinating atmospheric light "across
    multiple videos" — realized as *continuous batching*: L independent
    streams ride one fixed-shape device batch, each lane carrying its own
    causal A trajectory (the state is a lane-batched ``AtmoState``, see
    ``normalize.pack_atmo_states``). The single-stream component chain is
    vmapped over the lane axis, so the staged path *and* the fused
    megakernel path (gated by ``algorithms.supports_fused``, exactly as in
    ``make_dehaze_step``) both compile to one program for all lanes.

    Lane semantics: per-lane outputs are bit-identical to running
    ``make_dehaze_step`` on that lane's frames alone — vmap adds a batch
    axis, it does not reorder any within-frame reduction. Unoccupied
    (padding) lanes carry ``frame_ids == -1`` everywhere; the masked EMA
    scans pass their state through untouched and their frame outputs are
    discarded by the scheduler.
    """
    step = make_dehaze_step(cfg, associative=associative)
    return jax.vmap(step)


# ---------------------------------------------------------------------------
# Sharded step (production mesh)
# ---------------------------------------------------------------------------

def _gather_argmin_over_model(t_min: jnp.ndarray, rgb: jnp.ndarray,
                              axis_name: str) -> jnp.ndarray:
    """Combine per-shard (min_t, rgb) candidates into the global argmin-t rgb.

    t_min: (B,), rgb: (B, 3) per shard -> (B, 3) replicated over the axis.
    """
    all_t = lax.all_gather(t_min, axis_name, axis=0)      # (M, B)
    all_rgb = lax.all_gather(rgb, axis_name, axis=0)      # (M, B, 3)
    j = jnp.argmin(all_t, axis=0)                         # (B,)
    return jnp.take_along_axis(all_rgb, j[None, :, None], axis=0)[0]


def make_sharded_dehaze_step(cfg: DehazeConfig, mesh: jax.sharding.Mesh,
                             batch_axes: Tuple[str, ...] = ("data",),
                             height_axis: Optional[str] = "model"):
    """Build a shard_map dehaze step for ``mesh``.

    Sharding: frames (B, H, W, 3) with B over ``batch_axes`` and H over
    ``height_axis`` (None disables spatial parallelism). frame_ids (B,)
    over ``batch_axes``. The AtmoState is replicated.
    """
    cfg = cfg.validate()
    t_est = alg.get_transmission_estimator(cfg.algorithm)
    del t_est  # estimators are inlined below (halo-aware masked forms)
    n_h = mesh.shape[height_axis] if height_axis else 1
    halo = cfg.patch_radius + (2 * cfg.gf_radius if cfg.refine else 0)
    # With height sharding the fused path switches to the halo-aware
    # megakernel: the exchanged (pre-map, guide) planes plus the
    # row-validity mask feed the kernel directly and the min/box filters
    # run masked in-VMEM (kernels.fused.fused_transmission_halo_pallas).
    use_fused = cfg.kernel_mode == "fused" and alg.supports_fused(cfg)

    fspec = P(batch_axes, height_axis) if height_axis else P(batch_axes)
    ispec = P(batch_axes)

    def halo_premap_and_guide(frames, state):
        """Halo-extended (pre-map, guide) planes + row validity, honoring
        ``cfg.halo_packed``: either exchange the packed 2-channel stack
        (what the stencils consume — 1/3 less wire than RGB) or exchange
        RGB and compute the maps on the extended block. Both the staged
        chain and the fused halo kernel consume this, so the two paths see
        identical inputs (including bf16 halo rounding placement)."""
        hdt = jnp.dtype(cfg.halo_dtype)
        if cfg.halo_packed:
            packed = jnp.stack([alg.premap(frames, state.A, cfg),
                                alg.luminance(frames)], axis=-1)
            p_ext, valid = spatial.halo_exchange_height(
                packed.astype(hdt), halo, height_axis, n_h)
            p_ext = p_ext.astype(frames.dtype)
            return p_ext[..., 0], p_ext[..., 1], valid
        x_ext, valid = spatial.halo_exchange_height(
            frames.astype(hdt), halo, height_axis, n_h)
        x_ext = x_ext.astype(frames.dtype)
        return alg.premap(x_ext, state.A, cfg), alg.luminance(x_ext), valid

    def staged_t_and_candidates(frames, state):
        """Per-stage chain: masked filters over halo-extended blocks ->
        (refined t, per-frame (t_min, rgb) candidates)."""
        if height_axis and n_h > 1:
            pre_ext, guide_ext, valid = halo_premap_and_guide(frames, state)
        else:
            valid = jnp.ones((frames.shape[1],), bool)
            pre_ext = alg.premap(frames, state.A, cfg)
            guide_ext = alg.luminance(frames)

        # --- Component 1 on the halo-extended block (masked filters). ---
        from repro.kernels import ref as kref
        t_raw_ext = kref.tmap_from_dark(
            spatial.masked_min_filter_2d(pre_ext, valid, cfg.patch_radius),
            cfg.algorithm, cfg.omega, cfg.beta)
        t_raw_ext = t_raw_ext.astype(frames.dtype)

        core = slice(halo, halo + frames.shape[1]) if (height_axis and n_h > 1) \
            else slice(None)
        t_raw = t_raw_ext[:, core]

        # --- Component 2: per-frame candidates (paper Eq. 6). ---
        b_loc = frames.shape[0]
        flat_t = t_raw.reshape(b_loc, -1)
        jmin = jnp.argmin(flat_t, axis=-1)
        t_min = jnp.take_along_axis(flat_t, jmin[:, None], axis=-1)[:, 0]
        rgb = jnp.take_along_axis(frames.reshape(b_loc, -1, 3),
                                  jmin[:, None, None], axis=1)[:, 0]
        if height_axis and n_h > 1:
            rgb = _gather_argmin_over_model(t_min, rgb, height_axis)

        # --- Refinement on the halo-extended block. ---
        if cfg.refine:
            t_ext = spatial.masked_guided_filter(
                guide_ext, t_raw_ext, valid, cfg.gf_radius, cfg.gf_eps)
            t = jnp.clip(t_ext[:, core], 0.0, 1.0)
        else:
            t = t_raw
        return t, t_min, rgb

    def fused_t_and_candidates(frames, state):
        """Fused megakernel form of ``staged_t_and_candidates``: one launch
        per block instead of the masked per-stage XLA chain."""
        if height_axis and n_h > 1:
            # Halo-aware fused kernel: the exchange output is the kernel
            # input; masking happens in-VMEM.
            pre_ext, guide_ext, valid = halo_premap_and_guide(frames, state)
            t, t_min, rgb = alg.fused_transmission_halo(
                frames, pre_ext, guide_ext, valid, cfg)
            rgb = _gather_argmin_over_model(t_min, rgb, height_axis)
        else:
            t, t_min, rgb = alg.fused_transmission(frames, state.A, cfg)
        return t, t_min, rgb

    def local_step(frames, frame_ids, state):
        b_loc = frames.shape[0]
        if use_fused:
            # Components 1 + 2 candidates + refinement in ONE launch.
            t, t_min, rgb = fused_t_and_candidates(frames, state)
        else:
            t, t_min, rgb = staged_t_and_candidates(frames, state)

        # State sync: all-gather candidates over the frame axes, scan,
        # slice the local part (the paper's A broadcast, minus the race).
        a_all = lax.all_gather(rgb, batch_axes, axis=0, tiled=True)
        ids_all = lax.all_gather(frame_ids, batch_axes, axis=0, tiled=True)
        a_seq_all, new_state = ema_scan_associative(
            a_all, ids_all, state, cfg.update_period, cfg.lam)
        didx = lax.axis_index(batch_axes)
        a_seq = lax.dynamic_slice_in_dim(a_seq_all, didx * b_loc, b_loc)
        a_seq = a_seq.astype(frames.dtype)

        # --- Component 3 on the core block. ---
        out = alg.generate_haze_free(frames, t, a_seq,
                                     dataclasses.replace(cfg, kernel_mode="ref"))
        return DehazeOutput(out, t, a_seq, new_state)

    state_spec = AtmoState(A=P(), last_update=P(), initialized=P())
    step = compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(fspec, ispec, state_spec),
        out_specs=DehazeOutput(frames=fspec, transmission=fspec,
                               atmo_light=ispec, state=state_spec),
        check_vma=False,
    )
    return step, fspec, ispec


__all__ = ["DehazeOutput", "make_dehaze_step", "make_multi_stream_step",
           "make_sharded_dehaze_step", "init_atmo_state",
           "init_atmo_state_lanes", "pack_atmo_states", "unpack_atmo_states",
           "AtmoState", "ema_scan", "ema_scan_associative", "DehazeConfig"]
