"""The paper's primary contribution: component-decomposed dehazing.

- ``config``     — DehazeConfig
- ``physics``    — atmospheric scattering model (Eq. 1/2/8)
- ``algorithms`` — the three generic components + DCP/CAP instantiations
- ``normalize``  — cross-frame atmospheric-light EMA normalization (§3.3)
- ``spatial``    — halo exchange + masked filters for within-frame sharding
- ``pipeline``   — jitted single-shard and shard_map dehaze steps
"""
from repro.core.config import DehazeConfig
from repro.core.normalize import (AtmoState, ema_scan, ema_scan_associative,
                                  ema_scan_lanes, get_lane_state,
                                  init_atmo_state, init_atmo_state_lanes,
                                  lane_carry, pack_atmo_states,
                                  set_lane_state, state_from_lane_carry,
                                  unpack_atmo_states)
from repro.core.pipeline import (DehazeOutput, make_dehaze_step,
                                 make_multi_stream_step,
                                 make_sharded_dehaze_step, make_step,
                                 resolve_lane_native)
from repro.core.placement import PlacementSpec

__all__ = [
    "DehazeConfig", "AtmoState", "ema_scan", "ema_scan_associative",
    "ema_scan_lanes", "init_atmo_state", "init_atmo_state_lanes",
    "lane_carry", "pack_atmo_states", "unpack_atmo_states",
    "state_from_lane_carry", "get_lane_state", "set_lane_state",
    "DehazeOutput", "PlacementSpec", "make_step", "make_dehaze_step",
    "make_multi_stream_step", "make_sharded_dehaze_step",
    "resolve_lane_native",
]
