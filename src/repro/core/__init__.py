"""The paper's primary contribution: component-decomposed dehazing.

- ``config``     — DehazeConfig
- ``physics``    — atmospheric scattering model (Eq. 1/2/8)
- ``algorithms`` — the three generic components + DCP/CAP instantiations
- ``normalize``  — cross-frame atmospheric-light EMA normalization (§3.3)
- ``spatial``    — halo exchange + masked filters for within-frame sharding
- ``pipeline``   — jitted single-shard and shard_map dehaze steps
"""
from repro.core.config import DehazeConfig
from repro.core.normalize import (AtmoState, ema_scan, ema_scan_associative,
                                  init_atmo_state)
from repro.core.pipeline import (DehazeOutput, make_dehaze_step,
                                 make_sharded_dehaze_step)

__all__ = [
    "DehazeConfig", "AtmoState", "ema_scan", "ema_scan_associative",
    "init_atmo_state", "DehazeOutput", "make_dehaze_step",
    "make_sharded_dehaze_step",
]
