"""Consolidated ``REPRO_*`` environment resolution.

Every runtime knob the repo reads from the environment goes through one
typed, validated accessor here — call sites (`kernels.ops`,
`kernels.tuning`, `core.pipeline`, `stream.elastic`, the benchmark
drivers) never touch ``os.environ`` directly. Unknown or malformed values
raise ``ValueError`` (the ``resolve_mode`` precedent: a typo like
``REPRO_KERNEL_MODE=Pallas`` must not silently select a different code
path), with one documented exception: ``REPRO_TUNE_<OP>`` overrides are
best-effort performance hints, so malformed JSON there is ignored rather
than taking a serving fleet down over a tuning experiment.

Knobs:

  REPRO_KERNEL_MODE      execution substrate / pipeline mode override
  REPRO_LANE_NATIVE      force the lane-native megakernel on (1) or off (0)
  REPRO_TICK_OVERLAP     force the zero-copy overlapped serve tick path on
                         (1) or off (0; the blocking parity oracle)
  REPRO_STEP_CACHE_SIZE  bounded LRU size of the jitted-step cache
  REPRO_KERNEL_TUNING    path of the persisted kernel-tuning table
  REPRO_TUNE_<OP>        per-op JSON tile-parameter override
  REPRO_TUNE_DEVICE_KIND override the device-kind key tuned winners
                         persist/resolve under (CI validates foreign tables)
  REPRO_TUNE_REQUIRE_TABLE
                         when truthy, get_params raises if neither a table
                         entry nor an env override exists (no silent defaults)
  REPRO_BENCH_SMOKE      benchmark drivers use tiny CI shapes when truthy

``snapshot()`` / ``restore()`` capture and reinstate the full ``REPRO_*``
environment for test isolation (monkeypatch-free setup/teardown of
multi-knob scenarios).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional

# Execution substrates and pipeline-level modes (see ``kernels.ops``):
# "fused" selects the megakernel path, "auto" defers to the backend.
SUBSTRATES = ("ref", "pallas", "interpret")
KERNEL_MODES = SUBSTRATES + ("fused", "auto")

_TUNING_DEFAULT_PATH = Path("results") / "kernel_tuning.json"


def kernel_mode() -> str:
    """``REPRO_KERNEL_MODE``: a mode from :data:`KERNEL_MODES`, or ``""``
    when unset. Unknown values raise."""
    env = os.environ.get("REPRO_KERNEL_MODE", "")
    if env and env not in KERNEL_MODES:
        raise ValueError(
            f"REPRO_KERNEL_MODE={env!r} is not a valid kernel mode; "
            f"expected one of {sorted(KERNEL_MODES)}, or unset it")
    return env


def lane_native() -> Optional[bool]:
    """``REPRO_LANE_NATIVE``: ``True`` (force lane-native), ``False``
    (force the vmapped path) or ``None`` when unset. Unknown values raise;
    the fused-coverage check the force implies lives with the config, in
    ``core.pipeline.resolve_lane_native``."""
    env = os.environ.get("REPRO_LANE_NATIVE", "")
    if env not in ("", "0", "1"):
        raise ValueError(
            f"REPRO_LANE_NATIVE={env!r} is not a valid override; expected "
            "'0' (force vmap), '1' (force lane-native) or unset")
    return None if env == "" else env == "1"


def tick_overlap() -> Optional[bool]:
    """``REPRO_TICK_OVERLAP``: ``True`` (force the zero-copy overlapped
    serve tick path), ``False`` (force the blocking path — the parity
    oracle) or ``None`` when unset. Unknown values raise. Whether forcing
    overlap on can actually be honored (device-resident staging needs
    ``jax.device_put`` + donation on the backend) is decided by
    ``stream.iobuf.donation_supported``; ``launch/serve.py`` turns a
    silent fallback into a hard failure under ``--expect-overlap``."""
    env = os.environ.get("REPRO_TICK_OVERLAP", "")
    if env not in ("", "0", "1"):
        raise ValueError(
            f"REPRO_TICK_OVERLAP={env!r} is not a valid override; expected "
            "'0' (force blocking), '1' (force overlap) or unset")
    return None if env == "" else env == "1"


def step_cache_size(default: int = 8) -> int:
    """``REPRO_STEP_CACHE_SIZE``: max entries in the bounded LRU jitted-step
    cache. Must parse as a positive integer."""
    env = os.environ.get("REPRO_STEP_CACHE_SIZE", "")
    if not env:
        return default
    try:
        size = int(env)
    except ValueError:
        raise ValueError(
            f"REPRO_STEP_CACHE_SIZE={env!r} is not an integer") from None
    if size < 1:
        raise ValueError(
            f"REPRO_STEP_CACHE_SIZE must be >= 1, got {size}")
    return size


def tuning_table_path() -> Path:
    """``REPRO_KERNEL_TUNING``: path of the persisted tuning table."""
    return Path(os.environ.get("REPRO_KERNEL_TUNING",
                               str(_TUNING_DEFAULT_PATH)))


def tune_override(op: str) -> Dict[str, Any]:
    """``REPRO_TUNE_<OP>``: JSON object of tile-parameter overrides for
    ``op``, ``{}`` when unset. Malformed JSON (or a non-object) is
    *ignored* — tuning overrides are performance hints, never allowed to
    turn a typo into a serving outage (unlike the mode knobs above)."""
    env = os.environ.get(f"REPRO_TUNE_{op.upper()}")
    if not env:
        return {}
    try:
        params = json.loads(env)
    except ValueError:
        return {}
    return params if isinstance(params, dict) else {}


def tune_device_kind() -> str:
    """``REPRO_TUNE_DEVICE_KIND``: overrides the device-kind key measured
    tuning winners persist (and resolve) under, ``""`` when unset — the
    hardware answer ``jax.devices()[0].device_kind`` then applies. Used by
    CI to validate a table tuned for foreign hardware without owning it."""
    return os.environ.get("REPRO_TUNE_DEVICE_KIND", "")


def tune_require_table() -> bool:
    """``REPRO_TUNE_REQUIRE_TABLE``: when set, ``tuning.get_params`` raises
    for lookups that found neither a measured table entry nor an env
    override — serving fleets opt in to "real measurements only" instead
    of silently running the built-in defaults. '0'/'1' or unset."""
    env = os.environ.get("REPRO_TUNE_REQUIRE_TABLE", "")
    if env not in ("", "0", "1"):
        raise ValueError(
            f"REPRO_TUNE_REQUIRE_TABLE={env!r} is not a valid value; "
            "expected '0', '1' or unset")
    return env == "1"


def bench_smoke() -> bool:
    """``REPRO_BENCH_SMOKE``: benchmark drivers shrink to CI smoke shapes
    when set to anything non-empty."""
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


# ---------------------------------------------------------------------------
# Test isolation
# ---------------------------------------------------------------------------

def snapshot() -> Dict[str, str]:
    """Current values of every ``REPRO_*`` variable (for :func:`restore`)."""
    return {k: v for k, v in os.environ.items() if k.startswith("REPRO_")}


def restore(snap: Dict[str, str]) -> None:
    """Reinstate a :func:`snapshot`: variables added since are removed,
    changed ones reset — the inverse of any ``REPRO_*`` mutation batch."""
    for k in [k for k in os.environ if k.startswith("REPRO_")]:
        if k not in snap:
            del os.environ[k]
    os.environ.update(snap)


__all__ = ["SUBSTRATES", "KERNEL_MODES", "kernel_mode", "lane_native",
           "tick_overlap",
           "step_cache_size", "tuning_table_path", "tune_override",
           "tune_device_kind", "tune_require_table", "bench_smoke",
           "snapshot", "restore"]
