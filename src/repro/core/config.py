"""Configuration for the dehazing pipeline (paper §3)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DehazeConfig:
    """Static configuration for one dehazing stream.

    Frozen + hashable so it can be closed over by jitted step functions.
    """
    # Which T-estimator instantiation (paper gives DCP and CAP).
    algorithm: str = "dcp"                 # "dcp" | "cap"

    # Shared component parameters.
    patch_radius: int = 7                  # Ω(x) window radius (15x15 patch)
    t0: float = 0.1                        # Eq. 8 transmission lower bound
    topk: int = 1                          # A-estimator candidates; 1 == Eq. 6
    refine: bool = True                    # guided-filter refinement of t
    gf_radius: int = 20
    gf_eps: float = 1e-3
    gamma: float = 1.0                     # serving epilogue tone curve

    # DCP (He et al. [13]).
    omega: float = 0.95                    # haze retention factor

    # CAP (Zhu et al. [23]) — published linear model coefficients.
    beta: float = 1.0
    cap_w0: float = 0.121779
    cap_w1: float = 0.959710
    cap_w2: float = -0.780245

    # Cross-frame atmospheric light update strategy (paper §3.3).
    update_period: int = 8                 # l: frames between A refreshes
    lam: float = 0.05                      # λ in A_m = λ A_new + (1-λ) A_k

    # Dataflow options.
    recompute_t_with_final_a: bool = False # extra accuracy pass (beyond paper)
    kernel_mode: str = "auto"              # ref | pallas | interpret | fused | auto
    #   "fused": single-pass megakernel path — DCP and CAP, any topk (k=1
    #   argmin or the robust in-VMEM top-k), including the halo-aware
    #   variant for height- and/or width-sharded meshes. The only fallback
    #   to the per-stage chain is DCP + recompute_t_with_final_a — see
    #   core.algorithms.supports_fused.
    dtype: str = "float32"

    # Perf levers for the sharded pipeline (EXPERIMENTS.md §Perf):
    halo_packed: bool = False   # exchange (cmin/depth, luma) 2-ch stack
    #                             instead of 3-ch RGB halos (1/3 less wire)
    halo_dtype: str = "float32" # bfloat16 halves halo wire bytes

    # Frame I/O dtype contract (README §Dtype contract). ``io_dtype`` is the
    # wire/ingest dtype of the frame stream — uint8 frames are the
    # quantization round(v*255) of the [0,1] float image and are upcast
    # in-VMEM by the kernels (kernels.ref.upcast_frames is THE canonical
    # form), cutting input HBM traffic 4x vs f32. Compute is always f32.
    # ``out_dtype`` is the J/t output dtype; "auto" follows the incoming
    # frame dtype for float ingest and resolves to float32 for uint8.
    io_dtype: str = "float32"   # float32 | bfloat16 | uint8
    out_dtype: str = "auto"     # auto | float32 | bfloat16

    def validate(self) -> "DehazeConfig":
        assert self.algorithm in ("dcp", "cap"), self.algorithm
        assert self.kernel_mode in ("auto", "ref", "pallas", "interpret",
                                    "fused"), self.kernel_mode
        assert 0.0 <= self.lam <= 1.0
        assert self.update_period >= 1
        assert self.patch_radius >= 0 and self.gf_radius >= 0
        assert 0.0 < self.t0 < 1.0
        assert self.io_dtype in ("float32", "bfloat16", "uint8"), self.io_dtype
        assert self.out_dtype in ("auto", "float32", "bfloat16"), self.out_dtype
        return self
