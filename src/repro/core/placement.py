"""Declarative placement: one spec for how every serving-batch axis maps
onto mesh axes.

Before this module each step builder hard-coded its own sharding story:
``make_dehaze_step`` assumed a single shard, ``make_multi_stream_step``
assumed a lane axis but no mesh, and ``make_sharded_dehaze_step`` took
three loose axis-name arguments. A :class:`PlacementSpec` declares the
whole mapping ONCE — the idiom of scalax's ``ShardingMetadata`` (declare
the rules, derive every PartitionSpec from them) — and
``core.pipeline.make_step(cfg, placement)`` realizes it:

  batch axis          mesh axes
  ------------------  -------------------------------------------------
  lane  (L)           ``lane_axis``    (pod-scale fleet: lanes → "data")
  frame (B)           ``batch_axes``   (data-parallel frames)
  height (H)          ``height_axis``  (halo-exchanged spatial shard)
  width  (W)          ``width_axis``   (halo-exchanged spatial shard)
  EMA / AtmoState     co-placed: lane-batched state rows shard over
                      ``lane_axis`` with their lanes, otherwise replicated

The spec is a frozen, hashable dataclass so it can key the serving-tier
step cache (``stream.elastic``) and ride through ``jax.jit`` static
arguments; ``to_dict``/``from_dict`` give a JSON-able wire form for
launch configs. ``n_hosts`` is the *serving* fan-out consumed by the
fleet scheduler (how many host-level schedulers sit behind one front
door) — it does not alter the per-host device program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro.core.normalize import AtmoState


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Where every axis of the serving batch lives.

    ``lanes`` declares a leading lane axis on the batch (``(L, B, ...)``
    multi-stream layout); ``lane_axis`` additionally shards it over a
    mesh axis — each shard then owns whole lanes, so per-lane EMA state
    rows are co-placed with their lanes and the causal scan needs no
    cross-shard sync. ``batch_axes`` shards the frame axis (single-stream
    data parallelism; mutually exclusive with a *sharded* lane axis,
    where each lane's batch must stay local to keep its scan causal).
    ``height_axis``/``width_axis`` shard the image plane with halo
    exchange. ``n_hosts`` sizes the fleet tier (see module docstring).
    """
    lanes: bool = False
    lane_axis: Optional[str] = None
    batch_axes: Tuple[str, ...] = ()
    height_axis: Optional[str] = None
    width_axis: Optional[str] = None
    n_hosts: int = 1

    def __post_init__(self):
        # Hashability guarantee: list-valued batch_axes (e.g. straight from
        # JSON) coerce to a tuple before the frozen instance is ever used.
        if not isinstance(self.batch_axes, tuple):
            object.__setattr__(self, "batch_axes", tuple(self.batch_axes))

    # -- validation --------------------------------------------------------

    def validate(self) -> "PlacementSpec":
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.n_hosts}")
        if self.lane_axis is not None and not self.lanes:
            raise ValueError(
                f"lane_axis={self.lane_axis!r} requires lanes=True (a "
                "sharded lane axis needs a lane axis to shard)")
        if self.lane_axis is not None and self.batch_axes:
            raise ValueError(
                "a sharded lane axis is mutually exclusive with batch_axes: "
                "each lane's frame batch must stay shard-local so its EMA "
                f"scan is causal (got lane_axis={self.lane_axis!r}, "
                f"batch_axes={self.batch_axes!r})")
        if self.lanes and self.batch_axes:
            raise ValueError(
                "lane-batched placements do not shard the frame axis; "
                f"got batch_axes={self.batch_axes!r}")
        named = [ax for ax in ((self.lane_axis,) + self.batch_axes
                               + (self.height_axis, self.width_axis))
                 if ax is not None]
        if len(set(named)) != len(named):
            raise ValueError(f"mesh axes must be distinct, got {named}")
        return self

    # -- derived views -----------------------------------------------------

    @property
    def mesh_axes(self) -> Tuple[str, ...]:
        """Every mesh axis the spec names, in batch-axis order."""
        return tuple(ax for ax in ((self.lane_axis,) + self.batch_axes
                                   + (self.height_axis, self.width_axis))
                     if ax is not None)

    @property
    def sharded(self) -> bool:
        """Does realizing this placement need a mesh at all?"""
        return bool(self.mesh_axes)

    def frame_spec(self) -> P:
        """PartitionSpec for the frame batch: ``(B, H, W, 3)`` or, with
        ``lanes``, ``(L, B, H, W, 3)``."""
        spatial = (self.height_axis, self.width_axis)
        if self.lanes:
            return P(self.lane_axis, None, *spatial)
        return P(self.batch_axes if self.batch_axes else None, *spatial)

    def ids_spec(self) -> P:
        """PartitionSpec for frame ids: ``(B,)`` or ``(L, B)``."""
        if self.lanes:
            return P(self.lane_axis)
        return P(self.batch_axes if self.batch_axes else None)

    def state_spec(self) -> AtmoState:
        """AtmoState placement: lane rows co-placed with their lanes
        (sharded over ``lane_axis``), otherwise replicated."""
        row = P(self.lane_axis) if self.lanes else P()
        return AtmoState(A=row, last_update=row, initialized=row)

    # -- wire form ---------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["batch_axes"] = list(self.batch_axes)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlacementSpec":
        d = dict(d)
        d["batch_axes"] = tuple(d.get("batch_axes", ()))
        return cls(**d).validate()

    # -- common constructions ---------------------------------------------

    @classmethod
    def single(cls) -> "PlacementSpec":
        """One shard, one host: the plain batched step."""
        return cls()

    @classmethod
    def lane_batched(cls, n_hosts: int = 1) -> "PlacementSpec":
        """Multi-stream lane batch on one device (fleet tier optional)."""
        return cls(lanes=True, n_hosts=n_hosts).validate()

    @classmethod
    def lane_sharded(cls, lane_axis: str = "data",
                     height_axis: Optional[str] = None,
                     width_axis: Optional[str] = None,
                     n_hosts: int = 1) -> "PlacementSpec":
        """Pod-scale lanes: the lane axis shards over the data mesh axis
        (each shard serves whole lanes), optionally composed with H/W
        halo sharding inside each shard."""
        return cls(lanes=True, lane_axis=lane_axis, height_axis=height_axis,
                   width_axis=width_axis, n_hosts=n_hosts).validate()

    @classmethod
    def frame_sharded(cls, batch_axes: Tuple[str, ...] = ("data",),
                      height_axis: Optional[str] = "model",
                      width_axis: Optional[str] = None) -> "PlacementSpec":
        """The classic single-stream production placement (frames over the
        data axes, height/width over the model-side axes)."""
        return cls(batch_axes=tuple(batch_axes or ()),
                   height_axis=height_axis, width_axis=width_axis).validate()


__all__ = ["PlacementSpec"]
