"""Cross-frame atmospheric-light normalization (paper §3.3).

The paper's update strategy: a selected estimator broadcasts its estimate;
peers reuse the saved value while the frame distance to the last update is
below the period ``l``; at distance >= l the state refreshes through the
EMA ``A_m = λ·A_new + (1−λ)·A_k`` (Eq. 9) and the new value is shared.

Storm realizes this with asynchronous thread messaging; the result then
depends on scheduling order. Our SPMD realization is a *deterministic
causal scan* over the frame axis implementing the identical recurrence:

  - ``ema_scan``             — lax.scan, handles arbitrary (sorted) frame ids,
                               including gaps left by dropped frames;
  - ``ema_scan_associative`` — log-depth ``lax.associative_scan`` fast path
                               for consecutive frame ids (the common case),
                               bit-identical to ``ema_scan`` there.

State is a tiny pytree so it checkpoints/replicates for free; in the
sharded pipeline the per-frame candidates are all-gathered along the frame
axis (a few dozen bytes) before the scan — that collective *is* the
paper's broadcast, minus the race.

**Padding frames.** A ``frame_id < 0`` marks padding (the spout's tail
fill, or a whole padded lane in the multi-stream scheduler). Both scans
mask such frames out of the recurrence: they never trigger an update,
never flip ``initialized``, and their output slot carries the running A
unchanged. A batch of *only* padding behaves exactly like the empty batch.

**Lanes.** The multi-tenant serving runtime batches L independent streams
along a leading lane axis. ``AtmoState`` itself is the lane container —
stack every leaf with ``pack_atmo_states`` and the result is an AtmoState
with ``A (L, 3) / last_update (L,) / initialized (L,)`` that vmaps over
lane 0. Padded (unoccupied) lanes carry all-padding frame ids, so the
per-frame mask above doubles as the lane-validity mask: a dead lane's
state rides through every step bit-unchanged. ``lane_carry`` /
``state_from_lane_carry`` convert between this pytree and the
``(L, 3)``/``(L, 2)`` carry-row layout the lane-native megakernel keeps
in VMEM scratch, so the serving runtime's packed state feeds the kernel
grid directly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AtmoState:
    """Shared atmospheric-light state for one video stream."""
    A: jnp.ndarray            # (3,) float32 — current shared estimate A_k
    last_update: jnp.ndarray  # ()  int32   — frame id k of the last refresh
    initialized: jnp.ndarray  # ()  bool    — False until the first frame


def init_atmo_state() -> AtmoState:
    """Bootstrap: white atmospheric light until the first estimate lands."""
    return AtmoState(
        A=jnp.ones((3,), jnp.float32),
        last_update=jnp.asarray(-(2 ** 30), jnp.int32),
        initialized=jnp.asarray(False),
    )


def ema_scan(a_cand: jnp.ndarray, frame_ids: jnp.ndarray, state: AtmoState,
             period: int, lam: float) -> Tuple[jnp.ndarray, AtmoState]:
    """Sequential reference scan (general frame ids, sorted ascending).

    Args:
      a_cand: (B, 3) per-frame A_new candidates (paper's per-estimator output).
      frame_ids: (B,) int32 global frame ids; ids < 0 mark padding frames
        that are masked out of the recurrence entirely.
    Returns: ((B, 3) per-frame normalized A, updated state).

    A zero-length batch (empty spout tail, elastic drain) is a no-op: the
    state — *including* ``initialized`` — passes through unchanged, so the
    next real first frame still bootstraps (replaces the white-light
    placeholder) instead of being EMA-blended with it. A batch of only
    padding ids behaves the same way.
    """
    a_cand = a_cand.astype(jnp.float32)
    if a_cand.shape[0] == 0:
        return a_cand.reshape(0, 3), state

    def step(carry, x):
        A_prev, k, inited = carry
        cand, fid = x
        valid = fid >= 0
        bootstrap = jnp.logical_and(valid, jnp.logical_not(inited))
        do_update = jnp.logical_and(valid, jnp.logical_or(
            bootstrap, (fid - k) >= period))
        target = jnp.where(bootstrap, cand, lam * cand + (1.0 - lam) * A_prev)
        A_next = jnp.where(do_update, target, A_prev)
        k_next = jnp.where(do_update, fid, k)
        return (A_next, k_next, jnp.logical_or(inited, valid)), A_next

    (A_fin, k_fin, inited_fin), a_seq = jax.lax.scan(
        step, (state.A, state.last_update, state.initialized),
        (a_cand, frame_ids))
    new_state = AtmoState(A=A_fin, last_update=k_fin,
                          initialized=inited_fin)
    return a_seq, new_state


def _update_mask(frame_ids: jnp.ndarray, state: AtmoState,
                 period: int) -> jnp.ndarray:
    """Closed-form update positions for *consecutive valid* frame ids.

    With consecutive ids the data-dependent trigger ``fid - k >= period``
    collapses to a fixed comb: first update at u0 = max(fid0, k0 + period)
    (or fid0 when uninitialized), then every ``period`` frames. Padding
    ids (< 0) are masked out — they used to alias the *future real* ids
    the spout later hands to real frames, double-advancing the EMA.
    """
    valid = frame_ids >= 0
    fid0 = frame_ids[jnp.argmax(valid)]          # first valid id (if any)
    u0 = jnp.where(state.initialized,
                   jnp.maximum(fid0, state.last_update + period), fid0)
    d = frame_ids - u0
    return jnp.logical_and(valid, jnp.logical_and(d >= 0, d % period == 0))


def ema_scan_associative(a_cand: jnp.ndarray, frame_ids: jnp.ndarray,
                         state: AtmoState, period: int,
                         lam: float) -> Tuple[jnp.ndarray, AtmoState]:
    """Log-depth path for consecutive frame ids.

    The recurrence is linear: A_i = c_i * A_{i-1} + d_i with
    c_i = 1 - λ·m_i (or 0 on bootstrap), d_i = λ·m_i·cand_i. Composition
    (c2, d2) ∘ (c1, d1) = (c2·c1, c2·d1 + d2) is associative.

    Empty batches pass the state through untouched (see ``ema_scan``),
    as do padding frames (ids < 0): their c_i = 1, d_i = 0 identity slot
    carries the running A through unchanged.
    """
    a_cand = a_cand.astype(jnp.float32)
    if a_cand.shape[0] == 0:
        return a_cand.reshape(0, 3), state
    valid = frame_ids >= 0
    mask = _update_mask(frame_ids, state, period)
    bootstrap = jnp.logical_and(
        jnp.logical_and(jnp.logical_not(state.initialized), valid),
        jnp.arange(frame_ids.shape[0]) == jnp.argmax(valid))
    m = mask.astype(jnp.float32)[:, None]
    c = jnp.where(bootstrap[:, None], 0.0, 1.0 - lam * m)
    d = jnp.where(bootstrap[:, None], a_cand, lam * m * a_cand)

    def combine(p, q):
        (c1, d1), (c2, d2) = p, q
        return c2 * c1, c2 * d1 + d2

    cc, dd = jax.lax.associative_scan(combine, (c, d))
    a_seq = cc * state.A[None, :] + dd

    upd = jnp.logical_or(mask, bootstrap)
    any_upd = jnp.any(upd)
    idx_last = jnp.where(any_upd, jnp.argmax(
        jnp.where(upd, frame_ids, jnp.int32(-2 ** 30))), 0)
    new_state = AtmoState(
        A=a_seq[-1],
        last_update=jnp.where(any_upd, frame_ids[idx_last], state.last_update),
        initialized=jnp.logical_or(state.initialized, jnp.any(valid)),
    )
    return a_seq, new_state


# ---------------------------------------------------------------------------
# Lane-batched state (multi-tenant serving: L streams in one device batch)
# ---------------------------------------------------------------------------

def init_atmo_state_lanes(n_lanes: int) -> AtmoState:
    """Lane-batched bootstrap: ``n_lanes`` independent white-light states
    stacked on a leading lane axis (A (L, 3), last_update (L,),
    initialized (L,))."""
    return pack_atmo_states([init_atmo_state() for _ in range(n_lanes)])


def pack_atmo_states(states: Sequence[AtmoState]) -> AtmoState:
    """Stack per-stream states into one lane-batched AtmoState (lane 0 axis)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unpack_atmo_states(state: AtmoState) -> List[AtmoState]:
    """Inverse of ``pack_atmo_states``: lane-batched -> per-lane states."""
    n = state.A.shape[0]
    return [get_lane_state(state, i) for i in range(n)]


def get_lane_state(state: AtmoState, lane: int) -> AtmoState:
    """Extract one lane's (3,)/()/() state from a lane-batched AtmoState."""
    return jax.tree_util.tree_map(lambda x: x[lane], state)


def set_lane_state(packed: AtmoState, lane: int, state: AtmoState) -> AtmoState:
    """Functionally replace one lane of a lane-batched AtmoState (admission:
    a new stream takes over a free/evicted lane)."""
    return jax.tree_util.tree_map(
        lambda p, s: p.at[lane].set(jnp.asarray(s, p.dtype)), packed, state)


def lane_carry(state: AtmoState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lane-batched AtmoState -> the lane-native megakernel's carry layout.

    Returns ``(carry_f (L, 3) float32, carry_i (L, 2) int32)`` — row
    ``l`` is lane ``l``'s (A,) and (last_update, initialized). This is
    exactly the per-lane scratch-row layout
    ``kernels.fused.fused_dehaze_lanes_pallas`` carries across its grid,
    so the packed state feeds the kernel with no per-lane unstacking."""
    return (state.A.astype(jnp.float32),
            jnp.stack([state.last_update.astype(jnp.int32),
                       state.initialized.astype(jnp.int32)], axis=-1))


def state_from_lane_carry(carry_f: jnp.ndarray,
                          carry_i: jnp.ndarray) -> AtmoState:
    """Inverse of :func:`lane_carry`: kernel carry rows -> lane-batched
    AtmoState."""
    return AtmoState(A=carry_f, last_update=carry_i[..., 0],
                     initialized=carry_i[..., 1].astype(bool))


def ema_scan_lanes(a_cand: jnp.ndarray, frame_ids: jnp.ndarray,
                   state: AtmoState, period: int, lam: float,
                   associative: bool = True) -> Tuple[jnp.ndarray, AtmoState]:
    """Lane-batched scan: (L, B, 3) candidates, (L, B) ids, lane-batched
    state -> ((L, B, 3), lane-batched state). Each lane scans its own
    causal chain; padded lanes (all ids < 0) pass through untouched."""
    scan = ema_scan_associative if associative else ema_scan
    return jax.vmap(lambda a, f, s: scan(a, f, s, period, lam))(
        a_cand, frame_ids, state)
