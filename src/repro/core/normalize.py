"""Cross-frame atmospheric-light normalization (paper §3.3).

The paper's update strategy: a selected estimator broadcasts its estimate;
peers reuse the saved value while the frame distance to the last update is
below the period ``l``; at distance >= l the state refreshes through the
EMA ``A_m = λ·A_new + (1−λ)·A_k`` (Eq. 9) and the new value is shared.

Storm realizes this with asynchronous thread messaging; the result then
depends on scheduling order. Our SPMD realization is a *deterministic
causal scan* over the frame axis implementing the identical recurrence:

  - ``ema_scan``             — lax.scan, handles arbitrary (sorted) frame ids,
                               including gaps left by dropped frames;
  - ``ema_scan_associative`` — log-depth ``lax.associative_scan`` fast path
                               for consecutive frame ids (the common case),
                               bit-identical to ``ema_scan`` there.

State is a tiny pytree so it checkpoints/replicates for free; in the
sharded pipeline the per-frame candidates are all-gathered along the frame
axis (a few dozen bytes) before the scan — that collective *is* the
paper's broadcast, minus the race.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AtmoState:
    """Shared atmospheric-light state for one video stream."""
    A: jnp.ndarray            # (3,) float32 — current shared estimate A_k
    last_update: jnp.ndarray  # ()  int32   — frame id k of the last refresh
    initialized: jnp.ndarray  # ()  bool    — False until the first frame


def init_atmo_state() -> AtmoState:
    """Bootstrap: white atmospheric light until the first estimate lands."""
    return AtmoState(
        A=jnp.ones((3,), jnp.float32),
        last_update=jnp.asarray(-(2 ** 30), jnp.int32),
        initialized=jnp.asarray(False),
    )


def ema_scan(a_cand: jnp.ndarray, frame_ids: jnp.ndarray, state: AtmoState,
             period: int, lam: float) -> Tuple[jnp.ndarray, AtmoState]:
    """Sequential reference scan (general frame ids, sorted ascending).

    Args:
      a_cand: (B, 3) per-frame A_new candidates (paper's per-estimator output).
      frame_ids: (B,) int32 global frame ids.
    Returns: ((B, 3) per-frame normalized A, updated state).

    A zero-length batch (empty spout tail, elastic drain) is a no-op: the
    state — *including* ``initialized`` — passes through unchanged, so the
    next real first frame still bootstraps (replaces the white-light
    placeholder) instead of being EMA-blended with it.
    """
    a_cand = a_cand.astype(jnp.float32)
    if a_cand.shape[0] == 0:
        return a_cand.reshape(0, 3), state

    def step(carry, x):
        A_prev, k, inited = carry
        cand, fid = x
        bootstrap = jnp.logical_not(inited)
        do_update = jnp.logical_or(bootstrap, (fid - k) >= period)
        target = jnp.where(bootstrap, cand, lam * cand + (1.0 - lam) * A_prev)
        A_next = jnp.where(do_update, target, A_prev)
        k_next = jnp.where(do_update, fid, k)
        return (A_next, k_next, jnp.asarray(True)), A_next

    (A_fin, k_fin, _), a_seq = jax.lax.scan(
        step, (state.A, state.last_update, state.initialized),
        (a_cand, frame_ids))
    new_state = AtmoState(A=A_fin, last_update=k_fin,
                          initialized=jnp.asarray(True))
    return a_seq, new_state


def _update_mask(frame_ids: jnp.ndarray, state: AtmoState,
                 period: int) -> jnp.ndarray:
    """Closed-form update positions for *consecutive* frame ids.

    With consecutive ids the data-dependent trigger ``fid - k >= period``
    collapses to a fixed comb: first update at u0 = max(fid0, k0 + period)
    (or fid0 when uninitialized), then every ``period`` frames.
    """
    fid0 = frame_ids[0]
    u0 = jnp.where(state.initialized,
                   jnp.maximum(fid0, state.last_update + period), fid0)
    d = frame_ids - u0
    return jnp.logical_and(d >= 0, d % period == 0)


def ema_scan_associative(a_cand: jnp.ndarray, frame_ids: jnp.ndarray,
                         state: AtmoState, period: int,
                         lam: float) -> Tuple[jnp.ndarray, AtmoState]:
    """Log-depth path for consecutive frame ids.

    The recurrence is linear: A_i = c_i * A_{i-1} + d_i with
    c_i = 1 - λ·m_i (or 0 on bootstrap), d_i = λ·m_i·cand_i. Composition
    (c2, d2) ∘ (c1, d1) = (c2·c1, c2·d1 + d2) is associative.

    Empty batches pass the state through untouched (see ``ema_scan``).
    """
    a_cand = a_cand.astype(jnp.float32)
    if a_cand.shape[0] == 0:
        return a_cand.reshape(0, 3), state
    mask = _update_mask(frame_ids, state, period)
    bootstrap = jnp.logical_and(jnp.logical_not(state.initialized),
                                jnp.arange(frame_ids.shape[0]) == 0)
    m = mask.astype(jnp.float32)[:, None]
    c = jnp.where(bootstrap[:, None], 0.0, 1.0 - lam * m)
    d = jnp.where(bootstrap[:, None], a_cand, lam * m * a_cand)

    def combine(p, q):
        (c1, d1), (c2, d2) = p, q
        return c2 * c1, c2 * d1 + d2

    cc, dd = jax.lax.associative_scan(combine, (c, d))
    a_seq = cc * state.A[None, :] + dd

    upd = jnp.logical_or(mask, bootstrap)
    any_upd = jnp.any(upd)
    idx_last = jnp.where(any_upd, jnp.argmax(
        jnp.where(upd, frame_ids, jnp.int32(-2 ** 30))), 0)
    new_state = AtmoState(
        A=a_seq[-1],
        last_update=jnp.where(any_upd, frame_ids[idx_last], state.last_update),
        initialized=jnp.logical_or(state.initialized, jnp.asarray(True)),
    )
    return a_seq, new_state
