"""Atomic, manifest-driven checkpointing with async writes."""
from repro.checkpoint.manager import (AsyncCheckpointer, CheckpointManager,
                                      load_pytree, save_pytree)

__all__ = ["CheckpointManager", "AsyncCheckpointer", "save_pytree",
           "load_pytree"]
