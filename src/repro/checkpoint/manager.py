"""Fault-tolerant checkpointing: atomic, manifest-driven, async-capable.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a temp dir
and atomically renamed — a crash mid-write can never leave a readable but
corrupt checkpoint. ``keep`` old checkpoints are retained for rollback.
``AsyncCheckpointer`` moves serialization off the training critical path
(device→host copy happens synchronously — it must, for consistency — the
file I/O happens in a background thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any, extra: Optional[dict] = None) -> None:
    """Atomically save a pytree of arrays + JSON-serializable extras."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "extra": extra or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype authority)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for r, l in zip(restored, leaves):
        assert r.shape == tuple(l.shape), (r.shape, l.shape)
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["extra"]


class CheckpointManager:
    """step-indexed checkpoints with retention + latest-resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        path = self._step_dir(step)
        save_pytree(path, tree, extra)
        self._gc()
        return path

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[Any, dict, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree, extra = load_pytree(self._step_dir(step), like)
        return tree, extra, step

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (device sync is eager)."""

    def __init__(self, manager: CheckpointManager):
        self._mgr = manager
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        self.wait()
        # Materialize on host NOW (consistency point), write in background.
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                self._mgr.save(step, host_tree, extra)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
