"""Pallas TPU kernels for the dehazing hot spots + jnp oracles.

Modules:
  dark_channel  — fused channel-min + separable windowed-min (DCP Eq. 3)
  boxfilter     — running-sum separable box filter (guided-filter core)
  recover       — fused haze-free recovery epilogue (Eq. 8)
  atmolight     — argmin-t atmospheric light reduction (Eq. 6)
  fused         — single-pass DCP/CAP megakernels (Eq. 3/4+6+9+8 in one
                  launch), incl. the halo-aware height-sharded variant
  tuning        — block-size/tiling registry + autotune sweep
  ops           — jitted dispatch wrappers (ref | pallas | interpret | fused)
  ref           — pure-jnp oracles for all of the above
"""
from repro.kernels import ops, ref, tuning  # noqa: F401
