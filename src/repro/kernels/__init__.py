"""Pallas TPU kernels for the dehazing hot spots + jnp oracles.

Modules:
  dark_channel  — fused channel-min + separable windowed-min (DCP Eq. 3)
  boxfilter     — running-sum separable box filter (guided-filter core)
  recover       — fused haze-free recovery epilogue (Eq. 8)
  atmolight     — argmin-t / robust top-k atmospheric light reduction
                  (Eq. 5/6) + the shared in-VMEM top-k running selection
  fused         — single-pass DCP/CAP megakernels (Eq. 3/4+5/6+9+8 in one
                  launch), incl. the halo-aware variant for height- and/or
                  width-sharded meshes (2-D validity masking)
  tuning        — block-size/tiling registry + autotune sweep
  ops           — jitted dispatch wrappers (ref | pallas | interpret | fused)
  ref           — pure-jnp oracles for all of the above
"""
from repro.kernels import ops, ref, tuning  # noqa: F401
