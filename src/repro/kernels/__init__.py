"""Pallas TPU kernels for the dehazing hot spots + jnp oracles.

Modules:
  dark_channel  — fused channel-min + separable windowed-min (DCP Eq. 3)
  boxfilter     — running-sum separable box filter (guided-filter core)
  recover       — fused haze-free recovery epilogue (Eq. 8)
  atmolight     — argmin-t atmospheric light reduction (Eq. 6)
  ops           — jitted dispatch wrappers (ref | pallas | interpret)
  ref           — pure-jnp oracles for all of the above
"""
from repro.kernels import ops, ref  # noqa: F401
