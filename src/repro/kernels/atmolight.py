"""Pallas TPU kernels: atmospheric-light argmin-t reduction (paper Eq. 6)
and its robust top-k generalization (mean of I over the k smallest-t pixels).

A = I(x*) where x* = argmin_x t(x). Implemented as a fused single-pass
reduction: each grid step reduces one frame's row-tile in VMEM to a
(min_t, R, G, B) quadruple and folds it into the running output — the
sequential TPU grid makes the cross-tile fold race-free.

``atmolight_topk_pallas`` extends the same fold to k rows: each tile's
local top-k (selected in-VMEM by ``topk_select``, a k-step lexicographic
(t, index) running selection) is merged with the k rows carried in the
output ref, so the cross-tile state is 4k floats + k indices regardless of
frame size. Tie-breaking is by global flat pixel index, matching
``lax.top_k`` (and therefore ``kernels.ref.atmospheric_light``) exactly —
the fused megakernel (``kernels.fused``) reuses ``topk_select`` for its
in-kernel candidates so all three paths pick identical pixels.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT32_MAX = 2 ** 31 - 1


def flat_iota_2d(h: int, w: int) -> jnp.ndarray:
    """Row-major flat pixel index as a 2-D int32 map (TPU needs >= 2-D
    iota) — the tie-break key shared by every top-k selection site."""
    return (jax.lax.broadcasted_iota(jnp.int32, (h, w), 0) * w
            + jax.lax.broadcasted_iota(jnp.int32, (h, w), 1))


def topk_select(t: jnp.ndarray, idx: jnp.ndarray, rgb: jnp.ndarray,
                k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """k-step running selection of the lexicographically smallest (t, idx).

    ``t``/``idx`` share any shape; ``rgb`` adds a trailing channel axis.
    Returns ``(t_k (k,), idx_k (k,), rgb_k (k, C))`` in ascending (t, idx)
    order — the same set, order and tie-breaking as
    ``lax.top_k(-t.ravel(), k)`` when ``idx`` is the flat pixel index.

    Pallas-safe by construction: each step is two reductions plus a masked
    sum (no sort, no gather), so it traces inside a TPU kernel body where
    ``lax.top_k``/``lax.sort`` do not. Requires k <= t.size; duplicated
    (t, idx) pairs would be picked once per duplicate.
    """
    lead_axes = tuple(range(t.ndim))
    t_work = t
    t_out, i_out, rgb_out = [], [], []
    for _ in range(k):
        t_min = jnp.min(t_work)
        at_min = t_work == t_min
        i_min = jnp.min(jnp.where(at_min, idx, _INT32_MAX))
        pick = jnp.logical_and(at_min, idx == i_min)
        t_out.append(t_min)
        i_out.append(i_min)
        rgb_out.append(jnp.sum(jnp.where(pick[..., None], rgb, 0.0),
                               axis=lead_axes))
        t_work = jnp.where(pick, jnp.inf, t_work)
    return jnp.stack(t_out), jnp.stack(i_out), jnp.stack(rgb_out)


def _atmolight_kernel(img_ref, t_ref, out_ref):
    h_idx = pl.program_id(1)
    img = img_ref[0].astype(jnp.float32)           # (TH, W, 3)
    t = t_ref[0].astype(jnp.float32)               # (TH, W)

    flat_t = t.reshape(-1)
    flat_i = img.reshape(-1, 3)
    j = jnp.argmin(flat_t)
    tile_min = flat_t[j]
    tile_rgb = flat_i[j]

    @pl.when(h_idx == 0)
    def _init():
        out_ref[0, 0] = tile_min
        out_ref[0, 1:4] = tile_rgb

    @pl.when(h_idx != 0)
    def _fold():
        best = out_ref[0, 0]
        take = tile_min < best
        out_ref[0, 0] = jnp.where(take, tile_min, best)
        out_ref[0, 1:4] = jnp.where(take, tile_rgb, out_ref[0, 1:4])


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def atmolight_pallas(img: jnp.ndarray, t_raw: jnp.ndarray,
                     tile_h: int = 0, interpret: bool = False) -> jnp.ndarray:
    """(B,H,W,3), (B,H,W) -> (B,3): I at the per-frame argmin of t_raw."""
    b, h, w, c = img.shape
    assert c == 3 and t_raw.shape == (b, h, w)
    if tile_h <= 0 or h % tile_h != 0:
        tile_h = h
    n_tiles = h // tile_h
    out = pl.pallas_call(
        _atmolight_kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_h, w, 3), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, tile_h, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 4), jnp.float32),
        interpret=interpret,
    )(img, t_raw)
    return out[:, 1:4].astype(img.dtype)


def _atmolight_topk_kernel(img_ref, t_ref, out_f_ref, out_i_ref, *,
                           k: int, tile_h: int):
    h_idx = pl.program_id(1)
    img = img_ref[0].astype(jnp.float32)           # (TH, W, 3)
    t = t_ref[0].astype(jnp.float32)               # (TH, W)
    th, w = t.shape

    # Tile-local top-k with *global* flat pixel indices (row-major tiles are
    # flat-contiguous, so global = tile offset + local).
    gidx = flat_iota_2d(th, w) + h_idx * tile_h * w
    tk_t, tk_i, tk_rgb = topk_select(t, gidx, img, k)

    @pl.when(h_idx == 0)
    def _init():
        out_f_ref[0, :, 0] = tk_t
        out_f_ref[0, :, 1:4] = tk_rgb
        out_i_ref[0] = tk_i

    @pl.when(h_idx != 0)
    def _fold():
        # Merge the carried k rows with the tile's k rows: a top-k over the
        # 2k-entry union, same lexicographic (t, idx) rule.
        all_t = jnp.concatenate([out_f_ref[0, :, 0], tk_t])
        all_i = jnp.concatenate([out_i_ref[0], tk_i])
        all_rgb = jnp.concatenate([out_f_ref[0, :, 1:4], tk_rgb])
        m_t, m_i, m_rgb = topk_select(all_t, all_i, all_rgb, k)
        out_f_ref[0, :, 0] = m_t
        out_f_ref[0, :, 1:4] = m_rgb
        out_i_ref[0] = m_i


@functools.partial(jax.jit, static_argnames=("k", "tile_h", "interpret"))
def atmolight_topk_pallas(img: jnp.ndarray, t_raw: jnp.ndarray, k: int,
                          tile_h: int = 0,
                          interpret: bool = False) -> jnp.ndarray:
    """(B,H,W,3), (B,H,W) -> (B,3): mean of I over the k smallest-t pixels.

    k=1 is numerically identical to ``atmolight_pallas`` (argmin with
    first-index tie-break); any k matches ``kernels.ref.atmospheric_light``
    because both break ties by flat pixel index.
    """
    b, h, w, c = img.shape
    assert c == 3 and t_raw.shape == (b, h, w)
    assert 1 <= k <= h * w, (k, h, w)
    if tile_h <= 0 or h % tile_h != 0 or tile_h * w < k:
        tile_h = h
    n_tiles = h // tile_h
    kernel = functools.partial(_atmolight_topk_kernel, k=k, tile_h=tile_h)
    out_f, _ = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_h, w, 3), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, tile_h, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k, 4), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k, 4), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(img, t_raw)
    return out_f[:, :, 1:4].mean(axis=1).astype(img.dtype)


def _merge_topk_kernel(t_ref, i_ref, rgb_ref, out_f_ref, out_i_ref, *,
                       k: int):
    """Grid-carry fold over candidate-list segments: each step merges one
    ``seg``-wide slice of the (M)-row list into the k rows carried in the
    output refs — the same 2k-union ``topk_select`` fold as
    ``_atmolight_topk_kernel``, applied to already-reduced candidates
    instead of pixels."""
    s_idx = pl.program_id(1)
    seg_t = t_ref[0].astype(jnp.float32)            # (seg,)
    seg_i = i_ref[0]                                # (seg,) int32
    seg_rgb = rgb_ref[0].astype(jnp.float32)        # (seg, 3)
    tk_t, tk_i, tk_rgb = topk_select(seg_t, seg_i, seg_rgb, k)

    @pl.when(s_idx == 0)
    def _init():
        out_f_ref[0, :, 0] = tk_t
        out_f_ref[0, :, 1:4] = tk_rgb
        out_i_ref[0] = tk_i

    @pl.when(s_idx != 0)
    def _fold():
        all_t = jnp.concatenate([out_f_ref[0, :, 0], tk_t])
        all_i = jnp.concatenate([out_i_ref[0], tk_i])
        all_rgb = jnp.concatenate([out_f_ref[0, :, 1:4], tk_rgb])
        m_t, m_i, m_rgb = topk_select(all_t, all_i, all_rgb, k)
        out_f_ref[0, :, 0] = m_t
        out_f_ref[0, :, 1:4] = m_rgb
        out_i_ref[0] = m_i


@functools.partial(jax.jit, static_argnames=("k", "seg", "interpret"))
def merge_topk_pallas(tk_t: jnp.ndarray, tk_idx: jnp.ndarray,
                      tk_rgb: jnp.ndarray, k: int, seg: int = 0,
                      interpret: bool = False) -> jnp.ndarray:
    """Cross-shard candidate merge: ``(B, M)`` t / global-index lists +
    ``(B, M, 3)`` rgb -> ``(B, 3)`` mean of the k lexicographically
    smallest (t, index) rows.

    This is the in-kernel form of the sharded pipeline's gather-then-
    ``lax.sort`` candidate merge (M = n_shards * k rows after the
    all-gather): the list folds through the sequential grid carry in
    ``seg``-row segments, so the cross-segment state is 4k floats + k
    indices and no sort materializes. Tie-breaking is by global flat
    index — identical to the sort path's two-key sort, hence bit-identical
    output (the k selected rows are the same set in the same order).
    Requires ``M % seg == 0`` and ``seg >= k`` (defaults to one segment
    per k rows, the natural per-shard granularity).
    """
    b, m_rows = tk_t.shape
    assert tk_idx.shape == (b, m_rows) and tk_rgb.shape == (b, m_rows, 3)
    assert 1 <= k <= m_rows, (k, m_rows)
    if seg <= 0 or m_rows % seg != 0 or seg < k:
        seg = k if m_rows % k == 0 else m_rows
    n_seg = m_rows // seg
    kernel = functools.partial(_merge_topk_kernel, k=k)
    out_f, _ = pl.pallas_call(
        kernel,
        grid=(b, n_seg),
        in_specs=[
            pl.BlockSpec((1, seg), lambda i, j: (i, j)),
            pl.BlockSpec((1, seg), lambda i, j: (i, j)),
            pl.BlockSpec((1, seg, 3), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k, 4), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k, 4), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(tk_t.astype(jnp.float32), tk_idx.astype(jnp.int32),
      tk_rgb.astype(jnp.float32))
    return out_f[:, :, 1:4].mean(axis=1)
