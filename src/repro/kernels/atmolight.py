"""Pallas TPU kernel: atmospheric-light argmin-t reduction (paper Eq. 6).

A = I(x*) where x* = argmin_x t(x). Implemented as a fused single-pass
reduction: each grid step reduces one frame's row-tile in VMEM to a
(min_t, R, G, B) quadruple and folds it into the running output — the
sequential TPU grid makes the cross-tile fold race-free. The robust top-k
variant (k > 1) stays in XLA (``kernels.ref.atmospheric_light``): top-k is
sort-shaped and tiny (three scalars per frame), so a kernel buys nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _atmolight_kernel(img_ref, t_ref, out_ref):
    h_idx = pl.program_id(1)
    img = img_ref[0].astype(jnp.float32)           # (TH, W, 3)
    t = t_ref[0].astype(jnp.float32)               # (TH, W)

    flat_t = t.reshape(-1)
    flat_i = img.reshape(-1, 3)
    j = jnp.argmin(flat_t)
    tile_min = flat_t[j]
    tile_rgb = flat_i[j]

    @pl.when(h_idx == 0)
    def _init():
        out_ref[0, 0] = tile_min
        out_ref[0, 1:4] = tile_rgb

    @pl.when(h_idx != 0)
    def _fold():
        best = out_ref[0, 0]
        take = tile_min < best
        out_ref[0, 0] = jnp.where(take, tile_min, best)
        out_ref[0, 1:4] = jnp.where(take, tile_rgb, out_ref[0, 1:4])


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def atmolight_pallas(img: jnp.ndarray, t_raw: jnp.ndarray,
                     tile_h: int = 0, interpret: bool = False) -> jnp.ndarray:
    """(B,H,W,3), (B,H,W) -> (B,3): I at the per-frame argmin of t_raw."""
    b, h, w, c = img.shape
    assert c == 3 and t_raw.shape == (b, h, w)
    if tile_h <= 0 or h % tile_h != 0:
        tile_h = h
    n_tiles = h // tile_h
    out = pl.pallas_call(
        _atmolight_kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_h, w, 3), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, tile_h, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 4), jnp.float32),
        interpret=interpret,
    )(img, t_raw)
    return out[:, 1:4].astype(img.dtype)
