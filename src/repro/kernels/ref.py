"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground-truth implementations: numerically exact, shape-
polymorphic, differentiable where meaningful. The Pallas kernels in the
sibling modules must ``allclose`` against these across the shape/dtype
sweeps in ``tests/test_kernels.py``.

Conventions: images are ``(..., H, W, C)`` float in [0, 1]; scalar maps are
``(..., H, W)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Frame I/O dtype contract (README §Dtype contract)
# ---------------------------------------------------------------------------

U8_SCALE = 255.0


def upcast_frames(x: jnp.ndarray) -> jnp.ndarray:
    """Wire/ingest dtype -> the f32 compute domain.

    THE canonical ingest upcast: the megakernels run it in-VMEM after the
    HBM copy and the oracles/staged chain run it in XLA, so every path sees
    bit-identical f32 frames and uint8-ingest parity stays exact. uint8
    frames are the wire quantization ``round(v * 255)`` of the [0,1] image
    (so the upcast is ``x / 255``); bf16 -> f32 is an exact widening cast;
    f32 is the identity.
    """
    if x.dtype == jnp.uint8:
        return x.astype(jnp.float32) * jnp.float32(1.0 / U8_SCALE)
    return x.astype(jnp.float32)


def resolve_out_dtype(in_dtype, out_dtype=None) -> jnp.dtype:
    """Resolve the J/t output dtype. ``None``/"auto" follows the ingest
    dtype for float ingest (f32 -> f32, bf16 -> bf16 — the pre-contract
    behavior) and resolves to float32 for uint8 ingest (dehazed frames are
    continuous; re-quantizing is the caller's choice, not the kernel's).
    """
    if out_dtype is not None and out_dtype != "auto":
        return jnp.dtype(out_dtype)
    d = jnp.dtype(in_dtype)
    return d if jnp.issubdtype(d, jnp.floating) else jnp.dtype(jnp.float32)


def quantize_frames(x, io_dtype):
    """Host-side [0,1] float frames -> the wire dtype (numpy in, numpy out).

    The inverse of :func:`upcast_frames` up to quantization: uint8 is
    ``round(clip(v, 0, 1) * 255)``, floats are a plain cast. Used by the
    serve driver and the parity tests to synthesize wire-dtype streams.
    """
    import numpy as np
    dt = jnp.dtype(io_dtype)
    if dt == jnp.uint8:
        arr = np.asarray(x, np.float32)
        return np.clip(np.round(arr * U8_SCALE), 0.0, U8_SCALE).astype(np.uint8)
    return np.asarray(x).astype(dt)


# ---------------------------------------------------------------------------
# Windowed min filter (dark channel prior, paper Eq. 3)
# ---------------------------------------------------------------------------

def min_filter_2d(x: jnp.ndarray, radius: int) -> jnp.ndarray:
    """Windowed minimum over a (2r+1)x(2r+1) box, clipped at borders.

    Border semantics match DCP's patch definition: the window is the
    intersection of the box with the image (equivalent to +inf padding).
    ``x``: (..., H, W).
    """
    if radius == 0:
        return x
    k = 2 * radius + 1
    ndim = x.ndim
    dims = (1,) * (ndim - 2) + (k, 1)
    pads = ((0, 0),) * (ndim - 2) + ((radius, radius), (0, 0))
    # Separable: rows then cols.
    rows = lax.reduce_window(x, jnp.inf, lax.min, dims, (1,) * ndim, pads)
    dims_c = (1,) * (ndim - 2) + (1, k)
    pads_c = ((0, 0),) * (ndim - 2) + ((0, 0), (radius, radius))
    out = lax.reduce_window(rows, jnp.inf, lax.min, dims_c, (1,) * ndim, pads_c)
    return out.astype(x.dtype)


def dark_channel(img: jnp.ndarray, radius: int) -> jnp.ndarray:
    """min over channels then windowed min (He et al. DCP). (...,H,W,3)->(...,H,W)."""
    return min_filter_2d(jnp.min(img, axis=-1), radius)


# ---------------------------------------------------------------------------
# Box filter / guided filter (He et al. [28], transmission refinement)
# ---------------------------------------------------------------------------

def box_filter_2d(x: jnp.ndarray, radius: int) -> jnp.ndarray:
    """Windowed mean over a (2r+1)^2 box normalized by the per-pixel count
    of in-bounds window elements (matches the reference guided-filter code).
    """
    if radius == 0:
        return x
    k = 2 * radius + 1
    ndim = x.ndim
    dims_r = (1,) * (ndim - 2) + (k, 1)
    pads_r = ((0, 0),) * (ndim - 2) + ((radius, radius), (0, 0))
    dims_c = (1,) * (ndim - 2) + (1, k)
    pads_c = ((0, 0),) * (ndim - 2) + ((0, 0), (radius, radius))

    def windowed_sum(v):
        s = lax.reduce_window(v, 0.0, lax.add, dims_r, (1,) * ndim, pads_r)
        return lax.reduce_window(s, 0.0, lax.add, dims_c, (1,) * ndim, pads_c)

    acc = windowed_sum(x.astype(jnp.float32))
    # Closed-form per-pixel in-bounds window counts (avoids a second
    # reduce_window over a constant ones-image, which XLA would try to
    # constant-fold at compile time).
    h, w = x.shape[-2], x.shape[-1]

    def axis_counts(n):
        i = jnp.arange(n, dtype=jnp.float32)
        return (jnp.minimum(i + radius, n - 1.0)
                - jnp.maximum(i - radius, 0.0) + 1.0)

    cnt = axis_counts(h)[:, None] * axis_counts(w)[None, :]
    return (acc / cnt).astype(x.dtype)


def guided_filter(guide: jnp.ndarray, src: jnp.ndarray, radius: int,
                  eps: float) -> jnp.ndarray:
    """Gray-guide guided filter. guide/src: (..., H, W)."""
    g = guide.astype(jnp.float32)
    p = src.astype(jnp.float32)
    mean_g = box_filter_2d(g, radius)
    mean_p = box_filter_2d(p, radius)
    corr_gp = box_filter_2d(g * p, radius)
    corr_gg = box_filter_2d(g * g, radius)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    mean_a = box_filter_2d(a, radius)
    mean_b = box_filter_2d(b, radius)
    return (mean_a * g + mean_b).astype(src.dtype)


# ---------------------------------------------------------------------------
# Atmospheric light estimation (paper Eq. 5/6, robust top-k form)
# ---------------------------------------------------------------------------

def atmospheric_light(img: jnp.ndarray, t_raw: jnp.ndarray, k: int = 1) -> jnp.ndarray:
    """A = mean of I over the k pixels with smallest raw transmission.

    k=1 reproduces paper Eq. 6 exactly (the argmin-t pixel). Larger k is
    the standard robustification (top 0.1 %). ``img``: (..., H, W, 3),
    ``t_raw``: (..., H, W) -> (..., 3).
    """
    flat_t = t_raw.reshape(*t_raw.shape[:-2], -1)
    flat_i = img.reshape(*img.shape[:-3], -1, 3)
    _, idx = lax.top_k(-flat_t, k)                      # smallest t
    picked = jnp.take_along_axis(flat_i, idx[..., None], axis=-2)
    return picked.mean(axis=-2)


# ---------------------------------------------------------------------------
# Fused haze-free recovery (paper Eq. 8)
# ---------------------------------------------------------------------------

def recover(hazy: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray,
            t0: float = 0.1) -> jnp.ndarray:
    """J = clip((I - A)/max(t, t0) + A, 0, 1). A: (..., 3)."""
    tt = jnp.maximum(t, t0)[..., None]
    A = jnp.broadcast_to(A[..., None, None, :], hazy.shape)
    return jnp.clip((hazy - A) / tt + A, 0.0, 1.0).astype(hazy.dtype)


# ---------------------------------------------------------------------------
# CAP depth map (Zhu et al. [23], paper Eq. 4)
# ---------------------------------------------------------------------------

# Published CAP linear-model coefficients (w0, w1, w2) — the single source
# shared by the fused kernels; ``DehazeConfig`` defaults mirror these.
CAP_COEFFS = (0.121779, 0.959710, -0.780245)


def cap_depth(img: jnp.ndarray, w0: float, w1: float, w2: float) -> jnp.ndarray:
    """d(x) = w0 + w1 * value(x) + w2 * saturation(x) from RGB in [0,1]."""
    v = jnp.max(img, axis=-1)
    mn = jnp.min(img, axis=-1)
    s = jnp.where(v > 0, (v - mn) / jnp.maximum(v, 1e-12), 0.0)
    return (w0 + w1 * v + w2 * s).astype(img.dtype)


# ---------------------------------------------------------------------------
# Fused megakernel oracles (paper Eq. 3/4 + 6 + 9 + 8 in one logical op)
# ---------------------------------------------------------------------------

# Rec.601 luma — THE guided-filter guide definition. The fused kernel, the
# per-stage chain (core.algorithms.luminance) and the benchmarks all share
# these weights; parity between them is asserted to 1e-5 in CI.
LUMA_WEIGHTS = (0.299, 0.587, 0.114)


def luminance(img: jnp.ndarray) -> jnp.ndarray:
    """Rec.601 luma in float32 — the guided-filter guide of the fused op."""
    w = jnp.asarray(LUMA_WEIGHTS, jnp.float32)
    return img.astype(jnp.float32) @ w


def premap(x: jnp.ndarray, a0: jnp.ndarray, algorithm: str,
           cap_w=CAP_COEFFS) -> jnp.ndarray:
    """Per-pixel stage-1 map: DCP min_c I/A (Eq. 3) or CAP depth (Eq. 4).

    THE canonical pre-map: the fused kernels, the oracles, and the sharded
    pipeline (which computes it before the halo exchange) all route here,
    so the in-kernel and out-of-kernel forms stay bit-identical.
    """
    if algorithm == "dcp":
        return jnp.min(x / a0, axis=-1)
    return cap_depth(x, *cap_w)


def tmap_from_dark(dark: jnp.ndarray, algorithm: str, omega: float,
                   beta: float) -> jnp.ndarray:
    """Min-filtered pre-map -> raw transmission: DCP ``1 - omega*dark``
    (Eq. 3 outer map) or CAP ``exp(-beta*dark)`` (Eq. 4).

    Like ``premap``, this is THE canonical form — the fused kernels, the
    oracles, and the sharded staged chain all route here.
    """
    if algorithm == "dcp":
        return 1.0 - omega * dark
    return jnp.exp(-beta * dark)


def fused_transmission(img: jnp.ndarray, A_saved: jnp.ndarray, *,
                       algorithm: str = "dcp", radius: int,
                       omega: float = 0.95, beta: float = 1.0,
                       cap_w=CAP_COEFFS, refine: bool, gf_radius: int,
                       gf_eps: float, topk: int = 1, out_dtype=None):
    """Oracle for ``fused.fused_transmission_pallas``.

    (B,H,W,3) -> (t, t_min (B,), cand_rgb (B,3)): Eq. 3 (DCP) / Eq. 4 (CAP)
    transmission, guided-filter refinement, per-frame atmospheric-light
    candidate — the argmin-t pixel (Eq. 6) for ``topk == 1``, the mean of
    the ``topk`` smallest-t pixels (the robust Eq. 5/6 generalization,
    identical to :func:`atmospheric_light`) otherwise. ``img`` may be any
    wire dtype (f32/bf16/uint8 — see :func:`upcast_frames`); outputs are
    cast to :func:`resolve_out_dtype`.
    """
    odt = resolve_out_dtype(img.dtype, out_dtype)
    b = img.shape[0]
    x = upcast_frames(img)
    a0 = jnp.maximum(A_saved.astype(jnp.float32), 1e-3)
    pre = premap(x, a0, algorithm, cap_w)
    dark = min_filter_2d(pre, radius)
    t_raw = tmap_from_dark(dark, algorithm, omega, beta)
    flat_t = t_raw.reshape(b, -1)
    j = jnp.argmin(flat_t, axis=-1)
    t_min = jnp.take_along_axis(flat_t, j[:, None], axis=-1)[:, 0]
    if topk == 1:
        cand = jnp.take_along_axis(x.reshape(b, -1, 3), j[:, None, None],
                                   axis=1)[:, 0]
    else:
        cand = atmospheric_light(x, t_raw, topk)
    if refine:
        t = jnp.clip(guided_filter(luminance(x), t_raw, gf_radius, gf_eps),
                     0.0, 1.0)
    else:
        t = t_raw
    return t.astype(odt), t_min, cand.astype(odt)


def fused_transmission_dcp(img: jnp.ndarray, A_saved: jnp.ndarray, *,
                           radius: int, omega: float, refine: bool,
                           gf_radius: int, gf_eps: float):
    """Back-compat DCP-only entry point (PR 1 name)."""
    return fused_transmission(img, A_saved, algorithm="dcp", radius=radius,
                              omega=omega, refine=refine, gf_radius=gf_radius,
                              gf_eps=gf_eps)


def fused_transmission_halo(img: jnp.ndarray, pre_ext: jnp.ndarray,
                            guide_ext: jnp.ndarray, valid: jnp.ndarray,
                            valid_w: jnp.ndarray = None, *,
                            algorithm: str = "dcp", radius: int,
                            omega: float = 0.95, beta: float = 1.0,
                            refine: bool, gf_radius: int, gf_eps: float,
                            topk: int = 1, out_dtype=None):
    """Oracle for ``fused.fused_transmission_halo_pallas``.

    Composes the masked XLA filters from ``core.spatial`` on the
    halo-extended (pre-map, guide) planes — exactly the per-stage chain the
    spatially-sharded pipeline ran before the fused halo kernel existed.
    ``valid``/``valid_w`` are the row/column validity vectors from the halo
    exchange (``valid_w=None`` means all columns valid, i.e. no W
    sharding).

    Returns ``(t (B, H_loc, W_loc), tk_t (B, k), tk_rgb (B, k, 3),
    tk_idx (B, k) int32)``: the refined transmission plus the shard-local
    top-k smallest-t candidates over the core block, ascending in
    (t, local flat index) — ready for the cross-shard lexicographic merge
    in ``core.pipeline``. ``topk == 1`` is the Eq. 6 argmin candidate.
    ``img`` may be any wire dtype; ``pre_ext``/``guide_ext`` are the
    already-upcast halo planes (f32 or bf16 per ``halo_dtype``).
    """
    from repro.core import spatial                 # lazy: spatial imports ref
    odt = resolve_out_dtype(img.dtype, out_dtype)
    b, h_loc, w_loc = img.shape[0], img.shape[1], img.shape[2]
    halo_h = (pre_ext.shape[1] - h_loc) // 2
    halo_w = (pre_ext.shape[2] - w_loc) // 2
    dark = spatial.masked_min_filter_2d(pre_ext.astype(jnp.float32), valid,
                                        radius, valid_w)
    t_raw_ext = tmap_from_dark(dark, algorithm, omega, beta)
    core_h = slice(halo_h, halo_h + h_loc)
    core_w = slice(halo_w, halo_w + w_loc)
    t_raw = t_raw_ext[:, core_h, core_w]
    if refine:
        t_ext = spatial.masked_guided_filter(guide_ext.astype(jnp.float32),
                                             t_raw_ext, valid, gf_radius,
                                             gf_eps, valid_w)
        t = jnp.clip(t_ext[:, core_h, core_w], 0.0, 1.0)
    else:
        t = t_raw
    flat_t = t_raw.reshape(b, -1)
    _, idx = lax.top_k(-flat_t, topk)              # k smallest, ties by idx
    tk_t = jnp.take_along_axis(flat_t, idx, axis=-1)
    tk_rgb = jnp.take_along_axis(upcast_frames(img).reshape(b, -1, 3),
                                 idx[..., None], axis=1)
    return (t.astype(odt), tk_t, tk_rgb.astype(odt),
            idx.astype(jnp.int32))


def fused_dehaze(img: jnp.ndarray, frame_ids: jnp.ndarray,
                 A_saved: jnp.ndarray, last_update: jnp.ndarray,
                 initialized: jnp.ndarray, *, algorithm: str = "dcp",
                 radius: int, omega: float = 0.95, beta: float = 1.0,
                 cap_w=CAP_COEFFS, refine: bool, gf_radius: int,
                 gf_eps: float, t0: float, gamma: float, period: int,
                 lam: float, topk: int = 1, out_dtype=None):
    """Oracle for ``fused.fused_dehaze_pallas``: (J, t, a_seq, A_fin, k_fin).

    Composes the per-stage oracles plus the Eq. 9 EMA recurrence (lax.scan)
    — the sequential scan the megakernel realizes via its grid carry.
    ``topk > 1`` feeds the EMA the robust mean-of-top-k candidate. ``img``
    may be any wire dtype (the canonical :func:`upcast_frames` ingest);
    J/t are cast to :func:`resolve_out_dtype`, a_seq stays f32.
    """
    odt = resolve_out_dtype(img.dtype, out_dtype)
    x = upcast_frames(img)
    t, _, cand = fused_transmission(
        x, A_saved, algorithm=algorithm, radius=radius, omega=omega,
        beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
        gf_eps=gf_eps, topk=topk)

    def step(carry, inp):
        A_prev, k, inited = carry
        c, fid = inp
        valid = fid >= 0                  # ids < 0 are padding: no update
        bootstrap = jnp.logical_and(valid, jnp.logical_not(inited))
        do = jnp.logical_and(valid, jnp.logical_or(
            bootstrap, (fid - k) >= period))
        target = jnp.where(bootstrap, c, lam * c + (1.0 - lam) * A_prev)
        A = jnp.where(do, target, A_prev)
        k_next = jnp.where(do, fid, k)
        return (A, k_next, jnp.logical_or(inited, valid)), A

    (A_fin, k_fin, _), a_seq = lax.scan(
        step,
        (A_saved.astype(jnp.float32), last_update.astype(jnp.int32),
         initialized.astype(bool)),
        (cand.astype(jnp.float32), frame_ids.astype(jnp.int32)))
    tt = jnp.maximum(t.astype(jnp.float32), t0)[..., None]
    A_b = a_seq[:, None, None, :]
    J = jnp.clip((x - A_b) / tt + A_b, 0.0, 1.0)
    if gamma != 1.0:
        J = J ** gamma
    return (J.astype(odt), t.astype(odt), a_seq,
            A_fin, k_fin.astype(jnp.int32))


def fused_dehaze_dcp(img: jnp.ndarray, frame_ids: jnp.ndarray,
                     A_saved: jnp.ndarray, last_update: jnp.ndarray,
                     initialized: jnp.ndarray, *, radius: int, omega: float,
                     refine: bool, gf_radius: int, gf_eps: float, t0: float,
                     gamma: float, period: int, lam: float):
    """Back-compat DCP-only entry point (PR 1 name)."""
    return fused_dehaze(img, frame_ids, A_saved, last_update, initialized,
                        algorithm="dcp", radius=radius, omega=omega,
                        refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
                        t0=t0, gamma=gamma, period=period, lam=lam)
