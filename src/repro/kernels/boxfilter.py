"""Pallas TPU kernel: separable box filter via in-VMEM running sums.

The guided filter (transmission refinement; He et al. [28]) is five box
filters plus elementwise math — on both CPU and GPU the naive window-sum
dominates DCP/CAP end-to-end cost. TPU rethink: hold the frame tile in
VMEM and compute each 1-D windowed sum from a cumulative sum (two
vector-adds + one subtraction per axis, O(H) instead of O(H*k)), then
normalize by the per-pixel in-bounds window count (computed closed-form
from iota, so no ones-image second pass is needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _counts_2d(h: int, w: int, radius: int) -> jnp.ndarray:
    """Closed-form per-pixel count of in-bounds window elements.

    Uses 2-D broadcasted iota (TPU requires >= 2-D iota)."""
    def axis_counts(axis, n):
        i = jax.lax.broadcasted_iota(jnp.float32, (h, w), axis)
        lo = jnp.maximum(i - radius, 0.0)
        hi = jnp.minimum(i + radius, float(n - 1))
        return hi - lo + 1.0
    return axis_counts(0, h) * axis_counts(1, w)


def _box_pass(x: jnp.ndarray, radius: int, axis: int) -> jnp.ndarray:
    """1-D windowed *sum* along axis using cumsum differences (zero pad)."""
    n = x.shape[axis]
    cs = jnp.cumsum(x, axis=axis)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (radius + 1, radius)
    csp = jnp.pad(cs, pad)                                   # zero padded
    hi = jax.lax.slice_in_dim(csp, 2 * radius + 1, 2 * radius + 1 + n, axis=axis)
    lo = jax.lax.slice_in_dim(csp, 0, n, axis=axis)
    # Right border: zero padding of the *cumsum* makes hi read 0 past the end
    # where it should read cs[n-1]; clamp those positions.
    last = jax.lax.slice_in_dim(cs, n - 1, n, axis=axis)
    i = jax.lax.broadcasted_iota(jnp.float32, x.shape, axis)
    over_end = i + radius > (n - 1)
    hi = jnp.where(over_end, last, hi)
    return hi - lo


def _boxfilter_kernel(x_ref, out_ref, *, radius: int):
    x = x_ref[0].astype(jnp.float32)              # (H, W)
    s = _box_pass(x, radius, axis=0)
    s = _box_pass(s, radius, axis=1)
    h, w = x.shape
    out_ref[0] = (s / _counts_2d(h, w, radius)).astype(out_ref.dtype)


def _masked_box_mean(v: jnp.ndarray, valid_f: jnp.ndarray, radius: int,
                     valid_w_f: jnp.ndarray = None) -> jnp.ndarray:
    """(H, W) windowed mean over valid rows (and columns), all in VMEM.

    The per-pixel divisor decomposes as (windowed sum of the row mask along
    H) x (windowed count along W) — one extra 1-D cumsum pass per axis
    instead of a full ones-image sweep. With no column mask the W count is
    the closed-form in-bounds count; with ``valid_w_f`` (the W-sharded halo
    path) it is the windowed sum of the column mask, so windows that
    straddle a *vertical* mesh edge renormalize exactly like a clipped
    image-border window too. Semantics match
    ``core.spatial.masked_box_filter_2d`` (whose divisor is the windowed
    sum of the full 2-D mask — equal to this separable product because the
    halo masks are outer products of per-axis validity). This is THE
    array-level masked box mean — the standalone kernel below and the fused
    halo megakernel (``kernels.fused``) both call it; change masking
    semantics here and in ``core.spatial`` together.
    """
    h, w = v.shape
    mask = valid_f[:, None] > 0.5
    if valid_w_f is not None:
        mask = jnp.logical_and(mask, valid_w_f[None, :] > 0.5)
    # `where`, not multiply: invalid rows/cols may hold +/-inf from an
    # upstream masked min filter and inf * 0 would poison the sums with NaN.
    vm = jnp.where(mask, v, 0.0)
    s = _box_pass(_box_pass(vm, radius, axis=0), radius, axis=1)
    rowcnt = _box_pass(jnp.broadcast_to(valid_f[:, None], (h, 1)),
                       radius, axis=0)                  # (H, 1)
    if valid_w_f is None:
        i = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1)
        wcnt = (jnp.minimum(i + radius, float(w - 1))
                - jnp.maximum(i - radius, 0.0) + 1.0)
    else:
        wcnt = _box_pass(jnp.broadcast_to(valid_w_f[None, :], (1, w)),
                         radius, axis=1)                # (1, W)
    return s / jnp.maximum(rowcnt * wcnt, 1.0)


def _masked_boxfilter_kernel(x_ref, valid_ref, valid_w_ref, out_ref, *,
                             radius: int):
    x = x_ref[0].astype(jnp.float32)
    valid = valid_ref[0]                               # (H,) float
    valid_w = valid_w_ref[0]                           # (W,) float
    out_ref[0] = _masked_box_mean(x, valid, radius,
                                  valid_w_f=valid_w).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def masked_box_filter_2d_pallas(x: jnp.ndarray, valid: jnp.ndarray,
                                radius: int, valid_w: jnp.ndarray = None,
                                interpret: bool = False) -> jnp.ndarray:
    """(B, H, W), (H,) [, (W,)] bool -> (B, H, W) masked windowed mean.

    ``valid_w`` (column validity, the W-sharded halo path) defaults to
    all-valid, reproducing the row-masked behavior exactly.
    """
    b, h, w = x.shape
    vmask = valid.astype(jnp.float32).reshape(1, h)
    if valid_w is None:
        valid_w = jnp.ones((w,), jnp.float32)
    wmask = valid_w.astype(jnp.float32).reshape(1, w)
    kernel = functools.partial(_masked_boxfilter_kernel, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, h), lambda i: (0, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), x.dtype),
        interpret=interpret,
    )(x, vmask, wmask)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def box_filter_2d_pallas(x: jnp.ndarray, radius: int,
                         interpret: bool = False) -> jnp.ndarray:
    """(B, H, W) -> (B, H, W) windowed mean over clipped (2r+1)^2 boxes."""
    b, h, w = x.shape
    kernel = functools.partial(_boxfilter_kernel, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), x.dtype),
        interpret=interpret,
    )(x)
