"""Pallas TPU kernel: fused channel-min + separable windowed min filter.

This is the compute hot spot of DCP (paper Eq. 3). The GPU formulation
gathers a (2r+1)^2 window per pixel; on TPU we instead keep the whole frame
tile resident in VMEM and perform two separable 1-D min passes, each as
2r+1 statically-shifted ``jnp.minimum`` vector ops — no gathers, fully
vectorized on the VPU, one HBM read + one HBM write per frame.

Grid: one step per frame (batch element). BlockSpec keeps the full
(H, W, 3) frame in VMEM: for the paper's resolutions (<= 1024x576 fp32
~= 7 MB) this fits comfortably; larger frames use the spatial-parallel
path in ``repro.core.pipeline`` which shards H across the mesh *before*
the kernel, so each shard's tile still fits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _min_pass(x: jnp.ndarray, radius: int, axis: int) -> jnp.ndarray:
    """1-D min filter along ``axis`` via 2r+1 shifted minima (+inf border)."""
    n = x.shape[axis]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (radius, radius)
    xp = jnp.pad(x, pad, constant_values=jnp.inf)
    out = jax.lax.slice_in_dim(xp, 0, n, axis=axis)
    for i in range(1, 2 * radius + 1):
        out = jnp.minimum(out, jax.lax.slice_in_dim(xp, i, i + n, axis=axis))
    return out


def _dark_channel_kernel(img_ref, out_ref, *, radius: int):
    img = img_ref[0].astype(jnp.float32)          # (H, W, 3)
    cmin = jnp.min(img, axis=-1)                  # channel min, (H, W)
    m = _min_pass(cmin, radius, axis=0)           # vertical pass
    m = _min_pass(m, radius, axis=1)              # horizontal pass
    out_ref[0] = m.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def dark_channel_pallas(img: jnp.ndarray, radius: int,
                        interpret: bool = False) -> jnp.ndarray:
    """(B, H, W, 3) -> (B, H, W) dark channel with window radius ``radius``."""
    b, h, w, c = img.shape
    assert c == 3, "dark_channel expects RGB"
    kernel = functools.partial(_dark_channel_kernel, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), img.dtype),
        interpret=interpret,
    )(img)


def _min_filter_kernel(x_ref, out_ref, *, radius: int):
    x = x_ref[0].astype(jnp.float32)
    m = _min_pass(x, radius, axis=0)
    m = _min_pass(m, radius, axis=1)
    out_ref[0] = m.astype(out_ref.dtype)


def _masked_min_filter_kernel(x_ref, valid_ref, valid_w_ref, out_ref, *,
                              radius: int):
    """Min filter ignoring invalid rows/columns (halo border semantics).

    valid: (1, H) / valid_w: (1, W) float validity masks held in VMEM
    alongside the tile; invalid rows and columns become +inf before the
    separable passes, exactly matching ``core.spatial.masked_min_filter_2d``
    with a 2-D (H x W) shard mask."""
    x = x_ref[0].astype(jnp.float32)
    valid = valid_ref[0] > 0.5                   # (H,)
    valid_w = valid_w_ref[0] > 0.5               # (W,)
    x = jnp.where(jnp.logical_and(valid[:, None], valid_w[None, :]),
                  x, jnp.inf)
    m = _min_pass(x, radius, axis=0)
    m = _min_pass(m, radius, axis=1)
    out_ref[0] = m.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def masked_min_filter_2d_pallas(x: jnp.ndarray, valid: jnp.ndarray,
                                radius: int, valid_w: jnp.ndarray = None,
                                interpret: bool = False) -> jnp.ndarray:
    """(B, H, W), (H,) [, (W,)] bool -> (B, H, W) masked windowed min."""
    b, h, w = x.shape
    vmask = valid.astype(jnp.float32).reshape(1, h)
    if valid_w is None:
        valid_w = jnp.ones((w,), jnp.float32)
    wmask = valid_w.astype(jnp.float32).reshape(1, w)
    kernel = functools.partial(_masked_min_filter_kernel, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, h), lambda i: (0, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), x.dtype),
        interpret=interpret,
    )(x, vmask, wmask)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def min_filter_2d_pallas(x: jnp.ndarray, radius: int,
                         interpret: bool = False) -> jnp.ndarray:
    """(B, H, W) -> (B, H, W) windowed min (border = clipped window)."""
    b, h, w = x.shape
    kernel = functools.partial(_min_filter_kernel, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), x.dtype),
        interpret=interpret,
    )(x)
