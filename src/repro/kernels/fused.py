"""Pallas TPU megakernel: the whole DCP dehaze chain in one pass over VMEM.

The paper pipelines its three components (transmission estimator,
atmospheric-light estimator, haze-free generator) across machines; on TPU
the equivalent win is *fusing* them so a frame never leaves VMEM between
stages. This module collapses the four per-frame kernel launches
(``dark_channel`` -> ``atmolight`` -> ``boxfilter``x5 -> ``recover``) into a
single ``pallas_call``:

  per grid step (one or more frames, ``frames_per_block``):
    1. pre-map        cmin = min_c I^c / A_saved^c            (Eq. 3 inner min)
    2. transmission   t_raw = 1 - omega * minfilt(cmin)       (Eq. 3)
    3. A candidate    (t*, I(x*)) at x* = argmin t_raw        (Eq. 6)
    4. EMA update     A_m = lam*A_new + (1-lam)*A_k           (Eq. 9, §3.3)
    5. refine         guided filter on the luma guide          (He et al. [28])
    6. recovery       J = clip((I - A)/max(t, t0) + A, 0, 1)  (Eq. 8) + gamma

The cross-frame EMA recurrence (step 4) is sequential, which would normally
force the scan *between* kernels — but the TPU grid executes sequentially,
so the running (A, last_update, initialized) state is carried across grid
steps in a small output ref, the same race-free fold trick as
``atmolight.py``. One HBM read of I, one write of (J, t) per frame; every
intermediate (pre-map, dark channel, box-filter moments) lives and dies in
VMEM.

``fused_transmission_pallas`` is the sharded-pipeline variant: it stops
after step 5 and returns per-frame candidates instead of recovering,
because under batch sharding the EMA must see all shards' candidates
(an all-gather) before recovery. Still one launch instead of seven.

Semantics match ``make_dehaze_step``: the pre-map for *every* frame in the
batch uses the batch-entry saved A (paper §3.3 — the T-estimator runs
before the A refresh), while recovery uses the per-frame EMA output.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.boxfilter import _box_pass, _counts_2d
from repro.kernels.dark_channel import _min_pass
from repro.kernels.ref import LUMA_WEIGHTS as _LUMA


def _guided_refine(img: jnp.ndarray, t_raw: jnp.ndarray, radius: int,
                   eps: float) -> jnp.ndarray:
    """In-VMEM guided filter (luma guide) + [0,1] clip. img: (H, W, 3) f32."""
    h, w = t_raw.shape
    g = _LUMA[0] * img[..., 0] + _LUMA[1] * img[..., 1] + _LUMA[2] * img[..., 2]
    cnt = _counts_2d(h, w, radius)

    def bf(v):
        return _box_pass(_box_pass(v, radius, axis=0), radius, axis=1) / cnt

    mean_g = bf(g)
    mean_p = bf(t_raw)
    corr_gp = bf(g * t_raw)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return jnp.clip(bf(a) * g + bf(b), 0.0, 1.0)


def _frame_tmap(img: jnp.ndarray, a0: jnp.ndarray, *, radius: int,
                omega: float, refine: bool, gf_radius: int, gf_eps: float):
    """Steps 1-3 (+5) for one (H, W, 3) f32 frame: t_raw, refined t, candidate."""
    pre = jnp.min(img / a0, axis=-1)                    # (H, W) pre-map
    dark = _min_pass(_min_pass(pre, radius, axis=0), radius, axis=1)
    t_raw = 1.0 - omega * dark
    flat_t = t_raw.reshape(-1)
    j = jnp.argmin(flat_t)
    cand_min = flat_t[j]
    cand_rgb = img.reshape(-1, 3)[j]
    t = _guided_refine(img, t_raw, gf_radius, gf_eps) if refine else t_raw
    return t, cand_min, cand_rgb


def _ema_step(cand: jnp.ndarray, fid: jnp.ndarray, A_prev: jnp.ndarray,
              k_prev: jnp.ndarray, inited: jnp.ndarray, *, period: int,
              lam: float):
    """One step of the paper's Eq. 9 update strategy.

    ``fid``/``k_prev`` stay int32 end-to-end — frame ids exceed f32's 2^24
    integer range within days of continuous streaming."""
    bootstrap = inited == 0
    do = jnp.logical_or(bootstrap, (fid - k_prev) >= period)
    target = jnp.where(bootstrap, cand, lam * cand + (1.0 - lam) * A_prev)
    A = jnp.where(do, target, A_prev)
    k = jnp.where(do, fid, k_prev)
    return A, k


def _fused_dcp_kernel(img_ref, ids_ref, state_f_ref, state_i_ref,
                      out_ref, t_ref, aseq_ref, carry_f_ref, carry_i_ref, *,
                      radius: int, omega: float, refine: bool, gf_radius: int,
                      gf_eps: float, t0: float, gamma: float, period: int,
                      lam: float, frames_per_block: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init_carry():
        carry_f_ref[0] = state_f_ref[0]
        carry_i_ref[0] = state_i_ref[0]

    A = carry_f_ref[0, 0:3]
    k = carry_i_ref[0, 0]
    inited = carry_i_ref[0, 1]
    # Pre-map divisor: the batch-entry *saved* A for every frame (§3.3);
    # state_f_ref is an input block, so it stays constant while the carry
    # refs advance.
    a0 = jnp.maximum(state_f_ref[0].astype(jnp.float32), 1e-3)

    for f in range(frames_per_block):
        img = img_ref[f].astype(jnp.float32)            # (H, W, 3)
        t, cand_min, cand_rgb = _frame_tmap(
            img, a0, radius=radius, omega=omega, refine=refine,
            gf_radius=gf_radius, gf_eps=gf_eps)
        A, k = _ema_step(cand_rgb, ids_ref[f, 0], A, k, inited,
                         period=period, lam=lam)
        inited = jnp.int32(1)
        aseq_ref[f] = A
        tt = jnp.maximum(t, t0)[..., None]
        J = jnp.clip((img - A) / tt + A, 0.0, 1.0)
        if gamma != 1.0:
            J = J ** gamma
        out_ref[f] = J.astype(out_ref.dtype)
        t_ref[f] = t.astype(t_ref.dtype)

    carry_f_ref[0, 0:3] = A
    carry_i_ref[0, 0] = k
    carry_i_ref[0, 1] = inited


@functools.partial(jax.jit, static_argnames=(
    "radius", "omega", "refine", "gf_radius", "gf_eps", "t0", "gamma",
    "period", "lam", "frames_per_block", "interpret"))
def fused_dehaze_dcp_pallas(
        img: jnp.ndarray, frame_ids: jnp.ndarray, A_saved: jnp.ndarray,
        last_update: jnp.ndarray, initialized: jnp.ndarray, *,
        radius: int, omega: float, refine: bool, gf_radius: int,
        gf_eps: float, t0: float, gamma: float, period: int, lam: float,
        frames_per_block: int = 1, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-launch DCP dehaze: (B,H,W,3) -> (J, t, a_seq, A_fin, k_fin).

    ``A_saved``/``last_update``/``initialized`` are the ``AtmoState`` fields;
    the EMA state is carried across the sequential grid, so ``a_seq[b]`` is
    bit-equal to running the Eq. 9 scan outside the kernel.
    """
    b, h, w, c = img.shape
    assert c == 3 and frame_ids.shape == (b,)
    fpb = frames_per_block if frames_per_block > 0 and b % frames_per_block == 0 \
        else 1
    ids = frame_ids.astype(jnp.int32).reshape(b, 1)
    state_f = A_saved.astype(jnp.float32).reshape(1, 3)
    state_i = jnp.stack([last_update.astype(jnp.int32),
                         initialized.astype(jnp.int32)]).reshape(1, 2)

    kernel = functools.partial(
        _fused_dcp_kernel, radius=radius, omega=omega, refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps, t0=t0, gamma=gamma,
        period=period, lam=lam, frames_per_block=fpb)
    out, t, a_seq, carry_f, carry_i = pl.pallas_call(
        kernel,
        grid=(b // fpb,),
        in_specs=[
            pl.BlockSpec((fpb, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fpb, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((fpb, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fpb, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((fpb, 3), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, w, 3), img.dtype),
            jax.ShapeDtypeStruct((b, h, w), img.dtype),
            jax.ShapeDtypeStruct((b, 3), jnp.float32),
            jax.ShapeDtypeStruct((1, 3), jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.int32),
        ],
        interpret=interpret,
    )(img, ids, state_f, state_i)
    return out, t, a_seq, carry_f[0], carry_i[0, 0]


def _fused_tmap_kernel(img_ref, a0_ref, t_ref, cand_ref, *, radius: int,
                       omega: float, refine: bool, gf_radius: int,
                       gf_eps: float):
    img = img_ref[0].astype(jnp.float32)
    a0 = jnp.maximum(a0_ref[0].astype(jnp.float32), 1e-3)
    t, cand_min, cand_rgb = _frame_tmap(
        img, a0, radius=radius, omega=omega, refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps)
    t_ref[0] = t.astype(t_ref.dtype)
    cand_ref[0, 0] = cand_min
    cand_ref[0, 1:4] = cand_rgb


@functools.partial(jax.jit, static_argnames=(
    "radius", "omega", "refine", "gf_radius", "gf_eps", "interpret"))
def fused_transmission_pallas(
        img: jnp.ndarray, A_saved: jnp.ndarray, *, radius: int, omega: float,
        refine: bool, gf_radius: int, gf_eps: float, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sharded-step variant: (B,H,W,3) -> (t, t_min (B,), cand_rgb (B,3)).

    Fuses pre-map + min filter + guided refine + per-frame argmin candidate
    in one launch; the EMA and the recovery stay outside because the
    candidates must cross shards (all-gather) first.
    """
    b, h, w, c = img.shape
    assert c == 3
    a0 = A_saved.astype(jnp.float32).reshape(1, 3)
    kernel = functools.partial(
        _fused_tmap_kernel, radius=radius, omega=omega, refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps)
    t, cand = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, w), img.dtype),
            jax.ShapeDtypeStruct((b, 4), jnp.float32),
        ],
        interpret=interpret,
    )(img, a0)
    return t, cand[:, 0], cand[:, 1:4].astype(img.dtype)
