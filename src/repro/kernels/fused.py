"""Pallas TPU megakernels: the whole dehaze chain in one pass over VMEM.

The paper pipelines its three components (transmission estimator,
atmospheric-light estimator, haze-free generator) across machines; on TPU
the equivalent win is *fusing* them so a frame never leaves VMEM between
stages. This module collapses the per-frame kernel launches
(``dark_channel``/``min_filter`` -> ``atmolight`` -> ``boxfilter``x5 ->
``recover``) into a single ``pallas_call``, parametric in the transmission
algorithm (paper §3.1: the estimator is a black box — DCP Eq. 3 and CAP
Eq. 4 are the two shipped instantiations):

  per grid step (one or more frames, ``frames_per_block``):
    1. pre-map        DCP: cmin = min_c I^c / A_saved^c       (Eq. 3 inner min)
                      CAP: d = w0 + w1*v + w2*s               (Eq. 4 depth)
    2. transmission   DCP: t_raw = 1 - omega * minfilt(cmin)  (Eq. 3)
                      CAP: t_raw = exp(-beta * minfilt(d))    (Eq. 4)
    3. A candidate    (t*, I(x*)) at x* = argmin t_raw        (Eq. 6), or the
                      mean of I over the ``topk`` smallest-t pixels (the
                      robust Eq. 5/6 generalization) via an in-VMEM k-step
                      running selection (``atmolight.topk_select``)
    4. EMA update     A_m = lam*A_new + (1-lam)*A_k           (Eq. 9, §3.3)
    5. refine         guided filter on the luma guide          (He et al. [28])
    6. recovery       J = clip((I - A)/max(t, t0) + A, 0, 1)  (Eq. 8) + gamma

The cross-frame EMA recurrence (step 4) is sequential, which would normally
force the scan *between* kernels — but the TPU grid executes sequentially,
so the running (A, last_update, initialized) state is carried across grid
steps in a small VMEM scratch, the same race-free fold trick as
``atmolight.py``. One HBM read of I, one write of (J, t) per frame; every
intermediate (pre-map, dark channel, box-filter moments) lives and dies in
VMEM.

**Lane axis.** The kernel family is *lane-native*: the multi-tenant
serving runtime batches L independent video streams on a leading lane
axis, and ``fused_dehaze_lanes_pallas`` folds that axis straight into the
pallas grid — a 2-D ``(L, B // frames_per_block)`` grid (or the
transposed frame-major order, a tuning choice) where each lane owns its
own row of the ``(L, 3)``/``(L, 2)`` EMA carry scratch. The per-lane EMA
stays causal *within* a lane (the batch-block dimension of the grid runs
in ascending order for every lane under both grid orders) and fully
independent *across* lanes (carry rows never alias), and padding lanes
(``frame_id == -1`` everywhere) ride through with their state untouched —
exactly the masked-EMA contract of the vmapped path. Serving L streams is
ONE ``pallas_call`` launch and one compiled program instead of L.
``fused_dehaze_pallas`` is the single-stream entry point, a lane-count-1
view of the same kernel; ``fused_transmission_lanes_pallas`` is the
lane-batched form of the (stateless) sharded-step stage, with a per-lane
saved-A input.

``fused_transmission_pallas`` is the sharded-pipeline variant: it stops
after step 5 and returns per-frame candidates instead of recovering,
because under batch sharding the EMA must see all shards' candidates
(an all-gather) before recovery. Still one launch instead of seven.

``fused_transmission_halo_pallas`` is the spatially-sharded variant: it
takes the halo-*extended* (pre-map, guide) planes produced by the
``core.spatial`` halo exchanges (height, and width when ``n_w > 1``) plus
the row- and column-validity vectors, and runs the min/box filters masked
in-VMEM (invalid rows/columns are +inf for the min filter, excluded from
both sum and count for the box filters), so mesh-edge shards — including
corner shards of a 2-D (H x W) mesh — keep the exact clipped-window border
semantics of the single-device chain. The halo exchange feeds the kernel
directly — no masked XLA chain. Its candidates are the shard-local top-k
(t, rgb, flat-index) lists, ascending in (t, index), which the pipeline
merges across shards with a lexicographic sort so tie-breaking matches the
unsharded ``lax.top_k`` bit-for-bit.

Semantics match ``make_dehaze_step``: the pre-map for *every* frame in the
batch uses the batch-entry saved A (paper §3.3 — the T-estimator runs
before the A refresh), while recovery uses the per-frame EMA output.

**Frame I/O dtype contract.** Every kernel accepts frames in the wire
dtype (f32, bf16, or uint8) and upcasts in-VMEM via the canonical
``ref.upcast_frames`` (uint8 is the quantization ``round(v*255)``, so the
upcast is ``/255``) — compute is always f32, and uint8 ingest cuts input
HBM traffic 4x. ``out_dtype`` picks the J/t output dtype (default:
follow float ingest, f32 for uint8); ``a_seq`` stays f32.

**Double buffering.** ``buffer_depth >= 2`` switches the frame input (and
the halo planes, for the halo kernel) to ``memory_space=ANY`` (HBM) and
streams blocks through a ``(depth, fpb, ...)`` VMEM scratch ring with
manual ``pltpu.make_async_copy`` DMAs: the copy of grid step n+1 is
started before compute on step n, so the sequential grid overlaps
HBM->VMEM traffic with compute instead of serializing on each block's
implicit BlockSpec copy. ``buffer_depth=1`` is the classic automatic
pipeline (the interpret-safe fallback the dispatch layer selects on the
interpret substrate); the manual-DMA path itself also runs under
``interpret=True`` for parity tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.atmolight import (flat_iota_2d as _flat_iota_2d,
                                     topk_select as _topk_select)
from repro.kernels.boxfilter import _box_pass, _counts_2d, _masked_box_mean
from repro.kernels.dark_channel import _min_pass
from repro.kernels.ref import (CAP_COEFFS, LUMA_WEIGHTS as _LUMA,
                               premap as _premap,
                               resolve_out_dtype as _resolve_out_dtype,
                               tmap_from_dark as _tmap_from_dark,
                               upcast_frames as _upcast_frames)

ALGORITHMS = ("dcp", "cap")


def _resolve_frames_per_block(batch: int, requested: int) -> int:
    """Largest divisor of ``batch`` that is <= ``requested`` (>= 1).

    An autotuned tile that does not divide the batch degrades *gracefully*
    (e.g. requested 4 over a batch of 6 runs 3-frame blocks) instead of
    silently collapsing to 1 frame per grid step.
    """
    fpb = max(1, min(requested, batch)) if requested > 0 else 1
    while batch % fpb:
        fpb -= 1
    return fpb


def _guided_refine(img: jnp.ndarray, t_raw: jnp.ndarray, radius: int,
                   eps: float) -> jnp.ndarray:
    """In-VMEM guided filter (luma guide) + [0,1] clip. img: (H, W, 3) f32."""
    h, w = t_raw.shape
    g = _LUMA[0] * img[..., 0] + _LUMA[1] * img[..., 1] + _LUMA[2] * img[..., 2]
    cnt = _counts_2d(h, w, radius)

    def bf(v):
        return _box_pass(_box_pass(v, radius, axis=0), radius, axis=1) / cnt

    mean_g = bf(g)
    mean_p = bf(t_raw)
    corr_gp = bf(g * t_raw)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return jnp.clip(bf(a) * g + bf(b), 0.0, 1.0)


def _frame_tmap(img: jnp.ndarray, a0: jnp.ndarray, *, algorithm: str,
                radius: int, omega: float, beta: float,
                cap_w: Tuple[float, float, float], refine: bool,
                gf_radius: int, gf_eps: float, topk: int = 1):
    """Steps 1-3 (+5) for one (H, W, 3) f32 frame: t_raw, refined t, candidate.

    The A candidate is the argmin-t pixel (Eq. 6) for ``topk == 1`` and the
    mean of the ``topk`` smallest-t pixels otherwise — selected entirely in
    VMEM by ``atmolight.topk_select``, with the same (t, flat index)
    tie-breaking as ``lax.top_k``, so it matches the staged
    ``kernels.atmolight`` / ``kernels.ref.atmospheric_light`` estimators
    for both DCP and CAP.
    """
    # ref.premap is the canonical form (pure jnp, traces in-kernel too);
    # the sharded step computes the identical map outside the kernel before
    # the halo exchange, which is what keeps fused and staged paths equal.
    pre = _premap(img, a0, algorithm, cap_w)                    # (H, W)
    dark = _min_pass(_min_pass(pre, radius, axis=0), radius, axis=1)
    t_raw = _tmap_from_dark(dark, algorithm=algorithm, omega=omega, beta=beta)
    if topk == 1:
        flat_t = t_raw.reshape(-1)
        j = jnp.argmin(flat_t)
        cand_min = flat_t[j]
        cand_rgb = img.reshape(-1, 3)[j]
    else:
        h, w = t_raw.shape
        tk_t, _, tk_rgb = _topk_select(t_raw, _flat_iota_2d(h, w), img, topk)
        cand_min = tk_t[0]
        cand_rgb = tk_rgb.mean(axis=0)
    t = _guided_refine(img, t_raw, gf_radius, gf_eps) if refine else t_raw
    return t, cand_min, cand_rgb


def _ema_step(cand: jnp.ndarray, fid: jnp.ndarray, A_prev: jnp.ndarray,
              k_prev: jnp.ndarray, inited: jnp.ndarray, *, period: int,
              lam: float):
    """One step of the paper's Eq. 9 update strategy.

    ``fid``/``k_prev`` stay int32 end-to-end — frame ids exceed f32's 2^24
    integer range within days of continuous streaming. A padding frame
    (``fid < 0``, the spout's tail fill) is masked out entirely: no update,
    no ``initialized`` flip."""
    valid = fid >= 0
    bootstrap = jnp.logical_and(valid, inited == 0)
    do = jnp.logical_and(valid, jnp.logical_or(
        bootstrap, (fid - k_prev) >= period))
    target = jnp.where(bootstrap, cand, lam * cand + (1.0 - lam) * A_prev)
    A = jnp.where(do, target, A_prev)
    k = jnp.where(do, fid, k_prev)
    inited_next = jnp.maximum(inited, valid.astype(inited.dtype))
    return A, k, inited_next


def _dehaze_grid_step(load_frame, ids_ref, state_f_ref, state_i_ref,
                      out_ref, t_ref, aseq_ref, statef_ref, statei_ref,
                      carry_f_ref, carry_i_ref, lane, blk, *,
                      algorithm: str, radius: int, omega: float, beta: float,
                      cap_w: Tuple[float, float, float], refine: bool,
                      gf_radius: int, gf_eps: float, t0: float,
                      gamma: float, period: int, lam: float, topk: int,
                      frames_per_block: int):
    """One (lane, batch-block) grid step of the megakernel, frame source
    abstracted: ``load_frame(f)`` yields the f-th (H, W, 3) f32 frame of
    the block — an automatic BlockSpec copy in the classic kernel, a slot
    of the manual-DMA VMEM ring in the double-buffered one. Both flavors
    share this body, so they are trivially bit-identical.

    ``carry_f_ref``/``carry_i_ref`` are (L, 3)/(L, 2) VMEM *scratch*: row
    ``lane`` is that lane's running (A, last_update, initialized) EMA
    state. Scratch persists across the whole sequential grid, so the carry
    is correct under either grid order — within a lane the batch blocks
    always run in ascending order, and no two lanes touch the same row.
    """
    @pl.when(blk == 0)
    def _init_carry():
        carry_f_ref[pl.ds(lane, 1)] = state_f_ref[0:1]
        carry_i_ref[pl.ds(lane, 1)] = state_i_ref[0:1]

    A = carry_f_ref[pl.ds(lane, 1)][0]
    ci = carry_i_ref[pl.ds(lane, 1)][0]
    k = ci[0]
    inited = ci[1]
    # Pre-map divisor: the lane's batch-entry *saved* A for every frame
    # (§3.3); state_f_ref is an input block, so it stays constant while the
    # carry rows advance. (CAP's pre-map is A-free and ignores it.)
    a0 = jnp.maximum(state_f_ref[0].astype(jnp.float32), 1e-3)

    for f in range(frames_per_block):
        img = load_frame(f)                             # (H, W, 3) f32
        t, cand_min, cand_rgb = _frame_tmap(
            img, a0, algorithm=algorithm, radius=radius, omega=omega,
            beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
            gf_eps=gf_eps, topk=topk)
        A, k, inited = _ema_step(cand_rgb, ids_ref[f, 0], A, k, inited,
                                 period=period, lam=lam)
        aseq_ref[f] = A
        tt = jnp.maximum(t, t0)[..., None]
        J = jnp.clip((img - A) / tt + A, 0.0, 1.0)
        if gamma != 1.0:
            J = J ** gamma
        out_ref[f] = J.astype(out_ref.dtype)
        t_ref[f] = t.astype(t_ref.dtype)

    ci_next = jnp.stack([k, inited])
    carry_f_ref[pl.ds(lane, 1)] = A[None]
    carry_i_ref[pl.ds(lane, 1)] = ci_next[None]
    # Final-state outputs are written every block; the last block of a lane
    # is the last writer of that lane's (1, 3)/(1, 2) output block, so the
    # flushed value is the lane's final EMA state under both grid orders.
    statef_ref[0] = A
    statei_ref[0] = ci_next


def _fused_dehaze_kernel(img_ref, ids_ref, state_f_ref, state_i_ref,
                         out_ref, t_ref, aseq_ref, statef_ref, statei_ref,
                         carry_f_ref, carry_i_ref, *,
                         algorithm: str, radius: int, omega: float, beta: float,
                         cap_w: Tuple[float, float, float], refine: bool,
                         gf_radius: int, gf_eps: float, t0: float,
                         gamma: float, period: int, lam: float, topk: int,
                         frames_per_block: int, lane_major: bool):
    """Classic megakernel body: frames arrive as automatic BlockSpec copies
    (the grid pipeline serializes each block's HBM->VMEM copy with its
    compute); the in-VMEM upcast makes the wire dtype free here too."""
    if lane_major:
        lane, blk = pl.program_id(0), pl.program_id(1)
    else:
        blk, lane = pl.program_id(0), pl.program_id(1)
    _dehaze_grid_step(
        lambda f: _upcast_frames(img_ref[f]), ids_ref, state_f_ref,
        state_i_ref, out_ref, t_ref, aseq_ref, statef_ref, statei_ref,
        carry_f_ref, carry_i_ref, lane, blk, algorithm=algorithm,
        radius=radius, omega=omega, beta=beta, cap_w=cap_w, refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps, t0=t0, gamma=gamma,
        period=period, lam=lam, topk=topk, frames_per_block=frames_per_block)


def _fused_dehaze_dbuf_kernel(img_hbm_ref, ids_ref, state_f_ref, state_i_ref,
                              out_ref, t_ref, aseq_ref, statef_ref,
                              statei_ref, carry_f_ref, carry_i_ref,
                              img_vmem, dma_sem, *,
                              algorithm: str, radius: int, omega: float,
                              beta: float,
                              cap_w: Tuple[float, float, float], refine: bool,
                              gf_radius: int, gf_eps: float, t0: float,
                              gamma: float, period: int, lam: float,
                              topk: int, frames_per_block: int,
                              lane_major: bool, n_lanes: int, nblk: int,
                              buffer_depth: int):
    """Double-buffered megakernel body: the frame input stays in HBM
    (``memory_space=ANY``) and blocks stream through the ``img_vmem``
    ``(depth, fpb, H, W, 3)`` ring via manual ``make_async_copy`` DMAs.

    Grid step g waits on the copy it (or the warm-up) started for its own
    block, but first *starts* the copy for step g+1 into the next ring
    slot — so block n+1's HBM->VMEM traffic overlaps block n's compute.
    Slot reuse is race-free on the sequential grid: slot ``(g+1) % depth``
    was last read by step ``g+1-depth``, which finished before step g
    began. The linear step index g and the flat frame row are recomputed
    from the program ids under either grid order, so the DMA schedule is
    exactly the BlockSpec index map of the classic kernel.
    """
    fpb = frames_per_block
    if lane_major:
        lane, blk = pl.program_id(0), pl.program_id(1)
        g = lane * nblk + blk
    else:
        blk, lane = pl.program_id(0), pl.program_id(1)
        g = blk * n_lanes + lane

    def copy_in(slot, g2):
        # Flat frame row of linear grid step g2 (mirrors ``frame_map``).
        if lane_major:
            l2, i2 = g2 // nblk, g2 % nblk
        else:
            l2, i2 = g2 % n_lanes, g2 // n_lanes
        row = (l2 * nblk + i2) * fpb
        return pltpu.make_async_copy(img_hbm_ref.at[pl.ds(row, fpb)],
                                     img_vmem.at[slot], dma_sem.at[slot])

    total = n_lanes * nblk
    slot = jax.lax.rem(g, buffer_depth)

    @pl.when(g == 0)
    def _warm_up():
        copy_in(slot, g).start()

    @pl.when(g + 1 < total)
    def _prefetch_next():
        copy_in(jax.lax.rem(g + 1, buffer_depth), g + 1).start()

    copy_in(slot, g).wait()
    block = img_vmem[pl.ds(slot, 1)][0]                 # (fpb, H, W, 3) wire
    _dehaze_grid_step(
        lambda f: _upcast_frames(block[f]), ids_ref, state_f_ref,
        state_i_ref, out_ref, t_ref, aseq_ref, statef_ref, statei_ref,
        carry_f_ref, carry_i_ref, lane, blk, algorithm=algorithm,
        radius=radius, omega=omega, beta=beta, cap_w=cap_w, refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps, t0=t0, gamma=gamma,
        period=period, lam=lam, topk=topk, frames_per_block=fpb)


@functools.partial(jax.jit, static_argnames=(
    "algorithm", "radius", "omega", "beta", "cap_w", "refine", "gf_radius",
    "gf_eps", "t0", "gamma", "period", "lam", "topk", "frames_per_block",
    "lane_major", "out_dtype", "buffer_depth", "interpret"))
def fused_dehaze_lanes_pallas(
        img: jnp.ndarray, frame_ids: jnp.ndarray, carry_f: jnp.ndarray,
        carry_i: jnp.ndarray, *, algorithm: str = "dcp", radius: int,
        omega: float = 0.95, beta: float = 1.0,
        cap_w: Tuple[float, float, float] = CAP_COEFFS, refine: bool,
        gf_radius: int, gf_eps: float, t0: float, gamma: float,
        period: int, lam: float, topk: int = 1, frames_per_block: int = 1,
        lane_major: bool = True, out_dtype: str = None,
        buffer_depth: int = 1, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Lane-native single-launch dehaze for L independent streams.

    img: (L, B, H, W, 3) in the wire dtype (f32/bf16/uint8 — upcast
    in-VMEM, see the module dtype contract); frame_ids: (L, B) int (< 0 =
    padding); carry_f: (L, 3) f32 saved A per lane; carry_i: (L, 2) int32
    (last_update, initialized) per lane — the layout produced by
    ``core.normalize.lane_carry``.

    Returns ``(J (L, B, H, W, 3), t (L, B, H, W), a_seq (L, B, 3) f32,
    carry_f' (L, 3), carry_i' (L, 2))`` with J/t in
    ``ref.resolve_out_dtype(img.dtype, out_dtype)``. Per lane the outputs
    are bit-identical to ``fused_dehaze_pallas`` on that lane alone: the
    grid is ``(L, B // frames_per_block)`` (``lane_major``) or its
    transpose (frame-major, a cache-locality tuning choice — resolved by
    the ``fused_lanes`` tuning bucket), each lane's EMA lives in its own
    ``(L, ...)`` scratch row, and an all-padding lane's carry rides
    through untouched. One ``pallas_call`` for all L streams.
    ``buffer_depth >= 2`` selects the manual-DMA double-buffered body
    (identical results; the frame copy of grid step n+1 overlaps step n's
    compute).
    """
    L, b, h, w, c = img.shape
    assert c == 3 and frame_ids.shape == (L, b), (img.shape, frame_ids.shape)
    assert carry_f.shape == (L, 3) and carry_i.shape == (L, 2)
    assert algorithm in ALGORITHMS, algorithm
    fpb = _resolve_frames_per_block(b, frames_per_block)
    nblk = b // fpb
    odt = _resolve_out_dtype(img.dtype, out_dtype)
    depth = max(1, min(buffer_depth, L * nblk))
    # Lane-flattened views keep the blocks 4-D (the same shapes the
    # single-stream kernel tiles); the (lane, block) -> row arithmetic
    # lives in the index maps.
    flat_img = img.reshape(L * b, h, w, 3)
    ids = frame_ids.astype(jnp.int32).reshape(L * b, 1)
    state_f = carry_f.astype(jnp.float32)
    state_i = carry_i.astype(jnp.int32)

    if lane_major:
        grid = (L, nblk)

        def gi(l, i):
            return l, i
    else:
        grid = (nblk, L)

        def gi(i, l):
            return l, i

    def frame_map(*g):
        l, i = gi(*g)
        return l * nblk + i

    kw = dict(algorithm=algorithm, radius=radius, omega=omega, beta=beta,
              cap_w=cap_w, refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
              t0=t0, gamma=gamma, period=period, lam=lam, topk=topk,
              frames_per_block=fpb, lane_major=lane_major)
    scratch = [pltpu.VMEM((L, 3), jnp.float32),
               pltpu.VMEM((L, 2), jnp.int32)]
    if depth >= 2:
        kernel = functools.partial(_fused_dehaze_dbuf_kernel, **kw,
                                   n_lanes=L, nblk=nblk, buffer_depth=depth)
        # Frames stay in HBM; the kernel DMAs them into the VMEM ring.
        img_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch += [pltpu.VMEM((depth, fpb, h, w, 3), img.dtype),
                    pltpu.SemaphoreType.DMA((depth,))]
    else:
        kernel = functools.partial(_fused_dehaze_kernel, **kw)
        img_spec = pl.BlockSpec((fpb, h, w, 3),
                                lambda *g: (frame_map(*g), 0, 0, 0))
    out, t, a_seq, statef, statei = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            img_spec,
            pl.BlockSpec((fpb, 1), lambda *g: (frame_map(*g), 0)),
            pl.BlockSpec((1, 3), lambda *g: (gi(*g)[0], 0)),
            pl.BlockSpec((1, 2), lambda *g: (gi(*g)[0], 0)),
        ],
        out_specs=[
            pl.BlockSpec((fpb, h, w, 3), lambda *g: (frame_map(*g), 0, 0, 0)),
            pl.BlockSpec((fpb, h, w), lambda *g: (frame_map(*g), 0, 0)),
            pl.BlockSpec((fpb, 3), lambda *g: (frame_map(*g), 0)),
            pl.BlockSpec((1, 3), lambda *g: (gi(*g)[0], 0)),
            pl.BlockSpec((1, 2), lambda *g: (gi(*g)[0], 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L * b, h, w, 3), odt),
            jax.ShapeDtypeStruct((L * b, h, w), odt),
            jax.ShapeDtypeStruct((L * b, 3), jnp.float32),
            jax.ShapeDtypeStruct((L, 3), jnp.float32),
            jax.ShapeDtypeStruct((L, 2), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(flat_img, ids, state_f, state_i)
    return (out.reshape(L, b, h, w, 3), t.reshape(L, b, h, w),
            a_seq.reshape(L, b, 3), statef, statei)


@functools.partial(jax.jit, static_argnames=(
    "algorithm", "radius", "omega", "beta", "cap_w", "refine", "gf_radius",
    "gf_eps", "t0", "gamma", "period", "lam", "topk", "frames_per_block",
    "out_dtype", "buffer_depth", "interpret"))
def fused_dehaze_pallas(
        img: jnp.ndarray, frame_ids: jnp.ndarray, A_saved: jnp.ndarray,
        last_update: jnp.ndarray, initialized: jnp.ndarray, *,
        algorithm: str = "dcp", radius: int, omega: float = 0.95,
        beta: float = 1.0, cap_w: Tuple[float, float, float] = CAP_COEFFS,
        refine: bool, gf_radius: int, gf_eps: float, t0: float, gamma: float,
        period: int, lam: float, topk: int = 1, frames_per_block: int = 1,
        out_dtype: str = None, buffer_depth: int = 1,
        interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-launch dehaze: (B,H,W,3) -> (J, t, a_seq, A_fin, k_fin).

    ``A_saved``/``last_update``/``initialized`` are the ``AtmoState`` fields;
    the EMA state is carried across the sequential grid, so ``a_seq[b]`` is
    bit-equal to running the Eq. 9 scan outside the kernel. A lane-count-1
    view of the lane-native kernel (``fused_dehaze_lanes_pallas``).
    """
    b = img.shape[0]
    assert frame_ids.shape == (b,)
    carry_f = A_saved.astype(jnp.float32).reshape(1, 3)
    carry_i = jnp.stack([last_update.astype(jnp.int32),
                         initialized.astype(jnp.int32)]).reshape(1, 2)
    out, t, a_seq, statef, statei = fused_dehaze_lanes_pallas(
        img[None], frame_ids.reshape(1, b), carry_f, carry_i,
        algorithm=algorithm, radius=radius, omega=omega, beta=beta,
        cap_w=cap_w, refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
        t0=t0, gamma=gamma, period=period, lam=lam, topk=topk,
        frames_per_block=frames_per_block, out_dtype=out_dtype,
        buffer_depth=buffer_depth, interpret=interpret)
    return out[0], t[0], a_seq[0], statef[0], statei[0, 0]


# Back-compat alias (PR 1 shipped the DCP-only kernel under this name).
fused_dehaze_dcp_pallas = fused_dehaze_pallas


def _fused_tmap_kernel(img_ref, a0_ref, t_ref, cand_ref, *, algorithm: str,
                       radius: int, omega: float, beta: float,
                       cap_w: Tuple[float, float, float], refine: bool,
                       gf_radius: int, gf_eps: float, topk: int):
    img = _upcast_frames(img_ref[0])
    a0 = jnp.maximum(a0_ref[0].astype(jnp.float32), 1e-3)
    t, cand_min, cand_rgb = _frame_tmap(
        img, a0, algorithm=algorithm, radius=radius, omega=omega, beta=beta,
        cap_w=cap_w, refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
        topk=topk)
    t_ref[0] = t.astype(t_ref.dtype)
    cand_ref[0, 0] = cand_min
    cand_ref[0, 1:4] = cand_rgb


@functools.partial(jax.jit, static_argnames=(
    "algorithm", "radius", "omega", "beta", "cap_w", "refine", "gf_radius",
    "gf_eps", "topk", "out_dtype", "interpret"))
def fused_transmission_pallas(
        img: jnp.ndarray, A_saved: jnp.ndarray, *, algorithm: str = "dcp",
        radius: int, omega: float = 0.95, beta: float = 1.0,
        cap_w: Tuple[float, float, float] = CAP_COEFFS, refine: bool,
        gf_radius: int, gf_eps: float, topk: int = 1,
        out_dtype: str = None, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sharded-step variant: (B,H,W,3) -> (t, t_min (B,), cand_rgb (B,3)).

    Fuses pre-map + min filter + guided refine + per-frame candidate
    (argmin for ``topk == 1``, in-VMEM mean-of-top-k otherwise) in one
    launch; the EMA and the recovery stay outside because the candidates
    must cross shards (all-gather) first. ``img`` may be any wire dtype.
    """
    b, h, w, c = img.shape
    assert c == 3
    assert algorithm in ALGORITHMS, algorithm
    odt = _resolve_out_dtype(img.dtype, out_dtype)
    a0 = A_saved.astype(jnp.float32).reshape(1, 3)
    kernel = functools.partial(
        _fused_tmap_kernel, algorithm=algorithm, radius=radius, omega=omega,
        beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
        gf_eps=gf_eps, topk=topk)
    t, cand = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, w), odt),
            jax.ShapeDtypeStruct((b, 4), jnp.float32),
        ],
        interpret=interpret,
    )(img, a0)
    return t, cand[:, 0], cand[:, 1:4].astype(odt)


@functools.partial(jax.jit, static_argnames=(
    "algorithm", "radius", "omega", "beta", "cap_w", "refine", "gf_radius",
    "gf_eps", "topk", "out_dtype", "interpret"))
def fused_transmission_lanes_pallas(
        img: jnp.ndarray, A_saved: jnp.ndarray, *, algorithm: str = "dcp",
        radius: int, omega: float = 0.95, beta: float = 1.0,
        cap_w: Tuple[float, float, float] = CAP_COEFFS, refine: bool,
        gf_radius: int, gf_eps: float, topk: int = 1,
        out_dtype: str = None, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Lane-native sharded-step stage: (L,B,H,W,3) + per-lane A (L,3) ->
    (t (L,B,H,W), t_min (L,B), cand_rgb (L,B,3)).

    The stage is stateless across frames, so the lane axis folds into a
    flat ``L*B`` grid; what makes it lane-*native* (vs reshaping into the
    single-stream kernel) is the per-lane saved-A input — frame row ``i``
    reads its own lane's A block via the ``i // B`` index map, so every
    lane's DCP pre-map divides by that lane's coherent A. One launch for
    all L streams.
    """
    L, b, h, w, c = img.shape
    assert c == 3 and A_saved.shape == (L, 3), (img.shape, A_saved.shape)
    assert algorithm in ALGORITHMS, algorithm
    odt = _resolve_out_dtype(img.dtype, out_dtype)
    flat = img.reshape(L * b, h, w, 3)
    a0 = A_saved.astype(jnp.float32)
    kernel = functools.partial(
        _fused_tmap_kernel, algorithm=algorithm, radius=radius, omega=omega,
        beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
        gf_eps=gf_eps, topk=topk)
    t, cand = pl.pallas_call(
        kernel,
        grid=(L * b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (i // b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L * b, h, w), odt),
            jax.ShapeDtypeStruct((L * b, 4), jnp.float32),
        ],
        interpret=interpret,
    )(flat, a0)
    return (t.reshape(L, b, h, w), cand[:, 0].reshape(L, b),
            cand[:, 1:4].astype(odt).reshape(L, b, 3))


# ---------------------------------------------------------------------------
# Halo-aware fused transmission (spatially-sharded pipeline, H and/or W)
# ---------------------------------------------------------------------------

def _masked_guided_refine(guide: jnp.ndarray, t_raw: jnp.ndarray,
                          valid_f: jnp.ndarray, valid_w_f: jnp.ndarray,
                          radius: int, eps: float) -> jnp.ndarray:
    """Guided filter with all five means over valid rows/columns only (no
    clip — the caller clips after slicing the core block, matching
    ``core.spatial.masked_guided_filter`` + the staged chain)."""
    bf = functools.partial(_masked_box_mean, valid_f=valid_f, radius=radius,
                           valid_w_f=valid_w_f)
    mean_g = bf(guide)
    mean_p = bf(t_raw)
    corr_gp = bf(guide * t_raw)
    corr_gg = bf(guide * guide)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return bf(a) * guide + bf(b)


def _halo_grid_step(load_block, valid_ref, valid_w_ref, t_ref, cand_ref,
                    idx_ref, *, algorithm: str, radius: int, omega: float,
                    beta: float, refine: bool, gf_radius: int,
                    gf_eps: float, halo_h: int, halo_w: int,
                    topk: int, frames_per_block: int):
    """One batch-block of the halo kernel, frame source abstracted:
    ``load_block(f)`` yields the f-th ``(img (H_loc, W_loc, 3), pre_ext,
    guide_ext (H_ext, W_ext))`` f32 triple — BlockSpec copies in the
    classic flavor, slots of the manual-DMA VMEM rings in the
    double-buffered one. Both flavors share this body."""
    valid_f = valid_ref[0]                        # (H_ext,) float row mask
    valid_w_f = valid_w_ref[0]                    # (W_ext,) float col mask
    mask2d = jnp.logical_and(valid_f[:, None] > 0.5, valid_w_f[None, :] > 0.5)

    for f in range(frames_per_block):
        img, pre, guide = load_block(f)
        h_loc, w_loc = img.shape[0], img.shape[1]

        # Masked min filter: invalid (off-mesh) rows/cols are +inf, so
        # windows that straddle a mesh edge clip exactly like image-border
        # windows.
        pm = jnp.where(mask2d, pre, jnp.inf)
        dark = _min_pass(_min_pass(pm, radius, axis=0), radius, axis=1)
        t_raw_ext = _tmap_from_dark(dark, algorithm=algorithm, omega=omega,
                                    beta=beta)
        t_raw = jax.lax.slice_in_dim(
            jax.lax.slice_in_dim(t_raw_ext, halo_h, halo_h + h_loc, axis=0),
            halo_w, halo_w + w_loc, axis=1)
        if refine:
            t_ext = _masked_guided_refine(guide, t_raw_ext, valid_f,
                                          valid_w_f, gf_radius, gf_eps)
            t = jnp.clip(jax.lax.slice_in_dim(
                jax.lax.slice_in_dim(t_ext, halo_h, halo_h + h_loc, axis=0),
                halo_w, halo_w + w_loc, axis=1), 0.0, 1.0)
        else:
            t = t_raw

        # Shard-local top-k candidates over the core block, ascending in
        # (t, local flat index) — the same running selection as the
        # unsharded megakernel, so the pipeline's cross-shard lexicographic
        # merge reproduces the global ``lax.top_k`` tie-breaking exactly.
        tk_t, tk_i, tk_rgb = _topk_select(
            t_raw, _flat_iota_2d(h_loc, w_loc), img, topk)
        t_ref[f] = t.astype(t_ref.dtype)
        cand_ref[f, :, 0] = tk_t
        cand_ref[f, :, 1:4] = tk_rgb
        idx_ref[f] = tk_i


def _fused_tmap_halo_kernel(img_ref, pre_ref, guide_ref, valid_ref,
                            valid_w_ref, t_ref, cand_ref, idx_ref, **kw):
    _halo_grid_step(
        lambda f: (_upcast_frames(img_ref[f]),
                   pre_ref[f].astype(jnp.float32),
                   guide_ref[f].astype(jnp.float32)),
        valid_ref, valid_w_ref, t_ref, cand_ref, idx_ref, **kw)


def _fused_tmap_halo_dbuf_kernel(img_ref, pre_ref, guide_ref, valid_ref,
                                 valid_w_ref, t_ref, cand_ref, idx_ref,
                                 img_vmem, pre_vmem, guide_vmem, dma_sem,
                                 *, nblk: int, buffer_depth: int, **kw):
    """Double-buffered halo kernel: the three per-frame planes (core RGB
    block + halo-extended pre-map and guide) stay in HBM and stream
    through per-plane VMEM rings; the three DMAs of batch-block g+1 are
    started before block g's compute. ``dma_sem`` is (depth, 3) — one
    semaphore per (slot, plane)."""
    fpb = kw["frames_per_block"]
    g = pl.program_id(0)

    def copies(slot, g2):
        row = g2 * fpb
        return (
            pltpu.make_async_copy(img_ref.at[pl.ds(row, fpb)],
                                  img_vmem.at[slot], dma_sem.at[slot, 0]),
            pltpu.make_async_copy(pre_ref.at[pl.ds(row, fpb)],
                                  pre_vmem.at[slot], dma_sem.at[slot, 1]),
            pltpu.make_async_copy(guide_ref.at[pl.ds(row, fpb)],
                                  guide_vmem.at[slot], dma_sem.at[slot, 2]),
        )

    slot = jax.lax.rem(g, buffer_depth)

    @pl.when(g == 0)
    def _warm_up():
        for cp in copies(slot, g):
            cp.start()

    @pl.when(g + 1 < nblk)
    def _prefetch_next():
        for cp in copies(jax.lax.rem(g + 1, buffer_depth), g + 1):
            cp.start()

    for cp in copies(slot, g):
        cp.wait()
    imgs = img_vmem[pl.ds(slot, 1)][0]
    pres = pre_vmem[pl.ds(slot, 1)][0]
    guides = guide_vmem[pl.ds(slot, 1)][0]
    _halo_grid_step(
        lambda f: (_upcast_frames(imgs[f]), pres[f].astype(jnp.float32),
                   guides[f].astype(jnp.float32)),
        valid_ref, valid_w_ref, t_ref, cand_ref, idx_ref, **kw)


@functools.partial(jax.jit, static_argnames=(
    "algorithm", "radius", "omega", "beta", "refine", "gf_radius", "gf_eps",
    "topk", "frames_per_block", "out_dtype", "buffer_depth", "interpret"))
def fused_transmission_halo_pallas(
        img: jnp.ndarray, pre_ext: jnp.ndarray, guide_ext: jnp.ndarray,
        valid: jnp.ndarray, valid_w: jnp.ndarray = None, *,
        algorithm: str = "dcp", radius: int, omega: float = 0.95,
        beta: float = 1.0, refine: bool, gf_radius: int, gf_eps: float,
        topk: int = 1, frames_per_block: int = 1, out_dtype: str = None,
        buffer_depth: int = 1, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Spatially-sharded fused transmission: one launch per local block.

    img:       (B, H_loc, W_loc, 3) — the shard's core pixels (candidates).
    pre_ext:   (B, H_ext, W_ext)    — halo-extended per-pixel pre-map.
    guide_ext: (B, H_ext, W_ext)    — halo-extended guide (luma).
    valid:     (H_ext,) bool        — row validity from the H halo exchange.
    valid_w:   (W_ext,) bool | None — column validity from the W halo
               exchange; None (no W sharding) means all columns valid.

    ``pre_ext``/``guide_ext`` may arrive in the halo *wire* dtype (e.g.
    bfloat16 under ``halo_dtype="bfloat16"``): the kernel upcasts them to
    float32 in-VMEM, so the exchanged planes feed the launch directly with
    no boundary re-cast pass — half the exchange bytes, bit-identical
    results to upcasting outside (bf16 -> f32 is exact).

    Returns ``(t (B, H_loc, W_loc), tk_t (B, k), tk_rgb (B, k, 3),
    tk_idx (B, k) int32)`` — the shard-local top-k smallest-t candidates in
    ascending (t, local flat index) order; matches
    ``kernels.ref.fused_transmission_halo`` (the masked per-stage XLA
    chain) on the same inputs to float tolerance. The pre-map is computed
    *outside* (it is per-pixel, so it rides the halo exchange), everything
    windowed runs masked in-VMEM here. ``frames_per_block`` frames share
    one grid step (no cross-frame state — pure tiling, resolved by the
    ``fused_halo_2d`` tuning bucket). ``img`` likewise may arrive in any
    wire dtype (uint8 ingest upcast in-VMEM); t and the candidate RGB are
    cast to ``ref.resolve_out_dtype(img.dtype, out_dtype)``.
    ``buffer_depth >= 2`` selects the manual-DMA double-buffered body.
    """
    b, h_loc, w_loc, c = img.shape
    h_ext, w_ext = pre_ext.shape[1], pre_ext.shape[2]
    assert c == 3 and guide_ext.shape == pre_ext.shape == (b, h_ext, w_ext)
    assert algorithm in ALGORITHMS, algorithm
    halo_h = (h_ext - h_loc) // 2
    halo_w = (w_ext - w_loc) // 2
    assert h_ext == h_loc + 2 * halo_h, (h_ext, h_loc)
    assert w_ext == w_loc + 2 * halo_w, (w_ext, w_loc)
    assert 1 <= topk <= h_loc * w_loc, (topk, h_loc, w_loc)
    fpb = _resolve_frames_per_block(b, frames_per_block)
    nblk = b // fpb
    odt = _resolve_out_dtype(img.dtype, out_dtype)
    depth = max(1, min(buffer_depth, nblk))
    vmask = valid.astype(jnp.float32).reshape(1, h_ext)
    if valid_w is None:
        valid_w = jnp.ones((w_ext,), jnp.float32)
    wmask = valid_w.astype(jnp.float32).reshape(1, w_ext)
    kw = dict(algorithm=algorithm, radius=radius, omega=omega, beta=beta,
              refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
              halo_h=halo_h, halo_w=halo_w, topk=topk, frames_per_block=fpb)
    scratch = []
    if depth >= 2:
        kernel = functools.partial(_fused_tmap_halo_dbuf_kernel, **kw,
                                   nblk=nblk, buffer_depth=depth)
        # The three per-frame planes stay in HBM; the kernel DMAs each
        # batch-block into its per-plane VMEM ring. The tiny validity
        # masks keep their automatic copies.
        plane_specs = [pl.BlockSpec(memory_space=pltpu.ANY)] * 3
        scratch = [pltpu.VMEM((depth, fpb, h_loc, w_loc, 3), img.dtype),
                   pltpu.VMEM((depth, fpb, h_ext, w_ext), pre_ext.dtype),
                   pltpu.VMEM((depth, fpb, h_ext, w_ext), guide_ext.dtype),
                   pltpu.SemaphoreType.DMA((depth, 3))]
    else:
        kernel = functools.partial(_fused_tmap_halo_kernel, **kw)
        plane_specs = [
            pl.BlockSpec((fpb, h_loc, w_loc, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fpb, h_ext, w_ext), lambda i: (i, 0, 0)),
            pl.BlockSpec((fpb, h_ext, w_ext), lambda i: (i, 0, 0)),
        ]
    t, cand, idx = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=plane_specs + [
            pl.BlockSpec((1, h_ext), lambda i: (0, 0)),
            pl.BlockSpec((1, w_ext), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((fpb, h_loc, w_loc), lambda i: (i, 0, 0)),
            pl.BlockSpec((fpb, topk, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((fpb, topk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h_loc, w_loc), odt),
            jax.ShapeDtypeStruct((b, topk, 4), jnp.float32),
            jax.ShapeDtypeStruct((b, topk), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(img, pre_ext, guide_ext, vmask, wmask)
    return t, cand[:, :, 0], cand[:, :, 1:4].astype(odt), idx
