"""Block-size/tiling registry + autotune sweep for the Pallas kernels.

Tile parameters (frames-per-block for the fused megakernel, row-tile height
for the atmolight reduction) are resolved per (op, shape-bucket) through a
three-level lookup, highest priority first:

  1. env override   ``REPRO_TUNE_<OP>`` — a JSON object, e.g.
                    ``REPRO_TUNE_FUSED_DCP='{"frames_per_block": 4}'``
  2. persisted table a JSON file written by :func:`autotune`, default
                    ``results/kernel_tuning.json`` (override the path with
                    ``REPRO_KERNEL_TUNING``)
  3. built-in default

:func:`autotune` times a caller-supplied builder over a candidate sweep on
the *current* backend and persists the winner, so a one-off
``python -m repro.kernels.tuning`` on the target pod bakes real
measurements into the table that every later run picks up.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, Optional

import jax

from repro.core import env as _env

DEFAULTS: Dict[str, Dict[str, Any]] = {
    # Per-algorithm buckets: CAP's pre-map (HSV depth, no divide-by-A) has a
    # different VMEM/FLOP profile, so its sweet spot is tuned separately.
    # ``buffer_depth`` is the manual-DMA input ring depth of the
    # double-buffered megakernel body (1 = classic automatic BlockSpec
    # pipeline; 2 = copy of block n+1 overlaps compute on block n). The
    # dispatch layer clamps it to 1 on the interpret substrate.
    "fused_dcp": {"frames_per_block": 1, "buffer_depth": 2},
    "fused_cap": {"frames_per_block": 1, "buffer_depth": 2},
    # Robust top-k A estimator (k > 1): the in-VMEM k-step running
    # selection adds compute per frame, so its tile is tuned apart from
    # the argmin (k=1) kernels.
    "fused_dcp_topk": {"frames_per_block": 1, "buffer_depth": 2},
    "fused_cap_topk": {"frames_per_block": 1, "buffer_depth": 2},
    # Spatially-sharded (H and/or W) halo megakernel: per-shard blocks are
    # smaller than full frames, so more of them fit one grid step.
    "fused_halo_2d": {"frames_per_block": 1, "buffer_depth": 2},
    # Lane-native multi-stream megakernel: the (lane, batch-block) grid
    # order trades carry-row locality (lane-major streams one lane's
    # whole batch) against output-tile locality (frame-major interleaves
    # lanes per block); the shape key includes the lane count, so the
    # frames_per_block x L product is swept per serving shape.
    "fused_lanes": {"frames_per_block": 1, "grid_order": "lane_major",
                    "buffer_depth": 2},
    "atmolight": {"tile_h": 0},          # 0 = whole frame per grid step
    "atmolight_topk": {"tile_h": 0},     # k-row grid-carry fold tile
}

def table_path() -> Path:
    return _env.tuning_table_path()


# Wire-dtype tags for non-f32 frame streams. The f32 bucket key stays the
# bare shape (back-compat with every committed/persisted table); uint8 and
# bf16 streams get their own buckets because the HBM-traffic profile — and
# therefore the optimal tile/buffer depth — changes with bytes/frame.
_DTYPE_TAGS = {"uint8": "u8", "bfloat16": "bf16"}


def shape_bucket(shape: Iterable[int], dtype=None) -> str:
    key = "x".join(str(int(s)) for s in shape)
    tag = _DTYPE_TAGS.get(jax.numpy.dtype(dtype).name) \
        if dtype is not None else None
    return f"{key}x{tag}" if tag else key


# (path, mtime) -> parsed table. get_params sits on the per-batch dispatch
# path, so eager (non-jitted) streaming must not pay a disk read per frame.
_TABLE_CACHE: Dict[str, tuple] = {}


def load_table(path: Optional[Path] = None) -> Dict[str, Dict[str, Dict[str, Any]]]:
    p = path or table_path()
    key = str(p)
    try:
        mtime = os.stat(p).st_mtime_ns
    except OSError:
        _TABLE_CACHE[key] = (None, {})
        return {}
    cached = _TABLE_CACHE.get(key)
    if cached and cached[0] == mtime:
        return cached[1]
    try:
        with open(p) as f:
            table = json.load(f)
    except (OSError, ValueError):
        table = {}
    _TABLE_CACHE[key] = (mtime, table)
    return table


def save_table(table: Dict[str, Any], path: Optional[Path] = None) -> Path:
    p = path or table_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
    # Update the cache directly: mtime granularity can be coarser than a
    # save-then-load round trip within one process.
    _TABLE_CACHE[str(p)] = (os.stat(p).st_mtime_ns, table)
    return p


def get_params(op: str, shape: Iterable[int], dtype=None) -> Dict[str, Any]:
    """Resolved tile params for ``op`` at ``shape`` (env > table > default).

    ``dtype`` is the frame wire dtype: non-f32 streams resolve their own
    dtype-tagged bucket (falling back through the untagged f32 bucket for
    keys the tagged entry doesn't override), so a uint8 toggle can never
    silently reuse an f32-tuned tile."""
    params = dict(DEFAULTS.get(op, {}))
    table = load_table()
    params.update(table.get(op, {}).get(shape_bucket(shape), {}))
    tagged = shape_bucket(shape, dtype)
    if tagged != shape_bucket(shape):
        params.update(table.get(op, {}).get(tagged, {}))
    params.update(_env.tune_override(op))   # malformed override -> ignored
    return params


def _time_callable(fn: Callable[[], Any], iters: int = 3) -> float:
    jax.block_until_ready(fn())          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def autotune(op: str, shape: Iterable[int],
             candidates: Iterable[Dict[str, Any]],
             build: Callable[[Dict[str, Any]], Callable[[], Any]],
             iters: int = 3, persist: bool = True,
             dtype=None) -> Dict[str, Any]:
    """Sweep ``candidates``, persist and return the fastest param dict.

    ``build(params)`` returns a no-arg callable to time; candidates whose
    build or execution raises are skipped (e.g. a tile that does not divide
    the shape, or VMEM overflow on a real TPU). ``dtype`` routes the
    persisted winner into the wire-dtype-tagged bucket (see
    :func:`shape_bucket`).
    """
    best, best_t = dict(DEFAULTS.get(op, {})), float("inf")
    for params in candidates:
        try:
            t = _time_callable(build(params), iters=iters)
        except Exception:
            continue
        if t < best_t:
            best, best_t = dict(params), t
    if persist:
        table = load_table()
        table.setdefault(op, {})[shape_bucket(shape, dtype)] = best
        save_table(table)
    return best


def autotune_fused(shapes=((4, 48, 64), (2, 120, 160)),
                   candidates=(1, 2, 4), iters: int = 3, persist: bool = True,
                   algorithms=("dcp", "cap"), topks=(1, 4),
                   depths=(1, 2, 3), io_dtypes=("float32", "uint8")) -> Dict[str, Any]:
    """Sweep ``frames_per_block`` x ``buffer_depth`` for the fused
    megakernels, per algorithm, per A-estimator (argmin vs robust top-k),
    and per frame wire dtype (f32 vs uint8 ingest — different bytes/frame,
    different overlap sweet spot; winners persist into dtype-tagged
    buckets).

    Uses the dispatch layer, so it times whatever substrate the current
    backend resolves to (Pallas on TPU, the XLA oracle on CPU). Each
    (algorithm, estimator) pair persists into its own bucket:
    ``fused_<algorithm>`` for topk=1, ``fused_<algorithm>_topk`` for k>1.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    table: Dict[str, Any] = {}
    for algorithm in algorithms:
        for topk in topks:
            op = f"fused_{algorithm}" + ("_topk" if topk > 1 else "")
            table.setdefault(op, {})
            for io_dtype in io_dtypes:
                for b, h, w in shapes:
                    r = np.random.default_rng(0)
                    frames = r.random((b, h, w, 3), np.float32)
                    img = jnp.asarray(ref.quantize_frames(frames, io_dtype))
                    ids = jnp.arange(b, dtype=jnp.int32)
                    A = jnp.ones((3,), jnp.float32)
                    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
                    init = jnp.asarray(False)

                    def build(params):
                        def run():
                            return ops.fused_dehaze(
                                img, ids, A, k0, init, algorithm=algorithm,
                                radius=7, omega=0.95, refine=True,
                                gf_radius=8, gf_eps=1e-3, t0=0.1, gamma=1.0,
                                period=8, lam=0.05, topk=topk,
                                frames_per_block=params["frames_per_block"],
                                buffer_depth=params["buffer_depth"])
                        return run

                    table[op][shape_bucket((b, h, w), img.dtype)] = autotune(
                        op, (b, h, w),
                        [{"frames_per_block": f, "buffer_depth": d}
                         for f in candidates for d in depths],
                        build, iters=iters, persist=persist, dtype=img.dtype)
    return table


def autotune_fused_lanes(shapes=((4, 4, 48, 64), (16, 2, 48, 64)),
                         fpb_candidates=(1, 2, 4),
                         orders=("lane_major", "frame_major"),
                         depths=(1, 2, 3),
                         iters: int = 3, persist: bool = True) -> Dict[str, Any]:
    """Sweep the lane-native megakernel's grid: ``frames_per_block`` x
    grid order (lane-major vs frame-major) x DMA ``buffer_depth``, per
    ``(L, B, H, W)`` serving shape, into the ``fused_lanes`` bucket.

    Uses the dispatch layer, so it times whatever substrate the backend
    resolves to — run on the serving pod to bake in real measurements.
    One lane is all-padding (ids -1), matching a typical partially
    occupied fleet tick.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    table: Dict[str, Any] = {"fused_lanes": {}}
    for n_lanes, b, h, w in shapes:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((n_lanes, b, h, w, 3), np.float32))
        ids = jnp.stack(
            [jnp.arange(b, dtype=jnp.int32)] * (n_lanes - 1)
            + [jnp.full((b,), -1, jnp.int32)])
        carry_f = jnp.ones((n_lanes, 3), jnp.float32)
        carry_i = jnp.stack([jnp.full((n_lanes,), -(2 ** 30), jnp.int32),
                             jnp.zeros((n_lanes,), jnp.int32)], axis=-1)

        def build(params):
            def run():
                return ops.fused_dehaze_lanes(
                    img, ids, carry_f, carry_i, algorithm="dcp", radius=7,
                    omega=0.95, refine=True, gf_radius=8, gf_eps=1e-3,
                    t0=0.1, gamma=1.0, period=8, lam=0.05,
                    frames_per_block=params["frames_per_block"],
                    lane_major=(params["grid_order"] == "lane_major"),
                    buffer_depth=params["buffer_depth"])
            return run

        table["fused_lanes"][shape_bucket((n_lanes, b, h, w))] = autotune(
            "fused_lanes", (n_lanes, b, h, w),
            [{"frames_per_block": f, "grid_order": o, "buffer_depth": d}
             for f in fpb_candidates for o in orders for d in depths],
            build, iters=iters, persist=persist)
    return table


def autotune_fused_halo(shapes=((4, 24, 64), (2, 60, 160)), halo=23,
                        candidates=(1, 2, 4), depths=(1, 2, 3),
                        iters: int = 3,
                        persist: bool = True) -> Dict[str, Any]:
    """Sweep ``frames_per_block`` x ``buffer_depth`` for the
    spatially-sharded halo megakernel (``fused_halo_2d`` bucket) on
    representative per-shard block shapes."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    table: Dict[str, Any] = {"fused_halo_2d": {}}
    for b, h_loc, w in shapes:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((b, h_loc, w, 3), np.float32))
        pre = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
        guide = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
        valid = jnp.arange(h_loc + 2 * halo) >= halo      # top-edge shard

        def build(params):
            def run():
                return ops.fused_transmission_halo(
                    img, pre, guide, valid, algorithm="dcp", radius=7,
                    omega=0.95, refine=True, gf_radius=8, gf_eps=1e-3,
                    frames_per_block=params["frames_per_block"],
                    buffer_depth=params["buffer_depth"])
            return run

        table["fused_halo_2d"][shape_bucket((b, h_loc, w))] = autotune(
            "fused_halo_2d", (b, h_loc, w),
            [{"frames_per_block": f, "buffer_depth": d}
             for f in candidates for d in depths],
            build, iters=iters, persist=persist)
    return table


if __name__ == "__main__":
    out = autotune_fused()
    out.update(autotune_fused_lanes())
    out.update(autotune_fused_halo())
    print(json.dumps({**out, "path": str(table_path())}, indent=2))
