"""Measured-search autotuner + device-kind-keyed tile-parameter tables.

Tile parameters (frames-per-block for the fused megakernel, DMA ring
depth, the lane-native grid order, row-tile height for the atmolight
reduction) are resolved per (op, shape-bucket) through a layered lookup,
highest priority first:

  1. env override    ``REPRO_TUNE_<OP>`` — a JSON object, e.g.
                     ``REPRO_TUNE_FUSED_DCP='{"frames_per_block": 4}'``
  2. measured table  the entry for the *current device kind*
                     (``jax.devices()[0].device_kind``, override with
                     ``REPRO_TUNE_DEVICE_KIND``) in the persisted JSON
                     table, default ``results/kernel_tuning.json``
                     (path override ``REPRO_KERNEL_TUNING``); within a
                     device kind the dtype-tagged bucket (``…xu8``)
                     layers over the untagged f32 bucket
  3. legacy table    pre-schema-2 tables had no device-kind key; their
                     entries still load, *below* any device-kind entry —
                     a table tuned on a TPU pod can no longer be silently
                     resolved as-if-measured by CPU CI (or vice versa)
  4. built-in default

``REPRO_TUNE_REQUIRE_TABLE=1`` turns a resolution that found neither a
table entry nor an env override into an error — serving fleets use it to
insist on real measurements instead of the built-in defaults.

The autotuner is a **measured search**: :func:`measured_search` runs
successive halving (eta = 3) over the joint candidate space — the whole
population is timed at ``start_iters`` timing iterations, only the
fastest third survives each rung at a tripled iteration count (capped at
``iters``) — so the total timed runs are provably below the exhaustive
``len(candidates) × iters`` product for every ``iters >= 2`` (each rung
costs at most ``N × start_iters`` runs and there are strictly fewer than
``iters`` rungs), while the winner matches the exhaustive sweep whenever
the candidate ranking is fidelity-stable (the best candidate ranks first
at every rung, and ``keep >= 1`` never prunes rank 1). Winners persist
under ``{device_kinds: {kind: {op: {bucket: {params, provenance}}}}}``
with per-entry provenance (time measured, iters, candidates
considered/skipped, method). A one-off
``python -m repro.kernels.tuning --search`` on the target hardware bakes
real measurements into the table every later run picks up; ``--validate``
checks a committed table's schema/provenance in CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax

from repro.core import env as _env

DEFAULTS: Dict[str, Dict[str, Any]] = {
    # Per-algorithm buckets: CAP's pre-map (HSV depth, no divide-by-A) has a
    # different VMEM/FLOP profile, so its sweet spot is tuned separately.
    # ``buffer_depth`` is the manual-DMA input ring depth of the
    # double-buffered megakernel body (1 = classic automatic BlockSpec
    # pipeline; 2 = copy of block n+1 overlaps compute on block n). The
    # dispatch layer clamps it to 1 on the interpret substrate.
    "fused_dcp": {"frames_per_block": 1, "buffer_depth": 2},
    "fused_cap": {"frames_per_block": 1, "buffer_depth": 2},
    # Robust top-k A estimator (k > 1): the in-VMEM k-step running
    # selection adds compute per frame, so its tile is tuned apart from
    # the argmin (k=1) kernels.
    "fused_dcp_topk": {"frames_per_block": 1, "buffer_depth": 2},
    "fused_cap_topk": {"frames_per_block": 1, "buffer_depth": 2},
    # Spatially-sharded (H and/or W) halo megakernel: per-shard blocks are
    # smaller than full frames, so more of them fit one grid step.
    "fused_halo_2d": {"frames_per_block": 1, "buffer_depth": 2},
    # Lane-native multi-stream megakernel: the (lane, batch-block) grid
    # order trades carry-row locality (lane-major streams one lane's
    # whole batch) against output-tile locality (frame-major interleaves
    # lanes per block); the shape key includes the lane count, so the
    # frames_per_block x L product is swept per serving shape.
    "fused_lanes": {"frames_per_block": 1, "grid_order": "lane_major",
                    "buffer_depth": 2},
    "atmolight": {"tile_h": 0},          # 0 = whole frame per grid step
    "atmolight_topk": {"tile_h": 0},     # k-row grid-carry fold tile
}

# Persisted-table schema version. Version 2 keys entries by device kind
# and wraps each winner as {"params", "provenance"}; version-1 tables
# (bare {op: {bucket: params}}) still load through the legacy layer.
SCHEMA_VERSION = 2


class AutotuneError(RuntimeError):
    """Every candidate in an autotune sweep failed to build/run.

    Raised instead of persisting the built-in DEFAULTS as a "measured
    winner" (the pre-schema-2 behavior: ``best_t`` never left ``inf``, so
    a sweep whose every candidate raised — wrong shapes, VMEM overflow —
    silently wrote the defaults into the table with full measured
    authority)."""


def table_path() -> Path:
    return _env.tuning_table_path()


_HW_DEVICE_KIND: Optional[str] = None


def device_kind() -> str:
    """The device-kind key measured winners persist (and resolve) under.

    ``REPRO_TUNE_DEVICE_KIND`` overrides (checked per call — CI validates
    foreign tables this way); the hardware answer
    (``jax.devices()[0].device_kind``, e.g. ``"cpu"``, ``"TPU v5e"``) is
    cached for the process, since ``get_params`` sits on the eager
    per-batch dispatch path."""
    env = _env.tune_device_kind()
    if env:
        return env
    global _HW_DEVICE_KIND
    if _HW_DEVICE_KIND is None:
        _HW_DEVICE_KIND = str(jax.devices()[0].device_kind)
    return _HW_DEVICE_KIND


# Wire-dtype tags for non-f32 frame streams. The f32 bucket key stays the
# bare shape (back-compat with every committed/persisted table); uint8 and
# bf16 streams get their own buckets because the HBM-traffic profile — and
# therefore the optimal tile/buffer depth — changes with bytes/frame.
_DTYPE_TAGS = {"uint8": "u8", "bfloat16": "bf16"}


def shape_bucket(shape: Iterable[int], dtype=None) -> str:
    key = "x".join(str(int(s)) for s in shape)
    tag = _DTYPE_TAGS.get(jax.numpy.dtype(dtype).name) \
        if dtype is not None else None
    return f"{key}x{tag}" if tag else key


# (path, mtime) -> parsed table. get_params sits on the per-batch dispatch
# path, so eager (non-jitted) streaming must not pay a disk read per frame.
_TABLE_CACHE: Dict[str, tuple] = {}


def load_table(path: Optional[Path] = None) -> Dict[str, Any]:
    p = path or table_path()
    key = str(p)
    try:
        mtime = os.stat(p).st_mtime_ns
    except OSError:
        _TABLE_CACHE[key] = (None, {})
        return {}
    cached = _TABLE_CACHE.get(key)
    if cached and cached[0] == mtime:
        return cached[1]
    try:
        with open(p) as f:
            table = json.load(f)
    except (OSError, ValueError):
        table = {}
    _TABLE_CACHE[key] = (mtime, table)
    return table


def save_table(table: Dict[str, Any], path: Optional[Path] = None) -> Path:
    p = path or table_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
    # Update the cache directly: mtime granularity can be coarser than a
    # save-then-load round trip within one process.
    _TABLE_CACHE[str(p)] = (os.stat(p).st_mtime_ns, table)
    return p


# ---------------------------------------------------------------------------
# Schema-2 table layout + legacy migration
# ---------------------------------------------------------------------------

_RESERVED_KEYS = ("schema", "device_kinds", "legacy")


def migrate_table(table: Dict[str, Any]) -> Dict[str, Any]:
    """Normalize any loaded table to the schema-2 layout.

    A version-1 table is a bare ``{op: {bucket: params}}`` mapping with no
    record of what hardware measured it; migration moves those ops under
    the ``"legacy"`` section (NOT under the current device kind — claiming
    a foreign table as locally measured is exactly the bug the device-kind
    key fixes) and leaves ``device_kinds`` for real measurements."""
    if table.get("schema") == SCHEMA_VERSION:
        return table
    legacy_ops = {k: v for k, v in table.items() if k not in _RESERVED_KEYS}
    return {"schema": SCHEMA_VERSION,
            "device_kinds": dict(table.get("device_kinds", {})),
            "legacy": {**table.get("legacy", {}), **legacy_ops}}


def _entry_params(entry: Dict[str, Any]) -> Dict[str, Any]:
    """A table entry's params: schema-2 entries wrap them as
    ``{"params": ..., "provenance": ...}``, legacy entries are bare."""
    if "params" in entry and isinstance(entry["params"], dict):
        return entry["params"]
    return entry


def _table_layers(table: Dict[str, Any], kind: str
                  ) -> List[Dict[str, Dict[str, Any]]]:
    """``{op: {bucket: entry}}`` mappings lowest-priority first: the
    legacy (untagged-by-device) section, then the current device kind's."""
    if table.get("schema") == SCHEMA_VERSION or "device_kinds" in table:
        legacy = table.get("legacy", {})
        kinds = table.get("device_kinds", {})
    else:                                   # version-1 file, unmigrated
        legacy = {k: v for k, v in table.items() if k not in _RESERVED_KEYS}
        kinds = {}
    return [legacy, kinds.get(kind, {})]


def get_params(op: str, shape: Iterable[int], dtype=None) -> Dict[str, Any]:
    """Resolved tile params for ``op`` at ``shape``.

    Layering (see module docstring): env override > the current device
    kind's table entry > legacy (device-untagged) table entry > built-in
    default; within each table layer the wire-dtype-tagged bucket
    (``…xu8`` / ``…xbf16``) overrides the untagged f32 bucket for the
    keys it sets, so a uint8 toggle can never silently reuse an f32-tuned
    tile, and a CPU process can never silently treat a TPU pod's
    measurements as its own (or vice versa).

    With ``REPRO_TUNE_REQUIRE_TABLE=1`` a lookup that found neither a
    table entry nor an env override raises — production serving opts in
    to "real measurements only" instead of silently running defaults."""
    params = dict(DEFAULTS.get(op, {}))
    table = load_table()
    buckets = [shape_bucket(shape)]
    tagged = shape_bucket(shape, dtype)
    if tagged != buckets[0]:
        buckets.append(tagged)
    found = False
    for layer in _table_layers(table, device_kind()):
        entries = layer.get(op, {})
        for bucket in buckets:
            entry = entries.get(bucket)
            if entry:
                params.update(_entry_params(entry))
                found = True
    override = _env.tune_override(op)       # malformed override -> ignored
    params.update(override)
    if not found and not override and _env.tune_require_table():
        raise AutotuneError(
            f"REPRO_TUNE_REQUIRE_TABLE is set but no measured table entry "
            f"(device kind {device_kind()!r}, buckets {buckets}) or env "
            f"override exists for op {op!r} — run "
            f"`python -m repro.kernels.tuning --search` on this hardware")
    return params


def validate_table(table: Optional[Dict[str, Any]] = None,
                   path: Optional[Path] = None) -> List[str]:
    """Schema/provenance lint for a persisted table; returns error strings.

    Checks: schema version, known op names, bucket-key grammar, wrapped
    ``{params, provenance}`` entries under ``device_kinds`` with the
    required provenance fields, bare param dicts under ``legacy``."""
    import re
    if table is None:
        table = load_table(path)
    errors: List[str] = []
    if not table:
        return ["table is empty or unreadable"]
    if table.get("schema") != SCHEMA_VERSION:
        return [f"schema={table.get('schema')!r}, expected {SCHEMA_VERSION} "
                "(legacy tables load at runtime but do not validate — "
                "regenerate with `python -m repro.kernels.tuning --search`)"]
    bucket_re = re.compile(r"^\d+(x\d+)*(xu8|xbf16)?$")
    required_prov = ("time_us", "iters", "considered", "skipped", "method")
    kinds = table.get("device_kinds")
    if not isinstance(kinds, dict) or not kinds:
        errors.append("device_kinds section missing or empty")
        kinds = {}
    for kind, ops_map in kinds.items():
        for op, entries in ops_map.items():
            if op not in DEFAULTS:
                errors.append(f"{kind}/{op}: unknown op")
            for bucket, entry in entries.items():
                where = f"{kind}/{op}/{bucket}"
                if not bucket_re.match(bucket):
                    errors.append(f"{where}: malformed bucket key")
                if not isinstance(entry, dict) \
                        or not isinstance(entry.get("params"), dict):
                    errors.append(f"{where}: entry must wrap a params dict")
                    continue
                prov = entry.get("provenance")
                if not isinstance(prov, dict):
                    errors.append(f"{where}: missing provenance")
                    continue
                for field in required_prov:
                    if field not in prov:
                        errors.append(f"{where}: provenance lacks {field!r}")
    for op, entries in table.get("legacy", {}).items():
        if op not in DEFAULTS:
            errors.append(f"legacy/{op}: unknown op")
        for bucket, entry in entries.items():
            if not bucket_re.match(bucket):
                errors.append(f"legacy/{op}/{bucket}: malformed bucket key")
            if not isinstance(entry, dict):
                errors.append(f"legacy/{op}/{bucket}: not a param dict")
    return errors


# ---------------------------------------------------------------------------
# Measurement + search core
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuneStats:
    """Cost ledger for one or more autotune calls (accumulates).

    ``timed_runs`` counts executions inside timing loops (the search's
    cost unit); ``builds`` counts candidate build+warm compiles;
    ``exhaustive_runs`` is the ``len(candidates) × iters`` product the
    exhaustive sweep would have timed over the same calls — the measured
    search's headline claim is ``timed_runs < exhaustive_runs``."""
    builds: int = 0
    timed_runs: int = 0
    rounds: int = 0
    considered: int = 0
    exhaustive_runs: int = 0
    skipped: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record_skip(self, exc: BaseException) -> None:
        name = type(exc).__name__
        self.skipped[name] = self.skipped.get(name, 0) + 1


def _time_callable(fn: Callable[[], Any], iters: int = 3,
                   timer: Callable[[], float] = time.perf_counter,
                   warm: bool = True,
                   stats: Optional[TuneStats] = None) -> float:
    if warm:
        jax.block_until_ready(fn())          # compile + warm
    t0 = timer()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    if stats is not None:
        stats.timed_runs += iters
    return (timer() - t0) / iters


def _provenance(best_t: float, iters: int, considered: int,
                skipped: Dict[str, int], method: str) -> Dict[str, Any]:
    return {"time_us": round(best_t * 1e6, 3), "iters": iters,
            "considered": considered, "skipped": skipped,
            "method": method, "device_kind": device_kind(),
            "measured_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}


def _stats_delta(stats: TuneStats, c0: int, skip0: Dict[str, int]
                 ) -> Tuple[int, Dict[str, int]]:
    """This call's own considered/skipped counts — callers share one
    accumulating :class:`TuneStats` across ops, but each persisted entry's
    provenance must describe only its own sweep."""
    skipped = {k: v - skip0.get(k, 0) for k, v in stats.skipped.items()
               if v - skip0.get(k, 0)}
    return stats.considered - c0, skipped


def _persist_winner(op: str, shape: Iterable[int], dtype,
                    params: Dict[str, Any],
                    provenance: Dict[str, Any]) -> None:
    table = migrate_table(load_table())
    table["device_kinds"].setdefault(device_kind(), {}).setdefault(op, {})[
        shape_bucket(shape, dtype)] = {"params": params,
                                       "provenance": provenance}
    save_table(table)


def _build_pool(op: str, shape, dtype, candidates, build,
                stats: TuneStats) -> List[Tuple[Dict[str, Any], Callable]]:
    """Build + warm every candidate once; callables are reused across
    measurement rungs. All-fail raises instead of letting a caller
    persist DEFAULTS as a measured winner."""
    pool: List[Tuple[Dict[str, Any], Callable]] = []
    for params in candidates:
        stats.considered += 1
        try:
            fn = build(params)
            jax.block_until_ready(fn())      # compile + warm
        except Exception as e:               # non-dividing tile, VMEM OOM...
            stats.record_skip(e)
            continue
        stats.builds += 1
        pool.append((dict(params), fn))
    if not pool:
        raise AutotuneError(
            f"autotune({op!r}, bucket {shape_bucket(shape, dtype)!r}): all "
            f"{stats.considered} candidates failed to build/run "
            f"(skipped by exception type: {stats.skipped}) — refusing to "
            "persist the built-in defaults as a measured winner")
    return pool


def autotune(op: str, shape: Iterable[int],
             candidates: Iterable[Dict[str, Any]],
             build: Callable[[Dict[str, Any]], Callable[[], Any]],
             iters: int = 3, persist: bool = True, dtype=None,
             timer: Callable[[], float] = time.perf_counter,
             stats: Optional[TuneStats] = None) -> Dict[str, Any]:
    """Exhaustive sweep: every candidate timed at full ``iters``.

    Kept as the measured search's baseline (the cost-comparison bench row
    and the same-winner differential test run both); candidates whose
    build or execution raises are skipped *and recorded* in
    ``stats.skipped`` by exception type. If every candidate raises, the
    sweep raises :class:`AutotuneError` — it never persists the built-in
    DEFAULTS as a measured winner. ``dtype`` routes the persisted winner
    into the wire-dtype-tagged bucket (see :func:`shape_bucket`)."""
    stats = stats if stats is not None else TuneStats()
    c0, skip0 = stats.considered, dict(stats.skipped)
    pool = _build_pool(op, shape, dtype, candidates, build, stats)
    stats.exhaustive_runs += len(pool) * iters
    best, best_t = None, float("inf")
    for params, fn in pool:
        try:
            t = _time_callable(fn, iters=iters, timer=timer, warm=False,
                               stats=stats)
        except Exception as e:
            stats.record_skip(e)
            continue
        if t < best_t:
            best, best_t = params, t
    stats.rounds += 1
    if best is None:
        raise AutotuneError(
            f"autotune({op!r}): every candidate raised during timing "
            f"(skipped: {stats.skipped}); not persisting")
    if persist:
        considered, skipped = _stats_delta(stats, c0, skip0)
        _persist_winner(op, shape, dtype, best,
                        _provenance(best_t, iters, considered, skipped,
                                    "exhaustive"))
    return best


def measured_search(op: str, shape: Iterable[int],
                    candidates: Iterable[Dict[str, Any]],
                    build: Callable[[Dict[str, Any]], Callable[[], Any]],
                    iters: int = 3, start_iters: int = 1, eta: int = 3,
                    persist: bool = True, dtype=None,
                    timer: Callable[[], float] = time.perf_counter,
                    stats: Optional[TuneStats] = None) -> Dict[str, Any]:
    """Successive-halving measured search over ``candidates``.

    Rung 0 times the whole population at ``start_iters`` timing
    iterations; each later rung keeps the fastest ``1/eta`` of the
    survivors (never fewer than one) and multiplies the iteration count
    by ``eta``, capped at ``iters``. The search stops at the first rung
    measured at the cap — or as soon as one survivor remains — so its
    total timed runs stay strictly below the exhaustive
    ``len(candidates) × iters`` product whenever ``iters >= 2``: rung r
    costs at most ``N / eta^r × start_iters·eta^r = N·start_iters`` runs
    and there are strictly fewer than ``iters`` rungs.

    On a deterministic timer whose candidate ranking is independent of
    the iteration count, the winner equals the exhaustive sweep's: the
    fastest candidate ranks first at every rung, survives every cut, and
    ties break toward the earlier candidate in both (stable sort here,
    strict ``<`` there). Failures during timing are recorded per
    exception type; an all-fail population raises :class:`AutotuneError`
    and persists nothing."""
    if iters < 1 or start_iters < 1 or eta < 2:
        raise ValueError(f"need iters/start_iters >= 1 and eta >= 2, got "
                         f"iters={iters} start_iters={start_iters} eta={eta}")
    stats = stats if stats is not None else TuneStats()
    c0, skip0 = stats.considered, dict(stats.skipped)
    pool = _build_pool(op, shape, dtype, candidates, build, stats)
    stats.exhaustive_runs += len(pool) * iters
    it = min(start_iters, iters)
    best, best_t = None, float("inf")
    while True:
        scored: List[Tuple[float, Dict[str, Any], Callable]] = []
        for params, fn in pool:
            try:
                t = _time_callable(fn, iters=it, timer=timer, warm=False,
                                   stats=stats)
            except Exception as e:
                stats.record_skip(e)
                continue
            scored.append((t, params, fn))
        stats.rounds += 1
        if not scored:
            raise AutotuneError(
                f"measured_search({op!r}): every surviving candidate raised "
                f"during timing (skipped: {stats.skipped}); not persisting")
        scored.sort(key=lambda s: s[0])      # stable: ties keep seed order
        best_t, best = scored[0][0], scored[0][1]
        if it >= iters or len(scored) == 1:
            break
        keep = max(1, len(scored) // eta)
        pool = [(p, f) for _, p, f in scored[:keep]]
        if len(pool) == 1:                   # decided — skip the re-measure
            break
        it = min(iters, it * eta)
    if persist:
        considered, skipped = _stats_delta(stats, c0, skip0)
        _persist_winner(op, shape, dtype, best,
                        _provenance(best_t, it, considered, skipped,
                                    "successive_halving"))
    return best


def _tune(method: str):
    """Driver dispatch: ``"search"`` (the default measured search) or
    ``"exhaustive"`` (the legacy full sweep, kept as baseline)."""
    if method == "search":
        return measured_search
    if method == "exhaustive":
        return autotune
    raise ValueError(f"method must be 'search' or 'exhaustive', "
                     f"got {method!r}")


def autotune_fused(shapes=((4, 48, 64), (2, 120, 160)),
                   candidates=(1, 2, 4), iters: int = 3, persist: bool = True,
                   algorithms=("dcp", "cap"), topks=(1, 4),
                   depths=(1, 2, 3), io_dtypes=("float32", "uint8"),
                   method: str = "search",
                   stats: Optional[TuneStats] = None) -> Dict[str, Any]:
    """Search ``frames_per_block`` x ``buffer_depth`` for the fused
    megakernels, per algorithm, per A-estimator (argmin vs robust top-k),
    and per frame wire dtype (f32 vs uint8 ingest — different bytes/frame,
    different overlap sweet spot; winners persist into dtype-tagged
    buckets under the current device kind).

    Uses the dispatch layer, so it times whatever substrate the current
    backend resolves to (Pallas on TPU, the XLA oracle on CPU). Each
    (algorithm, estimator) pair persists into its own bucket:
    ``fused_<algorithm>`` for topk=1, ``fused_<algorithm>_topk`` for k>1.
    ``method="search"`` runs :func:`measured_search` per bucket (cost
    strictly below the exhaustive candidates x depths x iters product);
    pass a shared :class:`TuneStats` to read the totals back.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    tune = _tune(method)
    table: Dict[str, Any] = {}
    for algorithm in algorithms:
        for topk in topks:
            op = f"fused_{algorithm}" + ("_topk" if topk > 1 else "")
            table.setdefault(op, {})
            for io_dtype in io_dtypes:
                for b, h, w in shapes:
                    r = np.random.default_rng(0)
                    frames = r.random((b, h, w, 3), np.float32)
                    img = jnp.asarray(ref.quantize_frames(frames, io_dtype))
                    ids = jnp.arange(b, dtype=jnp.int32)
                    A = jnp.ones((3,), jnp.float32)
                    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
                    init = jnp.asarray(False)

                    def build(params):
                        def run():
                            return ops.fused_dehaze(
                                img, ids, A, k0, init, algorithm=algorithm,
                                radius=7, omega=0.95, refine=True,
                                gf_radius=8, gf_eps=1e-3, t0=0.1, gamma=1.0,
                                period=8, lam=0.05, topk=topk,
                                frames_per_block=params["frames_per_block"],
                                buffer_depth=params["buffer_depth"])
                        return run

                    table[op][shape_bucket((b, h, w), img.dtype)] = tune(
                        op, (b, h, w),
                        [{"frames_per_block": f, "buffer_depth": d}
                         for f in candidates for d in depths],
                        build, iters=iters, persist=persist, dtype=img.dtype,
                        stats=stats)
    return table


def autotune_fused_lanes(shapes=((4, 4, 48, 64), (16, 2, 48, 64)),
                         fpb_candidates=(1, 2, 4),
                         orders=("lane_major", "frame_major"),
                         depths=(1, 2, 3),
                         iters: int = 3, persist: bool = True,
                         method: str = "search",
                         stats: Optional[TuneStats] = None) -> Dict[str, Any]:
    """Search the lane-native megakernel's joint grid space:
    ``frames_per_block`` x grid order (lane-major vs frame-major) x DMA
    ``buffer_depth``, per ``(L, B, H, W)`` serving shape, into the
    ``fused_lanes`` bucket of the current device kind's table.

    Uses the dispatch layer, so it times whatever substrate the backend
    resolves to — run on the serving pod to bake in real measurements.
    One lane is all-padding (ids -1), matching a typical partially
    occupied fleet tick.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    tune = _tune(method)
    table: Dict[str, Any] = {"fused_lanes": {}}
    for n_lanes, b, h, w in shapes:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((n_lanes, b, h, w, 3), np.float32))
        ids = jnp.stack(
            [jnp.arange(b, dtype=jnp.int32)] * (n_lanes - 1)
            + [jnp.full((b,), -1, jnp.int32)])
        carry_f = jnp.ones((n_lanes, 3), jnp.float32)
        carry_i = jnp.stack([jnp.full((n_lanes,), -(2 ** 30), jnp.int32),
                             jnp.zeros((n_lanes,), jnp.int32)], axis=-1)

        def build(params):
            def run():
                return ops.fused_dehaze_lanes(
                    img, ids, carry_f, carry_i, algorithm="dcp", radius=7,
                    omega=0.95, refine=True, gf_radius=8, gf_eps=1e-3,
                    t0=0.1, gamma=1.0, period=8, lam=0.05,
                    frames_per_block=params["frames_per_block"],
                    lane_major=(params["grid_order"] == "lane_major"),
                    buffer_depth=params["buffer_depth"])
            return run

        table["fused_lanes"][shape_bucket((n_lanes, b, h, w))] = tune(
            "fused_lanes", (n_lanes, b, h, w),
            [{"frames_per_block": f, "grid_order": o, "buffer_depth": d}
             for f in fpb_candidates for o in orders for d in depths],
            build, iters=iters, persist=persist, stats=stats)
    return table


def autotune_fused_halo(shapes=((4, 24, 64), (2, 60, 160)), halo=23,
                        candidates=(1, 2, 4), depths=(1, 2, 3),
                        iters: int = 3, persist: bool = True,
                        method: str = "search",
                        stats: Optional[TuneStats] = None) -> Dict[str, Any]:
    """Search ``frames_per_block`` x ``buffer_depth`` for the
    spatially-sharded halo megakernel (``fused_halo_2d`` bucket) on
    representative per-shard block shapes."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    tune = _tune(method)
    table: Dict[str, Any] = {"fused_halo_2d": {}}
    for b, h_loc, w in shapes:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((b, h_loc, w, 3), np.float32))
        pre = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
        guide = jnp.asarray(r.random((b, h_loc + 2 * halo, w), np.float32))
        valid = jnp.arange(h_loc + 2 * halo) >= halo      # top-edge shard

        def build(params):
            def run():
                return ops.fused_transmission_halo(
                    img, pre, guide, valid, algorithm="dcp", radius=7,
                    omega=0.95, refine=True, gf_radius=8, gf_eps=1e-3,
                    frames_per_block=params["frames_per_block"],
                    buffer_depth=params["buffer_depth"])
            return run

        table["fused_halo_2d"][shape_bucket((b, h_loc, w))] = tune(
            "fused_halo_2d", (b, h_loc, w),
            [{"frames_per_block": f, "buffer_depth": d}
             for f in candidates for d in depths],
            build, iters=iters, persist=persist, stats=stats)
    return table


# ---------------------------------------------------------------------------
# CLI: generate / validate per-hardware tables
# ---------------------------------------------------------------------------

_SMOKE = dict(shapes=((2, 8, 8),), lanes_shapes=((2, 2, 8, 8),),
              halo_shapes=((2, 8, 16),), halo=3, io_dtypes=("float32",),
              algorithms=("dcp",), topks=(1,), iters=2)


def run_search(smoke: bool = False, iters: Optional[int] = None,
               persist: bool = True, method: str = "search"
               ) -> Tuple[Dict[str, Any], TuneStats]:
    """Run all three drivers; returns (merged winner table, cost stats)."""
    stats = TuneStats()
    kw: Dict[str, Any] = dict(method=method, persist=persist, stats=stats)
    if iters is not None:
        kw["iters"] = iters
    if smoke:
        kw.setdefault("iters", _SMOKE["iters"])
        out = autotune_fused(shapes=_SMOKE["shapes"],
                             algorithms=_SMOKE["algorithms"],
                             topks=_SMOKE["topks"],
                             io_dtypes=_SMOKE["io_dtypes"], **kw)
        out.update(autotune_fused_lanes(shapes=_SMOKE["lanes_shapes"], **kw))
        out.update(autotune_fused_halo(shapes=_SMOKE["halo_shapes"],
                                       halo=_SMOKE["halo"], **kw))
    else:
        out = autotune_fused(**kw)
        out.update(autotune_fused_lanes(**kw))
        out.update(autotune_fused_halo(**kw))
    return out, stats


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Measured-search kernel autotuner: persists winners "
                    "into the device-kind-keyed tuning table")
    ap.add_argument("--search", action="store_true",
                    help="run the successive-halving measured search "
                         "(the default action)")
    ap.add_argument("--exhaustive", action="store_true",
                    help="run the legacy exhaustive sweep instead")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + low iters (CI): also exits nonzero "
                         "unless the search timed strictly fewer runs than "
                         "the exhaustive candidates x iters product")
    ap.add_argument("--iters", type=int, default=None,
                    help="full-fidelity timing iterations (default 3; "
                         "smoke default 2)")
    ap.add_argument("--no-persist", action="store_true",
                    help="measure only; do not write the table")
    ap.add_argument("--validate", action="store_true",
                    help="validate the persisted table's schema/provenance "
                         "and exit")
    ap.add_argument("--require-kind", default=None,
                    help="with --validate: fail unless this device kind "
                         "has measured entries in the table")
    args = ap.parse_args(argv)

    if args.validate:
        table = load_table()
        errors = validate_table(table)
        kinds = sorted(table.get("device_kinds", {}))
        if args.require_kind and args.require_kind not in kinds:
            errors.append(f"required device kind {args.require_kind!r} has "
                          f"no measured entries (kinds present: {kinds})")
        print(json.dumps({"path": str(table_path()), "device_kinds": kinds,
                          "errors": errors}, indent=2))
        return 1 if errors else 0

    method = "exhaustive" if args.exhaustive else "search"
    out, stats = run_search(smoke=args.smoke, iters=args.iters,
                            persist=not args.no_persist, method=method)
    summary = {**out, "path": str(table_path()),
               "device_kind": device_kind(), "method": method,
               "stats": dataclasses.asdict(stats)}
    print(json.dumps(summary, indent=2))
    if args.smoke and method == "search" \
            and stats.timed_runs >= stats.exhaustive_runs:
        print(f"FAIL: measured search timed {stats.timed_runs} runs, not "
              f"fewer than the exhaustive product {stats.exhaustive_runs}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
