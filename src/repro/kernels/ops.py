"""Jitted dispatch wrappers for the dehazing kernels.

Every op has three execution paths selected by ``mode``:
  - ``"ref"``      : pure-jnp oracle (XLA everywhere; default on CPU)
  - ``"pallas"``   : compiled Pallas TPU kernel (default on TPU)
  - ``"interpret"``: Pallas kernel body interpreted on CPU (tests)

Core code calls these and never touches pallas_call directly, so the same
pipeline runs on the CPU CI container and on a real pod unchanged.
"""
from __future__ import annotations

import functools
import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dark_channel import dark_channel_pallas, min_filter_2d_pallas
from repro.kernels.boxfilter import box_filter_2d_pallas
from repro.kernels.recover import recover_pallas
from repro.kernels.atmolight import atmolight_pallas

Mode = Literal["auto", "ref", "pallas", "interpret"]


def resolve_mode(mode: Mode = "auto") -> str:
    if mode != "auto":
        return mode
    env = os.environ.get("REPRO_KERNEL_MODE")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _batched(x: jnp.ndarray, rank: int):
    """Collapse leading dims so kernels always see (B, ...)."""
    lead = x.shape[: x.ndim - rank]
    flat = x.reshape((-1,) + x.shape[x.ndim - rank:])
    return flat, lead


def dark_channel(img: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.dark_channel(img, radius)
    flat, lead = _batched(img, 3)
    out = dark_channel_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def min_filter_2d(x: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.min_filter_2d(x, radius)
    flat, lead = _batched(x, 2)
    out = min_filter_2d_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def box_filter_2d(x: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.box_filter_2d(x, radius)
    flat, lead = _batched(x, 2)
    out = box_filter_2d_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def masked_min_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) with (H,) row-validity — the halo-exchange filter."""
    m = resolve_mode(mode)
    if m == "ref":
        from repro.core import spatial
        return spatial.masked_min_filter_2d(x, valid, radius)
    from repro.kernels.dark_channel import masked_min_filter_2d_pallas
    flat, lead = _batched(x, 2)
    out = masked_min_filter_2d_pallas(flat, valid, radius,
                                      interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def masked_box_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         mode: Mode = "auto") -> jnp.ndarray:
    m = resolve_mode(mode)
    if m == "ref":
        from repro.core import spatial
        return spatial.masked_box_filter_2d(x, valid, radius)
    from repro.kernels.boxfilter import masked_box_filter_2d_pallas
    flat, lead = _batched(x, 2)
    out = masked_box_filter_2d_pallas(flat, valid, radius,
                                      interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def guided_filter(guide: jnp.ndarray, src: jnp.ndarray, radius: int, eps: float,
                  mode: Mode = "auto") -> jnp.ndarray:
    """Guided filter composed from the box-filter op (5 box passes)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.guided_filter(guide, src, radius, eps)
    g = guide.astype(jnp.float32)
    p = src.astype(jnp.float32)
    bf = functools.partial(box_filter_2d, radius=radius, mode=m)
    mean_g = bf(g)
    mean_p = bf(p)
    corr_gp = bf(g * p)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return (bf(a) * g + bf(b)).astype(src.dtype)


def atmospheric_light(img: jnp.ndarray, t_raw: jnp.ndarray, k: int = 1,
                      mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3), (..., H, W) -> (..., 3)."""
    m = resolve_mode(mode)
    if m == "ref" or k > 1:          # top-k (k>1) stays in XLA by design
        return _ref.atmospheric_light(img, t_raw, k)
    flat_i, lead = _batched(img, 3)
    flat_t, _ = _batched(t_raw, 2)
    out = atmolight_pallas(flat_i, flat_t, interpret=(m == "interpret"))
    return out.reshape(lead + (3,))


def recover(img: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray, t0: float = 0.1,
            gamma: float = 1.0, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3), (..., H, W), (..., 3) -> (..., H, W, 3)."""
    m = resolve_mode(mode)
    if m == "ref":
        out = _ref.recover(img, t, A, t0)
        return out ** gamma if gamma != 1.0 else out
    flat_i, lead = _batched(img, 3)
    flat_t, _ = _batched(t, 2)
    flat_a = A.reshape(-1, 3)
    out = recover_pallas(flat_i, flat_t, flat_a, t0=t0, gamma=gamma,
                         interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def cap_depth(img: jnp.ndarray, w0: float, w1: float, w2: float) -> jnp.ndarray:
    """CAP linear depth model — pure elementwise, XLA fuses it optimally."""
    return _ref.cap_depth(img, w0, w1, w2)
