"""Jitted dispatch wrappers for the dehazing kernels.

Every op has three execution paths selected by ``mode``:
  - ``"ref"``      : pure-jnp oracle (XLA everywhere; default on CPU)
  - ``"pallas"``   : compiled Pallas TPU kernel (default on TPU)
  - ``"interpret"``: Pallas kernel body interpreted on CPU (tests)

``"fused"`` is a fourth, *pipeline-level* mode: instead of one launch per
stage, the whole DCP/CAP chain runs as the single-pass megakernel in
``kernels.fused`` (see ``fused_dehaze`` below). Its execution substrate
is still resolved to ref/pallas/interpret per backend/env, so the fused
path also runs on the CPU CI container.

Core code calls these and never touches pallas_call directly, so the same
pipeline runs on the CPU CI container and on a real pod unchanged.
"""
from __future__ import annotations

import functools
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core import env as _env
from repro.kernels import ref as _ref
from repro.kernels import tuning
from repro.kernels.dark_channel import dark_channel_pallas, min_filter_2d_pallas
from repro.kernels.boxfilter import box_filter_2d_pallas
from repro.kernels.recover import recover_pallas
from repro.kernels.atmolight import (atmolight_pallas, atmolight_topk_pallas,
                                     merge_topk_pallas)
from repro.kernels.fused import (fused_dehaze_lanes_pallas,
                                 fused_dehaze_pallas,
                                 fused_transmission_halo_pallas,
                                 fused_transmission_lanes_pallas,
                                 fused_transmission_pallas)
from repro.kernels.ref import CAP_COEFFS

Mode = Literal["auto", "ref", "pallas", "interpret", "fused"]

SUBSTRATES = _env.SUBSTRATES
MODES = _env.KERNEL_MODES


def resolve_mode(mode: Mode = "auto") -> str:
    """Resolve to an execution substrate: ref | pallas | interpret.

    ``"fused"`` is a pipeline-level mode (it selects *which* ops run, not
    *how*); here it resolves like "auto": env ``REPRO_KERNEL_MODE`` if it
    names a substrate, else Pallas on TPU and the XLA oracle elsewhere.

    Unknown values — in the argument or in ``REPRO_KERNEL_MODE`` — raise
    ``ValueError`` (validation lives in ``core.env.kernel_mode``). They
    used to fall straight through every dispatch wrapper's ``m == "ref"``
    check into the compiled-Pallas branch, so a typo like
    ``REPRO_KERNEL_MODE=Pallas`` silently ran compiled kernels.
    """
    if mode not in MODES:
        raise ValueError(
            f"unknown kernel mode {mode!r}; expected one of {sorted(MODES)}")
    env = _env.kernel_mode()
    default = "pallas" if jax.default_backend() == "tpu" else "ref"
    if env == "auto":                    # explicit "auto" == unset
        env = ""
    m = mode
    if m == "auto":
        m = env or default
    if m == "fused":
        m = env if env in SUBSTRATES else default
    return m


# Alias used by the fused ops, where the distinction matters for readers.
resolve_substrate = resolve_mode


def _batched(x: jnp.ndarray, rank: int):
    """Collapse leading dims so kernels always see (B, ...)."""
    lead = x.shape[: x.ndim - rank]
    flat = x.reshape((-1,) + x.shape[x.ndim - rank:])
    return flat, lead


def dark_channel(img: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.dark_channel(img, radius)
    flat, lead = _batched(img, 3)
    out = dark_channel_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def min_filter_2d(x: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.min_filter_2d(x, radius)
    flat, lead = _batched(x, 2)
    out = min_filter_2d_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def box_filter_2d(x: jnp.ndarray, radius: int, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) -> (..., H, W)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.box_filter_2d(x, radius)
    flat, lead = _batched(x, 2)
    out = box_filter_2d_pallas(flat, radius, interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def masked_min_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         valid_w: jnp.ndarray = None,
                         mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W) with (H,) row-validity (and optional (W,) column
    validity, the W-sharded halo path) — the halo-exchange filter."""
    m = resolve_mode(mode)
    if m == "ref":
        from repro.core import spatial
        return spatial.masked_min_filter_2d(x, valid, radius, valid_w)
    from repro.kernels.dark_channel import masked_min_filter_2d_pallas
    flat, lead = _batched(x, 2)
    out = masked_min_filter_2d_pallas(flat, valid, radius, valid_w,
                                      interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def masked_box_filter_2d(x: jnp.ndarray, valid: jnp.ndarray, radius: int,
                         valid_w: jnp.ndarray = None,
                         mode: Mode = "auto") -> jnp.ndarray:
    m = resolve_mode(mode)
    if m == "ref":
        from repro.core import spatial
        return spatial.masked_box_filter_2d(x, valid, radius, valid_w)
    from repro.kernels.boxfilter import masked_box_filter_2d_pallas
    flat, lead = _batched(x, 2)
    out = masked_box_filter_2d_pallas(flat, valid, radius, valid_w,
                                      interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def guided_filter(guide: jnp.ndarray, src: jnp.ndarray, radius: int, eps: float,
                  mode: Mode = "auto") -> jnp.ndarray:
    """Guided filter composed from the box-filter op (5 box passes)."""
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.guided_filter(guide, src, radius, eps)
    g = guide.astype(jnp.float32)
    p = src.astype(jnp.float32)
    bf = functools.partial(box_filter_2d, radius=radius, mode=m)
    mean_g = bf(g)
    mean_p = bf(p)
    corr_gp = bf(g * p)
    corr_gg = bf(g * g)
    var_g = corr_gg - mean_g * mean_g
    cov_gp = corr_gp - mean_g * mean_p
    a = cov_gp / (var_g + eps)
    b = mean_p - a * mean_g
    return (bf(a) * g + bf(b)).astype(src.dtype)


def atmospheric_light(img: jnp.ndarray, t_raw: jnp.ndarray, k: int = 1,
                      mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3), (..., H, W) -> (..., 3).

    k=1 is the Eq. 6 argmin-t reduction; k>1 the robust mean-of-top-k
    (``atmolight_topk_pallas``, an in-VMEM k-row running selection). Both
    match ``kernels.ref.atmospheric_light`` including tie-breaking.
    """
    m = resolve_mode(mode)
    if m == "ref":
        return _ref.atmospheric_light(img, t_raw, k)
    flat_i, lead = _batched(img, 3)
    flat_t, _ = _batched(t_raw, 2)
    if k > 1:
        tile_h = int(tuning.get_params(
            "atmolight_topk", flat_t.shape).get("tile_h", 0))
        out = atmolight_topk_pallas(flat_i, flat_t, k, tile_h=tile_h,
                                    interpret=(m == "interpret"))
    else:
        tile_h = int(tuning.get_params(
            "atmolight", flat_t.shape).get("tile_h", 0))
        out = atmolight_pallas(flat_i, flat_t, tile_h=tile_h,
                               interpret=(m == "interpret"))
    return out.reshape(lead + (3,))


def merge_topk_candidates(tk_t: jnp.ndarray, tk_idx: jnp.ndarray,
                          tk_rgb: jnp.ndarray, k: int,
                          mode: Mode = "auto") -> jnp.ndarray:
    """(B, M) t + global-index lists, (B, M, 3) rgb -> (B, 3) mean of the
    k lexicographically smallest (t, index) rows.

    The sharded pipeline's cross-shard candidate merge: after the
    all-gather, M = n_shards * k rows per frame. ``ref`` is the two-key
    ``lax.sort`` (t, then global flat index — reproducing ``lax.top_k``'s
    lowest-index tie-break across shard boundaries); pallas/interpret fold
    the list through a sequential grid carry (``merge_topk_pallas``) in
    k-row segments, bit-identical by the shared tie-break rule.
    """
    tk_t = tk_t.astype(jnp.float32)
    tk_rgb = tk_rgb.astype(jnp.float32)
    m = resolve_mode(mode)
    if m == "ref":
        _, _, r_s, g_s, b_s = jax.lax.sort(
            (tk_t, tk_idx, tk_rgb[..., 0], tk_rgb[..., 1], tk_rgb[..., 2]),
            dimension=1, num_keys=2)
        top = jnp.stack([r_s[:, :k], g_s[:, :k], b_s[:, :k]], axis=-1)
        return top.mean(axis=1)
    return merge_topk_pallas(tk_t, tk_idx, tk_rgb, k,
                             interpret=(m == "interpret"))


def recover(img: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray, t0: float = 0.1,
            gamma: float = 1.0, mode: Mode = "auto") -> jnp.ndarray:
    """(..., H, W, 3), (..., H, W), (..., 3) -> (..., H, W, 3)."""
    m = resolve_mode(mode)
    if m == "ref":
        out = _ref.recover(img, t, A, t0)
        return out ** gamma if gamma != 1.0 else out
    flat_i, lead = _batched(img, 3)
    flat_t, _ = _batched(t, 2)
    flat_a = A.reshape(-1, 3)
    out = recover_pallas(flat_i, flat_t, flat_a, t0=t0, gamma=gamma,
                         interpret=(m == "interpret"))
    return out.reshape(lead + out.shape[1:])


def cap_depth(img: jnp.ndarray, w0: float, w1: float, w2: float) -> jnp.ndarray:
    """CAP linear depth model — pure elementwise, XLA fuses it optimally."""
    return _ref.cap_depth(img, w0, w1, w2)


# ---------------------------------------------------------------------------
# Fused single-pass megakernels (kernels.fused) — algorithm-parametric
# ---------------------------------------------------------------------------

def fused_dehaze(img: jnp.ndarray, frame_ids: jnp.ndarray,
                 A_saved: jnp.ndarray, last_update: jnp.ndarray,
                 initialized: jnp.ndarray, *, algorithm: str = "dcp",
                 radius: int, omega: float = 0.95, beta: float = 1.0,
                 cap_w: Tuple[float, float, float] = CAP_COEFFS,
                 refine: bool, gf_radius: int, gf_eps: float, t0: float,
                 gamma: float, period: int, lam: float, topk: int = 1,
                 frames_per_block: int = 0, out_dtype: str = "auto",
                 buffer_depth: int = 0,
                 mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Whole DCP/CAP chain in one launch: (..., H, W, 3) -> (J, t, a_seq, A, k).

    ``topk`` selects the atmospheric-light candidate estimator: 1 is the
    Eq. 6 argmin-t pixel, >1 the robust in-VMEM mean-of-top-k.
    ``frames_per_block <= 0`` resolves the tile from the tuning registry's
    per-algorithm bucket (env ``REPRO_TUNE_FUSED_DCP`` /
    ``REPRO_TUNE_FUSED_CAP`` > the *current device kind's* measured entry
    in ``results/kernel_tuning.json`` > legacy device-untagged entry > 1 —
    see ``kernels.tuning.get_params``); the top-k selection changes the
    kernel's VMEM/compute profile, so ``topk > 1`` resolves from its own
    ``fused_<algorithm>_topk`` bucket.

    ``img`` may be any wire dtype (f32/bf16/uint8 — the canonical
    ``ref.upcast_frames`` ingest; non-f32 streams resolve dtype-tagged
    tuning buckets). ``out_dtype`` picks the J/t output dtype ("auto":
    follow float ingest, f32 for uint8). ``buffer_depth <= 0`` resolves
    the double-buffered DMA ring depth from the bucket; the interpret
    substrate falls back to the classic single-buffered body (depth 1)
    unless an explicit depth is requested — that is the interpret-safe
    fallback, while tests pass ``buffer_depth >= 2`` to execute the
    manual-DMA body itself under interpret.
    """
    m = resolve_substrate(mode)
    flat, lead = _batched(img, 3)
    flat_ids = frame_ids.reshape(-1)
    if m == "ref":
        j, t, a_seq, a_fin, k_fin = _ref.fused_dehaze(
            flat, flat_ids, A_saved, last_update, initialized,
            algorithm=algorithm, radius=radius, omega=omega, beta=beta,
            cap_w=cap_w, refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
            t0=t0, gamma=gamma, period=period, lam=lam, topk=topk,
            out_dtype=out_dtype)
    else:
        op = f"fused_{algorithm}" + ("_topk" if topk > 1 else "")
        params = tuning.get_params(op, flat.shape[:3], dtype=flat.dtype)
        if frames_per_block <= 0:
            frames_per_block = int(params.get("frames_per_block", 1))
        if buffer_depth <= 0:
            buffer_depth = 1 if m == "interpret" \
                else int(params.get("buffer_depth", 1))
        j, t, a_seq, a_fin, k_fin = fused_dehaze_pallas(
            flat, flat_ids, A_saved, last_update, initialized,
            algorithm=algorithm, radius=radius, omega=omega, beta=beta,
            cap_w=tuple(cap_w), refine=refine, gf_radius=gf_radius,
            gf_eps=gf_eps, t0=t0, gamma=gamma, period=period, lam=lam,
            topk=topk, frames_per_block=frames_per_block,
            out_dtype=out_dtype, buffer_depth=buffer_depth,
            interpret=(m == "interpret"))
    return (j.reshape(lead + j.shape[1:]), t.reshape(lead + t.shape[1:]),
            a_seq.reshape(lead + (3,)), a_fin, k_fin)


def fused_dehaze_lanes(img: jnp.ndarray, frame_ids: jnp.ndarray,
                       carry_f: jnp.ndarray, carry_i: jnp.ndarray, *,
                       algorithm: str = "dcp", radius: int,
                       omega: float = 0.95, beta: float = 1.0,
                       cap_w: Tuple[float, float, float] = CAP_COEFFS,
                       refine: bool, gf_radius: int, gf_eps: float, t0: float,
                       gamma: float, period: int, lam: float, topk: int = 1,
                       frames_per_block: int = 0, lane_major=None,
                       out_dtype: str = "auto", buffer_depth: int = 0,
                       mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Lane-native fused dehaze: L streams, one launch.

    img: (L, B, H, W, 3); frame_ids: (L, B); carry_f (L, 3) f32 /
    carry_i (L, 2) int32 are the lane-packed EMA carry rows
    (``core.normalize.lane_carry``). Returns ``(J, t, a_seq (L, B, 3),
    carry_f', carry_i')`` — per lane identical to :func:`fused_dehaze` on
    that lane alone, padding lanes (all ids < 0) untouched.

    ``frames_per_block <= 0`` and ``lane_major=None`` resolve from the
    ``fused_lanes`` tuning bucket (env ``REPRO_TUNE_FUSED_LANES`` >
    device-kind-keyed measured table > lane-major, 1 frame per block —
    run ``python -m repro.kernels.tuning --search`` on the serving pod to
    bake real measurements); the bucket's shape
    key includes the lane count, so the lane-major-vs-frame-major grid
    order and the ``frames_per_block`` x L tile sweep are tuned per
    serving shape. ``out_dtype``/``buffer_depth`` follow the
    :func:`fused_dehaze` dtype/DMA contract (non-f32 wire dtypes resolve
    dtype-tagged buckets; interpret falls back to depth 1 unless an
    explicit depth is passed).
    """
    assert img.ndim == 5, img.shape
    n_lanes, b = img.shape[0], img.shape[1]
    assert frame_ids.shape == (n_lanes, b), frame_ids.shape
    m = resolve_substrate(mode)
    if m == "ref":
        def one_lane(im, ids, cf, ci):
            j, t, a_seq, a_fin, k_fin = _ref.fused_dehaze(
                im, ids, cf, ci[0], ci[1].astype(bool), algorithm=algorithm,
                radius=radius, omega=omega, beta=beta, cap_w=cap_w,
                refine=refine, gf_radius=gf_radius, gf_eps=gf_eps, t0=t0,
                gamma=gamma, period=period, lam=lam, topk=topk,
                out_dtype=out_dtype)
            inited = jnp.maximum(ci[1], jnp.any(ids >= 0).astype(ci.dtype))
            return j, t, a_seq, a_fin, jnp.stack([k_fin, inited])
        return jax.vmap(one_lane)(img, frame_ids, carry_f, carry_i)
    params = tuning.get_params("fused_lanes", img.shape[:4], dtype=img.dtype)
    if frames_per_block <= 0:
        frames_per_block = int(params.get("frames_per_block", 1))
    if lane_major is None:
        lane_major = str(params.get("grid_order", "lane_major")) \
            != "frame_major"
    if buffer_depth <= 0:
        buffer_depth = 1 if m == "interpret" \
            else int(params.get("buffer_depth", 1))
    return fused_dehaze_lanes_pallas(
        img, frame_ids, carry_f, carry_i, algorithm=algorithm, radius=radius,
        omega=omega, beta=beta, cap_w=tuple(cap_w), refine=refine,
        gf_radius=gf_radius, gf_eps=gf_eps, t0=t0, gamma=gamma, period=period,
        lam=lam, topk=topk, frames_per_block=frames_per_block,
        lane_major=bool(lane_major), out_dtype=out_dtype,
        buffer_depth=buffer_depth, interpret=(m == "interpret"))


def fused_transmission(img: jnp.ndarray, A_saved: jnp.ndarray, *,
                       algorithm: str = "dcp", radius: int,
                       omega: float = 0.95, beta: float = 1.0,
                       cap_w: Tuple[float, float, float] = CAP_COEFFS,
                       refine: bool, gf_radius: int, gf_eps: float,
                       topk: int = 1, out_dtype: str = "auto",
                       mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Fused t-map + A candidates (the batch-sharded-step stage):
    (..., H, W, 3) -> (t, t_min (...,), cand_rgb (..., 3)). The candidate
    is the argmin-t pixel for ``topk == 1``, the mean of the ``topk``
    smallest-t pixels otherwise (each frame is whole on its shard, so the
    mean needs no cross-shard merge). ``img`` may be any wire dtype; t and
    the candidate RGB are cast per ``out_dtype`` (see
    :func:`fused_dehaze`)."""
    m = resolve_substrate(mode)
    flat, lead = _batched(img, 3)
    if m == "ref":
        t, t_min, cand = _ref.fused_transmission(
            flat, A_saved, algorithm=algorithm, radius=radius, omega=omega,
            beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
            gf_eps=gf_eps, topk=topk, out_dtype=out_dtype)
    else:
        t, t_min, cand = fused_transmission_pallas(
            flat, A_saved, algorithm=algorithm, radius=radius, omega=omega,
            beta=beta, cap_w=tuple(cap_w), refine=refine, gf_radius=gf_radius,
            gf_eps=gf_eps, topk=topk, out_dtype=out_dtype,
            interpret=(m == "interpret"))
    return (t.reshape(lead + t.shape[1:]), t_min.reshape(lead),
            cand.reshape(lead + (3,)))


def fused_transmission_lanes(img: jnp.ndarray, A_saved: jnp.ndarray, *,
                             algorithm: str = "dcp", radius: int,
                             omega: float = 0.95, beta: float = 1.0,
                             cap_w: Tuple[float, float, float] = CAP_COEFFS,
                             refine: bool, gf_radius: int, gf_eps: float,
                             topk: int = 1, out_dtype: str = "auto",
                             mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Lane-native fused t-map stage: (L, B, H, W, 3) + per-lane saved A
    (L, 3) -> (t (L, B, H, W), t_min (L, B), cand_rgb (L, B, 3)).

    The lane-batched form of :func:`fused_transmission` — each lane's DCP
    pre-map divides by its own coherent A, and all L lanes ride one
    launch. The stage is stateless across frames, so there is no carry to
    fold; the per-lane A input is what distinguishes this from reshaping
    the lane axis into the batch."""
    assert img.ndim == 5, img.shape
    n_lanes = img.shape[0]
    assert A_saved.shape == (n_lanes, 3), A_saved.shape
    m = resolve_substrate(mode)
    if m == "ref":
        def one_lane(im, a):
            return _ref.fused_transmission(
                im, a, algorithm=algorithm, radius=radius, omega=omega,
                beta=beta, cap_w=cap_w, refine=refine, gf_radius=gf_radius,
                gf_eps=gf_eps, topk=topk, out_dtype=out_dtype)
        return jax.vmap(one_lane)(img, A_saved)
    return fused_transmission_lanes_pallas(
        img, A_saved, algorithm=algorithm, radius=radius, omega=omega,
        beta=beta, cap_w=tuple(cap_w), refine=refine, gf_radius=gf_radius,
        gf_eps=gf_eps, topk=topk, out_dtype=out_dtype,
        interpret=(m == "interpret"))


def fused_transmission_halo(img: jnp.ndarray, pre_ext: jnp.ndarray,
                            guide_ext: jnp.ndarray, valid: jnp.ndarray,
                            valid_w: jnp.ndarray = None, *,
                            algorithm: str = "dcp", radius: int,
                            omega: float = 0.95, beta: float = 1.0,
                            refine: bool, gf_radius: int, gf_eps: float,
                            topk: int = 1, frames_per_block: int = 0,
                            out_dtype: str = "auto", buffer_depth: int = 0,
                            mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Halo-aware fused t-map stage for the spatially-sharded pipeline.

    img: (..., H_loc, W_loc, 3) core block; pre_ext/guide_ext:
    (..., H_ext, W_ext) halo-extended planes from the ``core.spatial`` halo
    exchanges; valid: (H_ext,) row-validity mask; valid_w: optional (W_ext,)
    column-validity mask (None = no W sharding). Returns ``(t, tk_t
    (..., k), tk_rgb (..., k, 3), tk_idx (..., k))`` — the shard-local
    top-k smallest-t candidates ascending in (t, local flat index), ready
    for the cross-shard lexicographic merge in ``core.pipeline``. The
    masked min/box filters run in-VMEM on the Pallas substrates and through
    ``core.spatial`` on the XLA oracle. ``frames_per_block <= 0`` and
    ``buffer_depth <= 0`` resolve from the ``fused_halo_2d`` tuning bucket
    (Pallas substrates only; the resolved buffer depth is clamped to 1 on
    the interpret substrate, where manual DMA brings no overlap — pass an
    explicit ``buffer_depth >= 2`` to force the double-buffered body).
    ``img`` may be uint8/bfloat16 wire frames (upcast in-VMEM); t/tk_rgb
    are cast per ``out_dtype``.
    """
    m = resolve_substrate(mode)
    flat, lead = _batched(img, 3)
    flat_pre, _ = _batched(pre_ext, 2)
    flat_guide, _ = _batched(guide_ext, 2)
    if m == "ref":
        t, tk_t, tk_rgb, tk_idx = _ref.fused_transmission_halo(
            flat, flat_pre, flat_guide, valid, valid_w, algorithm=algorithm,
            radius=radius, omega=omega, beta=beta, refine=refine,
            gf_radius=gf_radius, gf_eps=gf_eps, topk=topk,
            out_dtype=out_dtype)
    else:
        params = tuning.get_params("fused_halo_2d", flat.shape[:3],
                                   dtype=flat.dtype)
        if frames_per_block <= 0:
            frames_per_block = int(params.get("frames_per_block", 1))
        if buffer_depth <= 0:
            buffer_depth = 1 if m == "interpret" \
                else int(params.get("buffer_depth", 1))
        t, tk_t, tk_rgb, tk_idx = fused_transmission_halo_pallas(
            flat, flat_pre, flat_guide, valid, valid_w, algorithm=algorithm,
            radius=radius, omega=omega, beta=beta, refine=refine,
            gf_radius=gf_radius, gf_eps=gf_eps, topk=topk,
            frames_per_block=frames_per_block, out_dtype=out_dtype,
            buffer_depth=buffer_depth, interpret=(m == "interpret"))
    return (t.reshape(lead + t.shape[1:]), tk_t.reshape(lead + (topk,)),
            tk_rgb.reshape(lead + (topk, 3)), tk_idx.reshape(lead + (topk,)))


def fused_dehaze_dcp(img: jnp.ndarray, frame_ids: jnp.ndarray,
                     A_saved: jnp.ndarray, last_update: jnp.ndarray,
                     initialized: jnp.ndarray, *, radius: int, omega: float,
                     refine: bool, gf_radius: int, gf_eps: float, t0: float,
                     gamma: float, period: int, lam: float,
                     frames_per_block: int = 0,
                     mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Back-compat DCP-only entry point (PR 1 name) -> ``fused_dehaze``."""
    return fused_dehaze(img, frame_ids, A_saved, last_update, initialized,
                        algorithm="dcp", radius=radius, omega=omega,
                        refine=refine, gf_radius=gf_radius, gf_eps=gf_eps,
                        t0=t0, gamma=gamma, period=period, lam=lam,
                        frames_per_block=frames_per_block, mode=mode)


def fused_transmission_dcp(img: jnp.ndarray, A_saved: jnp.ndarray, *,
                           radius: int, omega: float, refine: bool,
                           gf_radius: int, gf_eps: float,
                           mode: Mode = "auto") -> Tuple[jnp.ndarray, ...]:
    """Back-compat DCP-only entry point (PR 1 name) -> ``fused_transmission``."""
    return fused_transmission(img, A_saved, algorithm="dcp", radius=radius,
                              omega=omega, refine=refine,
                              gf_radius=gf_radius, gf_eps=gf_eps, mode=mode)


# ---------------------------------------------------------------------------
# Introspection: pallas_call launches in a traced program
# ---------------------------------------------------------------------------

def _iter_jaxprs(val):
    from jax import core
    if isinstance(val, core.Jaxpr):
        yield val
    elif isinstance(val, core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _iter_jaxprs(v)


def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                n += _count_pallas(sub)
    return n


def pallas_launch_count(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations in ``fn``'s traced jaxpr
    (recursing into nested call/scan/cond jaxprs).

    This is the per-tick launch count the lane-native refactor optimizes:
    dispatching L streams through per-lane kernel calls traces L
    ``pallas_call``s, the lane-native kernel exactly one. Used by the
    ``kernels/fused_lanes_*`` bench rows and the launch-count regression
    test."""
    return _count_pallas(jax.make_jaxpr(fn)(*args, **kwargs).jaxpr)


def _count_prim(jaxpr, name: str) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                n += _count_prim(sub, name)
    return n


def dma_copy_count(fn, *args, **kwargs) -> dict:
    """Count manual-DMA equations in ``fn``'s traced program, recursing
    into every nested jaxpr (including pallas_call kernel bodies).

    Returns ``{"starts": n, "waits": m}``. The double-buffered megakernel
    bodies trace two ``dma_start``s (warm-up + prefetch) and one
    ``dma_wait`` per input plane; the classic single-buffered bodies trace
    zero of each. Used by the ``kernels/fused_dbuf`` bench row and the
    overlap-structure regression test to assert the copy/compute overlap
    is actually in the lowered program, independent of wall-clock."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs).jaxpr
    return {"starts": _count_prim(jaxpr, "dma_start"),
            "waits": _count_prim(jaxpr, "dma_wait")}
