"""Pallas TPU kernel: fused haze-free recovery (paper Eq. 8).

Fuses the transmission clamp, the per-channel (I - A)/t + A restore and the
[0, 1] clip into a single VMEM pass — one read of (I, t), one write of J.
XLA would fuse this too; the kernel exists because on TPU we additionally
fold in the per-frame atmospheric light broadcast from SMEM-resident
scalars, avoiding a materialized (B, H, W, 3) broadcast of A, and it gives
us a place to attach the epilogue (gamma / tone curve) used by the serving
path without re-reading HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _recover_kernel(img_ref, t_ref, a_ref, out_ref, *, t0: float, gamma: float):
    img = img_ref[0].astype(jnp.float32)           # (H, W, 3)
    t = t_ref[0].astype(jnp.float32)               # (H, W)
    A = a_ref[0].astype(jnp.float32)               # (3,)
    tt = jnp.maximum(t, t0)[..., None]
    out = jnp.clip((img - A) / tt + A, 0.0, 1.0)
    if gamma != 1.0:
        out = out ** gamma
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t0", "gamma", "interpret"))
def recover_pallas(img: jnp.ndarray, t: jnp.ndarray, A: jnp.ndarray,
                   t0: float = 0.1, gamma: float = 1.0,
                   interpret: bool = False) -> jnp.ndarray:
    """(B,H,W,3), (B,H,W), (B,3) -> (B,H,W,3) recovered radiance."""
    b, h, w, c = img.shape
    assert c == 3 and t.shape == (b, h, w) and A.shape == (b, 3)
    kernel = functools.partial(_recover_kernel, t0=t0, gamma=gamma)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, 3), img.dtype),
        interpret=interpret,
    )(img, t, A)
