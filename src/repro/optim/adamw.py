"""AdamW with decoupled weight decay, global-norm clipping, schedules.

Implemented directly over pytrees (optax is not available in this
environment, and a framework should own its optimizer step anyway: the
update is where gradient-compression / distributed-overlap tricks hook in).

Distributed notes: moments inherit the parameter sharding (first/second
moment carry the same PartitionSpec as their parameter), so pjit shards
optimizer state for free — ZeRO-1-style sharding then comes from assigning
data-axis specs to the moments in the train-step wrapper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray   # () int32
    mu: Any             # first moment, same structure as params
    nu: Any             # second moment


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 lr: jnp.ndarray | float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 decay_mask: Optional[Callable[[str], bool]] = None
                 ) -> Tuple[Any, AdamWState]:
    """One AdamW step. Returns (new_params, new_state).

    ``decay_mask(path)`` — True to apply weight decay to that leaf (default:
    decay everything with ndim >= 2, the usual no-decay-on-bias/norm rule).
    """
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if decay_mask is not None:
            do_decay = decay_mask(jax.tree_util.keystr(path))
        else:
            do_decay = p.ndim >= 2
        if do_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    gs = jax.tree.leaves(grads)
    ms = jax.tree.leaves(state.mu)
    vs = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat, gs, ms, vs):
        np_, nm, nv = upd(path, p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            AdamWState(step=step,
                       mu=jax.tree_util.tree_unflatten(treedef, new_m),
                       nu=jax.tree_util.tree_unflatten(treedef, new_v)))


def cosine_schedule(base_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
