"""Generic train/serve step builders shared by all architectures."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)


def make_train_step(loss_fn: Callable, lr_schedule: Callable,
                    grad_clip: float = 1.0, has_bn: bool = False,
                    weight_decay: float = 0.1, microbatches: int = 1,
                    accum_shardings=None):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    BN-carrying models return their refreshed running stats in
    ``metrics["bn_params"]``; those leaves overwrite the optimizer's output
    (they receive zero gradient, so this is the only path that moves them).

    ``microbatches > 1`` = gradient accumulation via lax.scan: the global
    batch splits along its leading dim, activations scale down by the
    factor, gradients accumulate in f32 (sharded like the params, so the
    extra state is params/|mesh| bytes per device).
    """

    def apply_update(params, opt_state, grads, metrics, loss):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(opt_state.step)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr,
                                           weight_decay=weight_decay)
        if has_bn:
            new_params = cm.merge_bn_stats(new_params,
                                           metrics.pop("bn_params"))
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, out_metrics

    if microbatches <= 1:
        def train_step(params, opt_state: AdamWState, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return apply_update(params, opt_state, grads, metrics, loss)
        return train_step

    assert not has_bn, "microbatching + BN stat merge not supported"

    def train_step(params, opt_state: AdamWState, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if accum_shardings is not None:
            # ZeRO-style sharding for the f32 accumulator (same specs as
            # the optimizer moments) — without it the accumulator is the
            # per-device memory floor for large models.
            constrain = lambda t: jax.tree.map(
                jax.lax.with_sharding_constraint, t, accum_shardings)
        else:
            constrain = lambda t: t
        g0 = constrain(g0)

        def acc(carry, mbatch):
            gsum, loss_sum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            gsum = constrain(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, loss_sum + loss), metrics

        (gsum, loss_sum), metrics = jax.lax.scan(acc, (g0, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return apply_update(params, opt_state, grads, metrics,
                            loss_sum / microbatches)

    return train_step
