"""Architecture zoo (pure-JAX param-pytree models)."""
from repro.models import (common, convnext, dit, efficientnet, resnet, steps,
                          transformer, unet, vit)

__all__ = ["common", "transformer", "dit", "unet", "vit", "resnet",
           "efficientnet", "convnext", "steps"]
