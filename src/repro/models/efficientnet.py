"""EfficientNet (Tan & Le, arXiv:1905.11946) — efficientnet-b7
(width_mult 2.0, depth_mult 3.1, img 600).

MBConv blocks (expand → depthwise → squeeze-excite → project) with
BatchNorm + swish. Stage tails (identical repeat blocks) run under
lax.scan with stacked params; running BN stats merge back via
``common.merge_bn_stats``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ParamSpec

# B0 stage spec: (expand, channels, repeats, stride, kernel)
_B0_STAGES = [
    (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3), (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3),
]


def _round_ch(ch: float, divisor: int = 8) -> int:
    new = max(divisor, int(ch + divisor / 2) // divisor * divisor)
    if new < 0.9 * ch:
        new += divisor
    return new


@dataclasses.dataclass(frozen=True)
class EfficientNetConfig:
    name: str = "efficientnet"
    img_res: int = 600
    width_mult: float = 2.0
    depth_mult: float = 3.1
    n_classes: int = 1000
    se_ratio: float = 0.25
    dtype: str = "float32"
    remat: bool = True      # checkpoint each MBConv (B7 @600px activations
    #                         otherwise exceed v5e HBM — EXPERIMENTS §Roofline)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def stages(self) -> Tuple[Tuple[int, int, int, int, int], ...]:
        out = []
        for e, c, r, s, k in _B0_STAGES:
            out.append((e, _round_ch(c * self.width_mult),
                        math.ceil(r * self.depth_mult), s, k))
        return tuple(out)

    @property
    def stem_ch(self) -> int:
        return _round_ch(32 * self.width_mult)

    @property
    def head_ch(self) -> int:
        return _round_ch(1280 * self.width_mult)


def _mbconv_table(cin, cout, expand, kernel, dt, n=None):
    lead = (n,) if n else ()
    la = ("layers",) if n else ()
    mid = cin * expand
    se = max(1, int(cin * 0.25))

    def conv(k, ci, co, groups=1):
        return ParamSpec(lead + (k, k, ci // groups, co),
                         la + (None, None, None, "conv_out"), dt)

    def bn(c):
        return {key: ParamSpec(lead + v.shape, la + v.axes, v.dtype, v.init)
                for key, v in cm.bn_table(c, dt).items()}

    t: Dict[str, Any] = {}
    if expand != 1:
        t["expand"] = conv(1, cin, mid)
        t["bn_e"] = bn(mid)
    t["dw"] = ParamSpec(lead + (kernel, kernel, 1, mid),
                        la + (None, None, None, "conv_out"), dt)
    t["bn_dw"] = bn(mid)
    t["se_reduce"] = conv(1, mid, se)
    t["se_reduce_b"] = ParamSpec(lead + (se,), la + ("conv_out",), dt, init="zeros")
    t["se_expand"] = conv(1, se, mid)
    t["se_expand_b"] = ParamSpec(lead + (mid,), la + ("conv_out",), dt, init="zeros")
    t["project"] = conv(1, mid, cout)
    t["bn_p"] = bn(cout)
    return t


def efficientnet_param_table(c: EfficientNetConfig) -> Dict[str, Any]:
    dt = c.jdtype
    t: Dict[str, Any] = {
        "stem": ParamSpec((3, 3, 3, c.stem_ch), (None, None, None, "conv_out"), dt),
        "stem_bn": cm.bn_table(c.stem_ch, dt),
    }
    cin = c.stem_ch
    for i, (e, ch, r, s, k) in enumerate(c.stages()):
        t[f"stage{i}_first"] = _mbconv_table(cin, ch, e, k, dt)
        if r > 1:
            t[f"stage{i}_rest"] = _mbconv_table(ch, ch, e, k, dt, n=r - 1)
        cin = ch
    t["head_conv"] = ParamSpec((1, 1, cin, c.head_ch),
                               (None, None, None, "conv_out"), dt)
    t["head_bn"] = cm.bn_table(c.head_ch, dt)
    t["head"] = ParamSpec((c.head_ch, c.n_classes), (None, "vocab"), dt)
    t["head_bias"] = ParamSpec((c.n_classes,), (None,), dt, init="zeros")
    return t


def _mbconv(p, x, stride, training, axis_name):
    new_p = dict(p)
    h = x
    if "expand" in p:
        h = cm.conv2d(h, p["expand"])
        h, new_p["bn_e"] = cm.bn_apply(p["bn_e"], h, training, axis_name)
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    h = cm.depthwise_conv2d(h, p["dw"], stride=stride)
    h, new_p["bn_dw"] = cm.bn_apply(p["bn_dw"], h, training, axis_name)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    # Squeeze-excite.
    s = jnp.mean(h, axis=(1, 2), keepdims=True)
    s = cm.conv2d(s, p["se_reduce"]) + p["se_reduce_b"]
    s = jax.nn.silu(s.astype(jnp.float32)).astype(x.dtype)
    s = cm.conv2d(s, p["se_expand"]) + p["se_expand_b"]
    h = h * jax.nn.sigmoid(s.astype(jnp.float32)).astype(x.dtype)
    h = cm.conv2d(h, p["project"])
    h, new_p["bn_p"] = cm.bn_apply(p["bn_p"], h, training, axis_name)
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h, new_p


def make_forward(cfg: EfficientNetConfig, mesh=None, batch_axes=("data",),
                 training: bool = False):
    axis_name = None

    def forward(params, images):
        new_params = dict(params)
        x = cm.conv2d(images.astype(cfg.jdtype), params["stem"], stride=2)
        x, new_params["stem_bn"] = cm.bn_apply(params["stem_bn"], x,
                                               training, axis_name)
        x = jax.nn.silu(x.astype(jnp.float32)).astype(cfg.jdtype)
        for i, (e, ch, r, s, k) in enumerate(cfg.stages()):
            x, new_params[f"stage{i}_first"] = _mbconv(
                params[f"stage{i}_first"], x, s, training, axis_name)
            if r > 1:
                def body(x, lp):
                    return _mbconv(lp, x, 1, training, axis_name)
                if cfg.remat and training:
                    body = jax.checkpoint(body)
                x, nrest = lax.scan(body, x, params[f"stage{i}_rest"])
                new_params[f"stage{i}_rest"] = nrest
        x = cm.conv2d(x, params["head_conv"])
        x, new_params["head_bn"] = cm.bn_apply(params["head_bn"], x,
                                               training, axis_name)
        x = jax.nn.silu(x.astype(jnp.float32)).astype(cfg.jdtype)
        x = jnp.mean(x, axis=(1, 2))
        logits = x @ params["head"] + params["head_bias"]
        return logits, new_params

    return forward


def make_loss_fn(cfg: EfficientNetConfig, mesh=None, batch_axes=("data",)):
    forward = make_forward(cfg, mesh, batch_axes, training=True)

    def loss_fn(params, batch):
        logits, new_params = forward(params, batch["images"])
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        nll = jnp.mean(logz - gold)
        return nll, {"nll": nll, "bn_params": new_params}

    return loss_fn
