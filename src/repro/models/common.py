"""Model-zoo substrate: param tables, sharding rules, attention, conv/norm.

Single source of truth per model is a *param table*: a pytree of
``ParamSpec(shape, dtype, axes)`` where ``axes`` names each dimension with
a logical axis ("embed", "heads", "mlp", "experts", "vocab", ...). From
the table we derive (a) initialized parameters, (b) ``PartitionSpec``
trees via a logical→mesh rule set, and (c) allocation-free
``ShapeDtypeStruct`` trees for ``.lower()`` dry-runs. One structure, three
views — the trees cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                 # normal | zeros | ones
    scale: float = 1.0                   # stddev multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Default logical→mesh rules for the production mesh (DESIGN.md §3).
# "model"-axis tensor parallelism on heads / mlp / experts / vocab;
# everything else replicated; batch dims handled by input shardings.
DEFAULT_RULES: Dict[str, Optional[Any]] = {
    "vocab": "model",
    "vocab_embed": "model",
    "dm_embed": None,
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "mlp": "model",
    "experts": "model",
    "conv_out": None,
    "embed": None,
    "layers": None,
    "head_dim": None,
    None: None,
}


def fanin_scale(spec: ParamSpec) -> float:
    """1/sqrt(fan_in) init, fan_in = product of non-output dims."""
    if len(spec.shape) < 2:
        return 1.0
    fan_in = math.prod(spec.shape[:-1]) / (
        spec.shape[0] if spec.axes and spec.axes[0] == "layers" else 1)
    return 1.0 / math.sqrt(max(fan_in, 1.0))


def init_params(rng: jax.Array, table: Any) -> Any:
    """Initialize a param pytree from a table of ParamSpec."""
    leaves, treedef = jax.tree_util.tree_flatten(
        table, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, spec in zip(keys, leaves):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            std = spec.scale * fanin_scale(spec)
            out.append((jax.random.normal(key, spec.shape, jnp.float32)
                        * std).astype(spec.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shapes(table: Any) -> Any:
    """ShapeDtypeStruct tree (dry-run view — no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), table,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_pspecs(table: Any, rules: Optional[Mapping] = None,
                 mesh: Optional[Any] = None) -> Any:
    """PartitionSpec tree via logical→mesh rules.

    When ``mesh`` is given, a dimension whose size is not divisible by the
    mapped mesh axis size falls back to replication (NamedSharding rejects
    uneven shards) — e.g. a 1000-class head under a 16-way model axis."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def axis_size(entry) -> int:
        if mesh is None or entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in names:
            n *= mesh.shape[a]
        return n

    def one(spec: ParamSpec) -> P:
        parts = []
        for dim, a in zip(spec.shape, spec.axes):
            entry = rules.get(a, None)
            n = axis_size(entry)
            parts.append(entry if (n > 1 and dim % n == 0) or mesh is None
                         else None)
        return P(*parts)

    return jax.tree.map(one, table, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(table: Any) -> int:
    return sum(math.prod(s.shape) for s in jax.tree_util.tree_leaves(
        table, is_leaf=lambda x: isinstance(x, ParamSpec)))


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
         ) -> jnp.ndarray:
    """Rotary embedding, interleaved-pair formulation.

    x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, KV-blocked online softmax)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     q_offset: int | jnp.ndarray = 0,
                     kv_block: int = 1024) -> jnp.ndarray:
    """Memory-efficient causal attention via online softmax over KV blocks.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) with H % Hkv == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0
    with Sq == Skv; decode: cache length). Never materializes the full
    (Sq, Skv) score matrix — peak is (Sq, kv_block) per head.
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = 1.0 / math.sqrt(d)
    q32 = q.astype(jnp.float32) * scale

    if skv <= kv_block:
        # Single-block fast path.
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, k.astype(jnp.float32))
        qpos = q_offset + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    n_blocks = math.ceil(skv / kv_block)
    pad = n_blocks * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, kv_block, h, d).astype(jnp.float32)
    vb = v.reshape(b, n_blocks, kv_block, h, d).astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        m_prev, l_prev, o_prev = carry
        k_blk, v_blk, blk_idx = blk
        kpos = blk_idx * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, k_blk)
        mask = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < skv)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        # Guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use 0.
        safe = jnp.isfinite(m_new)
        alpha = jnp.where(safe, jnp.exp(m_prev - jnp.where(safe, m_new, 0.0)), 0.0)
        p = jnp.exp(s - jnp.where(safe, m_new, 0.0)[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        o_new = alpha[..., None] * o_prev + jnp.einsum("bhqk,bkhd->bhqd", p, v_blk)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    o0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, o), _ = lax.scan(
        body, (m0, l0, o0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_blocks)))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Conv / pooling helpers (NHWC)
# ---------------------------------------------------------------------------

def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
           padding: str | Sequence[Tuple[int, int]] = "SAME",
           groups: int = 1) -> jnp.ndarray:
    """x: (B,H,W,Cin), w: (kh,kw,Cin/groups,Cout)."""
    return lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(stride, stride),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def depthwise_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                     padding="SAME") -> jnp.ndarray:
    """w: (kh, kw, 1, C) with feature_group_count=C."""
    return conv2d(x, w, stride=stride, padding=padding, groups=x.shape[-1])


def avg_pool(x: jnp.ndarray, window: int, stride: int,
             padding: str = "VALID") -> jnp.ndarray:
    s = lax.reduce_window(x.astype(jnp.float32), 0.0, lax.add,
                          (1, window, window, 1), (1, stride, stride, 1),
                          padding)
    return (s / (window * window)).astype(x.dtype)


def max_pool(x: jnp.ndarray, window: int, stride: int,
             padding: str = "SAME") -> jnp.ndarray:
    return lax.reduce_window(x, -jnp.inf, lax.max,
                             (1, window, window, 1),
                             (1, stride, stride, 1), padding).astype(x.dtype)


def batch_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               mean: jnp.ndarray, var: jnp.ndarray,
               training: bool, eps: float = 1e-5,
               axis_name: Optional[str] = None):
    """BatchNorm. In training mode returns (y, batch_mean, batch_var) with
    cross-replica stats when ``axis_name`` is set (sync-BN); in inference
    mode returns (y, mean, var) using the running stats."""
    x32 = x.astype(jnp.float32)
    if training:
        red = tuple(range(x.ndim - 1))
        bm = jnp.mean(x32, axis=red)
        bv = jnp.mean(jnp.square(x32), axis=red) - jnp.square(bm)
        if axis_name is not None:
            bm = lax.pmean(bm, axis_name)
            bv = lax.pmean(bv, axis_name)
    else:
        bm, bv = mean.astype(jnp.float32), var.astype(jnp.float32)
    y = (x32 - bm) * lax.rsqrt(bv + eps) * scale.astype(jnp.float32) + bias
    return y.astype(x.dtype), bm, bv


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               groups: int = 32, eps: float = 1e-5) -> jnp.ndarray:
    b, h, w, c = x.shape
    g = math.gcd(groups, c)      # largest group count dividing c
    x32 = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
    y = ((x32 - mu) * lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return y.astype(x.dtype) * scale + bias


def bn_table(ch: int, dtype=jnp.float32) -> Dict[str, ParamSpec]:
    """BatchNorm parameter group. ``mean``/``var`` are running stats: they
    receive zero gradients (never used in the training-mode loss path) and
    are refreshed functionally by ``bn_apply`` — the train step merges the
    returned stats back into the param tree."""
    return {
        "scale": ParamSpec((ch,), ("conv_out",), dtype, init="ones"),
        "bias": ParamSpec((ch,), ("conv_out",), dtype, init="zeros"),
        "mean": ParamSpec((ch,), ("conv_out",), dtype, init="zeros"),
        "var": ParamSpec((ch,), ("conv_out",), dtype, init="ones"),
    }


def bn_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray, training: bool,
             axis_name=None, momentum: float = 0.9):
    """Returns (y, new_bn_params) — new stats only change in training."""
    y, bm, bv = batch_norm(x, p["scale"], p["bias"], p["mean"], p["var"],
                           training, axis_name=axis_name)
    if training:
        new = dict(p)
        new["mean"] = (momentum * p["mean"]
                       + (1 - momentum) * lax.stop_gradient(bm)).astype(
                           p["mean"].dtype)
        new["var"] = (momentum * p["var"]
                      + (1 - momentum) * lax.stop_gradient(bv)).astype(
                          p["var"].dtype)
        return y, new
    return y, p


def merge_bn_stats(opt_params: Any, stats_params: Any) -> Any:
    """Take optimizer-updated leaves except BN running stats, which come
    from the forward pass (paths ending in mean/var under a bn group)."""
    flat_opt = jax.tree_util.tree_flatten_with_path(opt_params)[0]
    flat_new = jax.tree_util.tree_leaves(stats_params)
    treedef = jax.tree_util.tree_structure(opt_params)
    out = []
    for (path, leaf), new_leaf in zip(flat_opt, flat_new):
        key = jax.tree_util.keystr(path)
        if key.endswith("['mean']") or key.endswith("['var']"):
            out.append(new_leaf)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def timestep_embedding(t: jnp.ndarray, dim: int,
                       max_period: float = 10000.0) -> jnp.ndarray:
    """Sinusoidal embedding, (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def posemb_sincos_2d(h: int, w: int, dim: int) -> jnp.ndarray:
    """(h*w, dim) fixed 2-D sin-cos position embedding."""
    y, x = jnp.mgrid[:h, :w]
    omega = jnp.arange(dim // 4, dtype=jnp.float32) / (dim // 4 - 1)
    omega = 1.0 / (10000 ** omega)
    y = y.reshape(-1).astype(jnp.float32)[:, None] * omega[None]
    x = x.reshape(-1).astype(jnp.float32)[:, None] * omega[None]
    return jnp.concatenate([jnp.sin(x), jnp.cos(x), jnp.sin(y), jnp.cos(y)],
                           axis=1)
