"""ResNet (He et al., arXiv:1512.03385) — resnet-50 (bottleneck 3-4-6-3).

BatchNorm with cross-replica (sync) statistics in training; running stats
live in the param tree and are merged back by the train step
(``common.merge_bn_stats``). Within a stage, identity blocks (2..n) are
homogeneous and run under ``lax.scan`` with stacked params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet"
    img_res: int = 224
    depths: Tuple[int, ...] = (3, 4, 6, 3)
    width: int = 64
    expansion: int = 4
    n_classes: int = 1000
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _conv_spec(k, cin, cout, dt):
    return ParamSpec((k, k, cin, cout), (None, None, None, "conv_out"), dt)


def _bottleneck_table(cin, mid, cout, dt, stride_first=False, n=None):
    """Param table for one bottleneck (or n stacked identical ones)."""
    lead = (n,) if n else ()
    lax_ = ("layers",) if n else ()

    def conv(k, ci, co):
        return ParamSpec(lead + (k, k, ci, co),
                         lax_ + (None, None, None, "conv_out"), dt)

    def bn(c):
        return {k: ParamSpec(lead + v.shape, lax_ + v.axes, v.dtype, v.init)
                for k, v in cm.bn_table(c, dt).items()}

    t = {
        "conv1": conv(1, cin, mid), "bn1": bn(mid),
        "conv2": conv(3, mid, mid), "bn2": bn(mid),
        "conv3": conv(1, mid, cout), "bn3": bn(cout),
    }
    if stride_first or cin != cout:
        t["proj"] = conv(1, cin, cout)
        t["bn_proj"] = bn(cout)
    return t


def resnet_param_table(c: ResNetConfig) -> Dict[str, Any]:
    dt = c.jdtype
    t: Dict[str, Any] = {
        "stem": _conv_spec(7, 3, c.width, dt),
        "stem_bn": cm.bn_table(c.width, dt),
    }
    cin = c.width
    for i, depth in enumerate(c.depths):
        mid = c.width * (2 ** i)
        cout = mid * c.expansion
        t[f"stage{i}_first"] = _bottleneck_table(
            cin, mid, cout, dt, stride_first=True)
        if depth > 1:
            t[f"stage{i}_rest"] = _bottleneck_table(
                cout, mid, cout, dt, n=depth - 1)
        cin = cout
    t["head"] = ParamSpec((cin, c.n_classes), (None, "vocab"), dt)
    t["head_bias"] = ParamSpec((c.n_classes,), (None,), dt, init="zeros")
    return t


def _bottleneck(p, x, stride, training, axis_name):
    y, bn1 = cm.bn_apply(p["bn1"], cm.conv2d(x, p["conv1"]), training, axis_name)
    y = jax.nn.relu(y)
    y, bn2 = cm.bn_apply(p["bn2"], cm.conv2d(y, p["conv2"], stride=stride),
                         training, axis_name)
    y = jax.nn.relu(y)
    y, bn3 = cm.bn_apply(p["bn3"], cm.conv2d(y, p["conv3"]), training, axis_name)
    new_p = dict(p, bn1=bn1, bn2=bn2, bn3=bn3)
    if "proj" in p:
        sc, bnp = cm.bn_apply(p["bn_proj"],
                              cm.conv2d(x, p["proj"], stride=stride),
                              training, axis_name)
        new_p["bn_proj"] = bnp
    else:
        sc = x
    return jax.nn.relu(y + sc), new_p


def make_forward(cfg: ResNetConfig, mesh=None, batch_axes=("data",),
                 training: bool = False):
    """forward(params, images) -> (logits, params_with_new_bn_stats)."""
    axis_name = None  # sync-BN axis wired by shard_map wrappers if used

    def forward(params, images):
        new_params = dict(params)
        x = cm.conv2d(images.astype(cfg.jdtype), params["stem"], stride=2)
        x, new_params["stem_bn"] = cm.bn_apply(params["stem_bn"], x,
                                               training, axis_name)
        x = jax.nn.relu(x)
        x = cm.max_pool(x, 3, 2)
        for i, depth in enumerate(cfg.depths):
            stride = 1 if i == 0 else 2
            x, new_params[f"stage{i}_first"] = _bottleneck(
                params[f"stage{i}_first"], x, stride, training, axis_name)
            if depth > 1:
                def body(x, lp):
                    y, nlp = _bottleneck(lp, x, 1, training, axis_name)
                    return y, nlp
                x, nrest = lax.scan(body, x, params[f"stage{i}_rest"])
                new_params[f"stage{i}_rest"] = nrest
        x = jnp.mean(x, axis=(1, 2))
        logits = x @ params["head"] + params["head_bias"]
        return logits, new_params

    return forward


def make_loss_fn(cfg: ResNetConfig, mesh=None, batch_axes=("data",)):
    forward = make_forward(cfg, mesh, batch_axes, training=True)

    def loss_fn(params, batch):
        logits, new_params = forward(params, batch["images"])
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        nll = jnp.mean(logz - gold)
        return nll, {"nll": nll, "bn_params": new_params}

    return loss_fn
