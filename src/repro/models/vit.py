"""ViT classifier (Dosovitskiy et al., arXiv:2010.11929) — vit-l16.

Pre-LN encoder, learned position embeddings, CLS token, GELU MLP.
Layers run under lax.scan (stacked params) for flat compile time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str = "vit"
    img_res: int = 224
    patch: int = 16
    n_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    d_ff: int = 4096
    n_classes: int = 1000
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        r = img_res or self.img_res
        return (r // self.patch) ** 2 + 1


def vit_param_table(c: ViTConfig, img_res: Optional[int] = None) -> Dict[str, Any]:
    dt = c.jdtype
    L, dm = c.n_layers, c.d_model
    hd = dm // c.n_heads
    n_tok = c.n_tokens(img_res)
    return {
        "patch_embed": ParamSpec((c.patch, c.patch, 3, dm),
                                 (None, None, None, "embed"), dt),
        "patch_bias": ParamSpec((dm,), ("embed",), dt, init="zeros"),
        "cls": ParamSpec((1, 1, dm), (None, None, "embed"), dt, init="zeros"),
        "pos_embed": ParamSpec((1, n_tok, dm), (None, None, "embed"), dt,
                               scale=0.02),
        "layers": {
            "ln1_s": ParamSpec((L, dm), ("layers", "embed"), dt, init="ones"),
            "ln1_b": ParamSpec((L, dm), ("layers", "embed"), dt, init="zeros"),
            "wq": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wk": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wv": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wo": ParamSpec((L, c.n_heads, hd, dm), ("layers", "heads", "head_dim", "embed"), dt),
            "ln2_s": ParamSpec((L, dm), ("layers", "embed"), dt, init="ones"),
            "ln2_b": ParamSpec((L, dm), ("layers", "embed"), dt, init="zeros"),
            "w_in": ParamSpec((L, dm, c.d_ff), ("layers", "embed", "mlp"), dt),
            "b_in": ParamSpec((L, c.d_ff), ("layers", "mlp"), dt, init="zeros"),
            "w_out": ParamSpec((L, c.d_ff, dm), ("layers", "mlp", "embed"), dt),
            "b_out": ParamSpec((L, dm), ("layers", "embed"), dt, init="zeros"),
        },
        "final_ln_s": ParamSpec((dm,), ("embed",), dt, init="ones"),
        "final_ln_b": ParamSpec((dm,), ("embed",), dt, init="zeros"),
        "head": ParamSpec((dm, c.n_classes), ("embed", "vocab"), dt),
    }


def _encoder_block(x, lp, cfg: ViTConfig):
    h = cm.layer_norm(x, lp["ln1_s"], lp["ln1_b"])
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(
                       jnp.asarray(q.shape[-1], jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    h = cm.layer_norm(x, lp["ln2_s"], lp["ln2_b"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["w_in"]) + lp["b_in"])
    x = x + jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), lp["w_out"]) + lp["b_out"]
    return x


def make_forward(cfg: ViTConfig, mesh: Optional[Mesh] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",)):
    """Returns forward(params, images (B,R,R,3)) -> logits (B, n_classes)."""
    del mesh, batch_axes   # batch sharding comes from in_shardings

    def forward(params, images):
        x = cm.conv2d(images.astype(cfg.jdtype), params["patch_embed"],
                      stride=cfg.patch, padding="VALID") + params["patch_bias"]
        b = x.shape[0]
        x = x.reshape(b, -1, cfg.d_model)
        cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
        x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"]

        def block(x, lp):
            return _encoder_block(x, lp, cfg), None

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = lax.scan(block, x, params["layers"])
        x = cm.layer_norm(x[:, 0], params["final_ln_s"], params["final_ln_b"])
        return jnp.einsum("bd,dc->bc", x, params["head"])

    return forward


def make_loss_fn(cfg: ViTConfig, mesh=None, batch_axes=("data",)):
    forward = make_forward(cfg, mesh, batch_axes)

    def loss_fn(params, batch):
        logits = forward(params, batch["images"]).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None],
                                   axis=-1)[:, 0]
        nll = jnp.mean(logz - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]
                        ).astype(jnp.float32))
        return nll, {"nll": nll, "acc": acc}

    return loss_fn
