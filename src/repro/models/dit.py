"""DiT (Peebles & Xie, arXiv:2212.09748) — dit-l2 (DiT-L/2).

Latent-space diffusion transformer with adaLN-zero conditioning on
(timestep, class). Operates on VAE latents at img_res/8; patch size 2.
Predicts (eps, sigma) — 2x latent channels — like the paper
(learn_sigma=True). Layers are scanned.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str = "dit"
    img_res: int = 256
    patch: int = 2
    n_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    latent_ch: int = 4
    n_classes: int = 1000
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    def n_tokens(self, img_res: Optional[int] = None) -> int:
        r = (img_res or self.img_res) // 8
        return (r // self.patch) ** 2


def dit_param_table(c: DiTConfig) -> Dict[str, Any]:
    dt = c.jdtype
    L, dm = c.n_layers, c.d_model
    hd = dm // c.n_heads
    pdim = c.patch * c.patch * c.latent_ch
    return {
        "patch_embed": ParamSpec((pdim, dm), (None, "embed"), dt),
        "t_mlp1": ParamSpec((256, dm), (None, "embed"), dt),
        "t_mlp2": ParamSpec((dm, dm), ("embed", None), dt),
        "y_embed": ParamSpec((c.n_classes + 1, dm), ("vocab", "embed"), dt),
        "layers": {
            "wq": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wk": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wv": ParamSpec((L, dm, c.n_heads, hd), ("layers", "embed", "heads", "head_dim"), dt),
            "wo": ParamSpec((L, c.n_heads, hd, dm), ("layers", "heads", "head_dim", "embed"), dt),
            "w_in": ParamSpec((L, dm, 4 * dm), ("layers", "embed", "mlp"), dt),
            "w_out": ParamSpec((L, 4 * dm, dm), ("layers", "mlp", "embed"), dt),
            # adaLN-zero: 6 modulation vectors from conditioning.
            "ada_w": ParamSpec((L, dm, 6 * dm), ("layers", "embed", None), dt,
                               init="zeros"),
            "ada_b": ParamSpec((L, 6 * dm), ("layers", None), dt, init="zeros"),
        },
        "final_ada_w": ParamSpec((dm, 2 * dm), ("embed", None), dt, init="zeros"),
        "final_ada_b": ParamSpec((2 * dm,), (None,), dt, init="zeros"),
        "final_proj": ParamSpec((dm, 2 * pdim), ("embed", None), dt,
                                init="zeros"),
    }


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def _ln(x):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * lax.rsqrt(var + 1e-6)).astype(x.dtype)


def _block(x, c_emb, lp, cfg: DiTConfig):
    mod = (jnp.einsum("bd,de->be", c_emb, lp["ada_w"]) + lp["ada_b"])
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    h = _modulate(_ln(x), sh1, sc1)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(
                       jnp.asarray(q.shape[-1], jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
    attn = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    x = x + g1[:, None, :] * attn
    h = _modulate(_ln(x), sh2, sc2)
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["w_in"]))
    mlp = jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), lp["w_out"])
    return x + g2[:, None, :] * mlp


def make_forward(cfg: DiTConfig, mesh: Optional[Mesh] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",),
                 img_res: Optional[int] = None):
    """forward(params, latents (B,r,r,C), t (B,), y (B,)) -> (B,r,r,2C)."""
    del mesh, batch_axes
    r = (img_res or cfg.img_res) // 8
    g = r // cfg.patch

    def forward(params, latents, t, y):
        b = latents.shape[0]
        # Patchify: (B, g, p, g, p, C) -> (B, g*g, p*p*C).
        x = latents.astype(cfg.jdtype).reshape(
            b, g, cfg.patch, g, cfg.patch, cfg.latent_ch)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, g * g, -1)
        x = jnp.einsum("bsp,pd->bsd", x, params["patch_embed"])
        x = x + cm.posemb_sincos_2d(g, g, cfg.d_model).astype(x.dtype)[None]

        t_emb = cm.timestep_embedding(t, 256).astype(cfg.jdtype)
        t_emb = jnp.einsum("be,ed->bd", t_emb, params["t_mlp1"])
        t_emb = jnp.einsum("bd,de->be", jax.nn.silu(t_emb), params["t_mlp2"])
        y_emb = params["y_embed"].at[y].get(mode="clip")
        c_emb = jax.nn.silu(t_emb + y_emb)

        def block(x, lp):
            return _block(x, c_emb, lp, cfg), None

        if cfg.remat:
            block = jax.checkpoint(block)
        x, _ = lax.scan(block, x, params["layers"])

        mod = jnp.einsum("bd,de->be", c_emb, params["final_ada_w"]) \
            + params["final_ada_b"]
        sh, sc = jnp.split(mod, 2, axis=-1)
        x = _modulate(_ln(x), sh, sc)
        x = jnp.einsum("bsd,dp->bsp", x, params["final_proj"])
        # Unpatchify to (B, r, r, 2C).
        x = x.reshape(b, g, g, cfg.patch, cfg.patch, 2 * cfg.latent_ch)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, r, r, 2 * cfg.latent_ch)
        return x

    return forward


def make_loss_fn(cfg: DiTConfig, mesh=None, batch_axes=("data",),
                 img_res: Optional[int] = None):
    """Denoising MSE (eps-prediction) with a cosine-ish schedule."""
    forward = make_forward(cfg, mesh, batch_axes, img_res)

    def loss_fn(params, batch):
        z0 = batch["latents"]
        t = batch["timesteps"]
        # Deterministic pseudo-noise from the batch (keeps the step pure).
        noise = batch["noise"]
        abar = jnp.cos((t.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar = abar[:, None, None, None]
        zt = jnp.sqrt(abar) * z0 + jnp.sqrt(1 - abar) * noise
        out = forward(params, zt, t, batch["labels"]).astype(jnp.float32)
        eps_hat = out[..., :cfg.latent_ch]
        loss = jnp.mean(jnp.square(eps_hat - noise))
        return loss, {"mse": loss}

    return loss_fn


def make_sample_step(cfg: DiTConfig, mesh=None, batch_axes=("data",),
                     img_res: Optional[int] = None, guidance: float = 4.0):
    """One classifier-free-guided DDIM step: (params, z_t, t, t_next, y)."""
    forward = make_forward(cfg, mesh, batch_axes, img_res)

    def sample_step(params, zt, t, t_next, y):
        b = zt.shape[0]
        null_y = jnp.full_like(y, cfg.n_classes)      # CFG null class
        z2 = jnp.concatenate([zt, zt], axis=0)
        t2 = jnp.concatenate([t, t], axis=0)
        y2 = jnp.concatenate([y, null_y], axis=0)
        out = forward(params, z2, t2, y2).astype(jnp.float32)
        eps_c, eps_u = jnp.split(out[..., :cfg.latent_ch], 2, axis=0)
        eps = eps_u + guidance * (eps_c - eps_u)
        abar = jnp.cos((t.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar_n = jnp.cos((t_next.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar = abar[:, None, None, None]
        abar_n = abar_n[:, None, None, None]
        z0 = (zt - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        return jnp.sqrt(abar_n) * z0 + jnp.sqrt(1 - abar_n) * eps

    return sample_step
