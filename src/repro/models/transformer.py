"""Decoder-only transformer LM: GQA, RoPE, MoE (expert-parallel), KV cache.

Covers the four assigned LM architectures (moonshot-v1-16b-a3b,
llama4-scout-17b-a16e, granite-20b, llama3-8b):

  * GQA with arbitrary kv-head count (MQA = 1) and TP head padding: when
    the mesh's model axis does not divide the head count, q/kv heads are
    padded up to the next multiple (Megatron-style KV duplication). The
    MODEL_FLOPS/HLO ratio in the roofline table surfaces the overhead.
  * MoE FFN with sort-based dispatch under ``shard_map``: experts sharded
    over the model axis (EP), tokens routed with a single all-to-all per
    direction within each data row. Dispatch is gather/scatter (no one-hot
    matmul), so compiled FLOPs ≈ active FLOPs.
  * llama4-style chunked local attention (``chunk_attn``) with a RoPE-less
    global layer every ``global_every`` layers — this is what makes the
    long_500k cell sub-quadratic.
  * Layers run under ``lax.scan`` (stacked params) — compile time and HLO
    size stay flat in depth, which is what makes 40 dry-run cells viable.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat
from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 256
    vocab: int = 512
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    # MoE (0 experts = dense FFN).
    moe_experts: int = 0
    moe_topk: int = 1
    moe_capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # llama4-style local attention: 0 = full attention everywhere.
    chunk_attn: int = 0
    global_every: int = 4            # every Nth layer is global (RoPE-less)
    # TP head padding (set to the mesh model-axis size by the launcher).
    pad_heads_to: int = 1
    dtype: str = "bfloat16"
    kv_block: int = 1024
    remat: bool = True               # activation checkpointing per layer
    # Perf levers (EXPERIMENTS.md §Perf):
    seq_shard: bool = False          # shard residual stream seq over "model"
    remat_policy: str = "minimal"    # minimal | save_sums (keep post-
    #                                  collective sums; backward skips the
    #                                  recomputed all-reduces)
    reduce_dtype: str = "float32"    # accumulation dtype of the row-parallel
    #                                  (wo / w_down) matmuls — "bfloat16"
    #                                  halves cross-chip all-reduce bytes
    embed_shard: str = "vocab"       # vocab | dm: embedding-table sharding
    #                                  (dm turns the masked-gather all-reduce
    #                                  into a 4x cheaper bf16 all-gather)
    microbatch: int = 1              # gradient-accumulation factor
    decode_seq_shard: bool = False   # long-context decode: shard the KV
    #                                  cache SEQUENCE over "model" and run
    #                                  distributed flash-decoding (partial
    #                                  online softmax + pmax/psum combine);
    #                                  attention weights become replicated

    @property
    def n_heads_padded(self) -> int:
        return -(-self.n_heads // self.pad_heads_to) * self.pad_heads_to

    @property
    def n_kv_padded(self) -> int:
        return -(-self.n_kv_heads // self.pad_heads_to) * self.pad_heads_to

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self, padded: bool = False) -> int:
        return cm.param_count(lm_param_table(self)) if padded else \
            _logical_param_count(self)

    def active_param_count(self) -> int:
        """Per-token active params (MoE counts top-k experts only)."""
        c = self
        attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
        ffn = 3 * c.d_model * c.d_ff
        ffn_active = ffn * (c.moe_topk if c.moe_experts else 1)
        router = c.d_model * c.moe_experts if c.moe_experts else 0
        per_layer = attn + ffn_active + router + 2 * c.d_model
        return (c.n_layers * per_layer + 2 * c.vocab * c.d_model
                + c.d_model)


def _logical_param_count(c: LMConfig) -> int:
    attn = c.d_model * c.head_dim * (c.n_heads * 2 + c.n_kv_heads * 2)
    ffn = 3 * c.d_model * c.d_ff * (c.moe_experts if c.moe_experts else 1)
    router = c.d_model * c.moe_experts if c.moe_experts else 0
    per_layer = attn + ffn + router + 2 * c.d_model
    return c.n_layers * per_layer + 2 * c.vocab * c.d_model + c.d_model


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def lm_param_table(c: LMConfig) -> Dict[str, Any]:
    dt = c.jdtype
    L, dm, hd = c.n_layers, c.d_model, c.head_dim
    hp, kp = c.n_heads_padded, c.n_kv_padded
    layer: Dict[str, Any] = {
        "attn_norm": ParamSpec((L, dm), ("layers", "embed"), dt, init="ones"),
        "wq": ParamSpec((L, dm, hp, hd), ("layers", "embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((L, dm, kp, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
        "wv": ParamSpec((L, dm, kp, hd), ("layers", "embed", "kv_heads", "head_dim"), dt),
        "wo": ParamSpec((L, hp, hd, dm), ("layers", "heads", "head_dim", "embed"), dt),
        "mlp_norm": ParamSpec((L, dm), ("layers", "embed"), dt, init="ones"),
    }
    if c.moe_experts:
        E, dff = c.moe_experts, c.d_ff
        layer.update({
            "router": ParamSpec((L, dm, E), ("layers", "embed", None),
                                jnp.float32),
            "w_gate": ParamSpec((L, E, dm, dff), ("layers", "experts", "embed", None), dt),
            "w_up": ParamSpec((L, E, dm, dff), ("layers", "experts", "embed", None), dt),
            "w_down": ParamSpec((L, E, dff, dm), ("layers", "experts", None, "embed"), dt),
        })
    else:
        dff = c.d_ff
        layer.update({
            "w_gate": ParamSpec((L, dm, dff), ("layers", "embed", "mlp"), dt),
            "w_up": ParamSpec((L, dm, dff), ("layers", "embed", "mlp"), dt),
            "w_down": ParamSpec((L, dff, dm), ("layers", "mlp", "embed"), dt),
        })
    return {
        # Dedicated logical axes: the input-embedding sharding is a perf
        # lever (cfg.embed_shard) independent of the unembed projection.
        "embed": ParamSpec((c.vocab, dm), ("vocab_embed", "dm_embed"), dt),
        "layers": layer,
        "final_norm": ParamSpec((dm,), ("embed",), dt, init="ones"),
        "unembed": ParamSpec((dm, c.vocab), ("embed", "vocab"), dt),
    }


def lm_rules(c: LMConfig) -> Dict[str, Any]:
    """Logical→mesh rule overrides implied by the config's perf levers."""
    if c.embed_shard == "dm":
        return {"vocab_embed": None, "dm_embed": "model"}
    return {"vocab_embed": "model", "dm_embed": None}


# ---------------------------------------------------------------------------
# MoE FFN (expert parallel, sort-based dispatch)
# ---------------------------------------------------------------------------

def _route(x, router, cfg: LMConfig):
    """Top-k routing. Returns (top_w, top_e, probs)."""
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, cfg.moe_topk)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_e, probs


def _aux_loss(top_e, probs, E: int) -> jnp.ndarray:
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


def _expert_ffn(xs, w_gate, w_up, w_down, dtype):
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xs, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(x, router, w_gate, w_up, w_down, *, cfg: LMConfig,
               model_axis: Optional[str], n_model: int):
    """Per-device MoE body under shard_map — all-to-all dispatch.

    PRECONDITION: every device holds DISTINCT tokens (the caller shards
    the sequence across the model axis). x: (T_loc, dm); w_*: (E_loc, ...)
    local expert shards. Returns (y: (T_loc, dm), aux scalar).
    """
    E, k = cfg.moe_experts, cfg.moe_topk
    t_loc, dm = x.shape
    e_loc = E // n_model
    cap = max(1, math.ceil(t_loc * k / E * cfg.moe_capacity_factor))

    top_w, top_e, probs = _route(x, router, cfg)

    flat_e = top_e.reshape(-1)                                     # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t_loc), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(t_loc * k) - starts[sorted_e]
    keep = pos < cap
    slot_sorted = jnp.where(keep, sorted_e * cap + pos, E * cap)   # OOB=drop

    # Dispatch: (E*cap, dm) buffers, dropped tokens vanish.
    buf = jnp.zeros((E * cap, dm), x.dtype)
    buf = buf.at[slot_sorted].set(x[sorted_t], mode="drop")

    if model_axis is not None and n_model > 1:
        buf = buf.reshape(n_model, e_loc * cap, dm)
        buf = lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                             tiled=True)                           # grouped by source
        xs = buf.reshape(n_model, e_loc, cap, dm).transpose(1, 0, 2, 3) \
                .reshape(e_loc, n_model * cap, dm)
    else:
        xs = buf.reshape(e_loc, cap, dm)

    o = _expert_ffn(xs, w_gate, w_up, w_down, x.dtype)

    if model_axis is not None and n_model > 1:
        o = o.reshape(e_loc, n_model, cap, dm).transpose(1, 0, 2, 3) \
             .reshape(n_model * e_loc * cap, dm)
        o = lax.all_to_all(o.reshape(n_model, e_loc * cap, dm), model_axis,
                           split_axis=0, concat_axis=0, tiled=True)
        o = o.reshape(E * cap, dm)
    else:
        o = o.reshape(E * cap, dm)

    # Combine: unsort slots back to (T, k), gather, weight, sum.
    slot_flat = jnp.zeros((t_loc * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    picked = o.at[slot_flat.clip(0, E * cap - 1)].get()            # (T*k, dm)
    valid = (slot_flat < E * cap).astype(x.dtype)
    w = (top_w.reshape(-1).astype(x.dtype) * valid)[:, None]
    y = (picked * w).reshape(t_loc, k, dm).sum(axis=1)
    return y, _aux_loss(top_e, probs, E)


def _moe_local_replicated(x, router, w_gate, w_up, w_down, *, cfg: LMConfig,
                          model_axis: Optional[str], n_model: int):
    """MoE body when tokens are REPLICATED across the model axis (decode:
    seq length 1 cannot shard). Each column computes only its local
    experts' contributions for all tokens; a psum over the model axis
    combines them — no all-to-all, no duplicated expert FLOPs."""
    E, k = cfg.moe_experts, cfg.moe_topk
    t_loc, dm = x.shape
    e_loc = E // n_model
    cap = max(1, math.ceil(t_loc * k / E * cfg.moe_capacity_factor))

    top_w, top_e, probs = _route(x, router, cfg)
    col = lax.axis_index(model_axis) if (model_axis and n_model > 1) else 0
    local_e = top_e - col * e_loc                                  # (T, k)
    is_local = (local_e >= 0) & (local_e < e_loc)

    flat_e = jnp.where(is_local, local_e, e_loc).reshape(-1)       # e_loc=drop
    flat_t = jnp.repeat(jnp.arange(t_loc), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1), side="left")
    pos = jnp.arange(t_loc * k) - starts[jnp.minimum(sorted_e, e_loc)]
    keep = (pos < cap) & (sorted_e < e_loc)
    slot_sorted = jnp.where(keep, sorted_e * cap + pos, e_loc * cap)

    buf = jnp.zeros((e_loc * cap, dm), x.dtype)
    buf = buf.at[slot_sorted].set(x[sorted_t], mode="drop")
    o = _expert_ffn(buf.reshape(e_loc, cap, dm), w_gate, w_up, w_down,
                    x.dtype).reshape(e_loc * cap, dm)

    slot_flat = jnp.zeros((t_loc * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    picked = o.at[slot_flat.clip(0, e_loc * cap - 1)].get()
    valid = (slot_flat < e_loc * cap).astype(x.dtype)
    w = (top_w.reshape(-1).astype(x.dtype) * valid)[:, None]
    y = (picked * w).reshape(t_loc, k, dm).sum(axis=1)
    if model_axis and n_model > 1:
        y = lax.psum(y, model_axis)
    return y, _aux_loss(top_e, probs, E)


def make_moe_ffn(cfg: LMConfig, mesh: Mesh,
                 batch_axes: Optional[Tuple[str, ...]],
                 seq_len: Optional[int] = None):
    """Returns moe_ffn(x (B,S,dm), layer_params) -> (y, aux_loss).

    When the sequence divides the model axis, tokens are sequence-sharded
    across it so every device dispatches DISTINCT tokens (all-to-all EP —
    expert FLOPs are ideal x capacity factor). Otherwise (decode, S=1)
    tokens stay replicated and each column computes only its local
    experts, combined with a psum."""
    model_axis = "model" if "model" in mesh.axis_names else None
    n_model = mesh.shape.get("model", 1)
    seq_sharded = bool(model_axis and n_model > 1 and seq_len
                       and seq_len % n_model == 0)
    x_spec = P(batch_axes, "model" if seq_sharded else None, None) \
        if batch_axes else P(None, "model" if seq_sharded else None, None)
    body = _moe_local if (seq_sharded or n_model == 1 or model_axis is None) \
        else _moe_local_replicated

    def local_fn(x, router, w_gate, w_up, w_down):
        b, s, dm = x.shape
        y, aux = body(x.reshape(b * s, dm), router, w_gate, w_up,
                      w_down, cfg=cfg, model_axis=model_axis,
                      n_model=n_model)
        if model_axis and n_model > 1 and seq_sharded:
            aux = lax.pmean(aux, model_axis)
        if batch_axes:
            aux = lax.pmean(aux, batch_axes)
        return y.reshape(b, s, dm), aux

    e_spec = P("model", None, None) if model_axis else P(None, None, None)
    return compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec,
                  P(None, None),        # router replicated
                  e_spec, e_spec, e_spec),
        out_specs=(x_spec, P()),
        check_vma=False)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

def _attention(x, lp, positions, cfg: LMConfig, is_global,
               kv_cache=None, cache_pos=None):
    """One attention sublayer. Returns (out, (k_new, v_new)).

    Training/prefill: kv_cache None, positions (B, S).
    Decode: kv_cache (k, v) each (B, S_max, Kp, hd), cache_pos scalar.
    """
    b, s, dm = x.shape
    h = cm.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])

    if cfg.chunk_attn == 0:
        # Plain causal arch: RoPE everywhere.
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
    else:
        # llama4-style: chunked layers use RoPE, global layers are NoPE.
        q_r = cm.rope(q, positions, cfg.rope_theta)
        k_r = cm.rope(k, positions, cfg.rope_theta)
        q = jnp.where(is_global, q, q_r)
        k = jnp.where(is_global, k, k_r)

    if kv_cache is None:
        if cfg.chunk_attn and s > cfg.chunk_attn:
            w = cfg.chunk_attn
            nchunk = s // w

            def chunked():
                qc = q.reshape(b * nchunk, w, *q.shape[2:])
                kc = k.reshape(b * nchunk, w, *k.shape[2:])
                vc = v.reshape(b * nchunk, w, *v.shape[2:])
                o = cm.causal_attention(qc, kc, vc, kv_block=cfg.kv_block)
                return o.reshape(b, s, *o.shape[2:])

            def full():
                return cm.causal_attention(q, k, v, kv_block=cfg.kv_block)

            o = lax.cond(is_global, full, chunked)
        else:
            o = cm.causal_attention(q, k, v, kv_block=cfg.kv_block)
        k_out, v_out = k, v
    else:
        ck, cv = kv_cache
        k_out = lax.dynamic_update_slice_in_dim(ck, k, cache_pos, axis=1)
        v_out = lax.dynamic_update_slice_in_dim(cv, v, cache_pos, axis=1)
        s_max = ck.shape[1]
        if cfg.chunk_attn and cfg.chunk_attn < s_max:
            w = cfg.chunk_attn

            def windowed():
                start = jnp.clip(cache_pos + s - w, 0, s_max - w)
                kw = lax.dynamic_slice_in_dim(k_out, start, w, axis=1)
                vw = lax.dynamic_slice_in_dim(v_out, start, w, axis=1)
                return cm.causal_attention(q, kw, vw,
                                           q_offset=cache_pos - start,
                                           kv_block=cfg.kv_block)

            def full():
                return cm.causal_attention(q, k_out, v_out,
                                           q_offset=cache_pos,
                                           kv_block=cfg.kv_block)

            o = lax.cond(is_global, full, windowed)
        else:
            o = cm.causal_attention(q, k_out, v_out, q_offset=cache_pos,
                                    kv_block=cfg.kv_block)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"],
                     preferred_element_type=_accum_dtype(cfg))
    return out.astype(x.dtype), (k_out, v_out)


def _accum_dtype(cfg: LMConfig):
    """Accumulation dtype for the row-parallel matmuls whose partial sums
    cross chips (Megatron 2nd all-reduce): bf16 halves the wire bytes."""
    return jnp.bfloat16 if cfg.reduce_dtype == "bfloat16" else jnp.float32


def _dense_ffn(x, lp, cfg: LMConfig):
    h = cm.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", h, lp["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h, lp["w_up"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", act, lp["w_down"],
                      preferred_element_type=_accum_dtype(cfg)).astype(x.dtype)


def _seq_constraint(cfg: LMConfig, mesh: Optional[Mesh],
                    batch_axes, seq_len: int):
    """Residual-stream sequence sharding (SP): returns a constraint fn for
    (B, S, dm) activations, sharding S over 'model' between layers. GSPMD
    then lowers the Megatron all-reduce pair into all-gather +
    reduce-scatter and — the point — remat-saved layer inputs shrink by
    the TP degree."""
    if (not cfg.seq_shard or mesh is None or batch_axes is None
            or "model" not in mesh.axis_names):
        return lambda x: x
    n_model = mesh.shape["model"]
    if seq_len % n_model != 0 or seq_len < n_model:
        return lambda x: x
    sh = jax.sharding.NamedSharding(mesh, P(batch_axes, "model", None))
    return lambda x: jax.lax.with_sharding_constraint(x, sh)


def _maybe_name(x, name: str, cfg: LMConfig):
    if cfg.remat_policy == "save_sums":
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(x, name)
    return x


def _remat(block, cfg: LMConfig):
    if not cfg.remat:
        return block
    if cfg.remat_policy == "save_sums":
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out")
        return jax.checkpoint(block, policy=policy)
    return jax.checkpoint(block)


def make_seqpar_attention(cfg: LMConfig, mesh: Mesh):
    """Distributed flash-decoding: KV cache sequence-sharded over "model".

    Each device holds an S/16 slice of the 500k-token cache, computes a
    partial online-softmax over its slice, and the partials combine with
    one pmax + two psums of (B, H, 1)-sized scalars/vectors — wire bytes
    are O(B·H·hd), independent of context length. Chunked (windowed)
    layers use the same code with an extra window mask.

    Returns attn(q, k_new, v_new, ck, cv, pos, is_global)
      -> (out (B,1,H,hd), new_ck, new_cv), with ck/cv local slices
      (B, S_loc, Kp, hd) under shard_map.
    """
    n_model = mesh.shape.get("model", 1)

    def local_attn(q, k_new, v_new, ck, cv, pos, is_global):
        b, _, h, d = q.shape
        s_loc = ck.shape[1]
        idx = lax.axis_index("model")
        start = idx * s_loc
        # Scatter the new token's K/V into the owning shard's slice.
        owned = (pos >= start) & (pos < start + s_loc)
        li = jnp.clip(pos - start, 0, s_loc - 1)
        ck_upd = lax.dynamic_update_slice_in_dim(ck, k_new, li, axis=1)
        cv_upd = lax.dynamic_update_slice_in_dim(cv, v_new, li, axis=1)
        ck = jnp.where(owned, ck_upd, ck)
        cv = jnp.where(owned, cv_upd, cv)

        kk = cm._repeat_kv(ck, h // ck.shape[2])
        vv = cm._repeat_kv(cv, h // cv.shape[2])
        scale = 1.0 / math.sqrt(d)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                       kk.astype(jnp.float32))            # (B,H,1,S_loc)
        abs_pos = start + jnp.arange(s_loc)
        mask = abs_pos <= pos
        if cfg.chunk_attn:
            win = abs_pos > pos - cfg.chunk_attn
            mask = jnp.where(is_global, mask, mask & win)
        s = jnp.where(mask[None, None, None, :], s, -jnp.inf)

        m_loc = jnp.max(s, axis=-1)                       # (B,H,1)
        m_glob = lax.pmax(m_loc, "model")
        safe = jnp.isfinite(m_glob)
        p = jnp.exp(s - jnp.where(safe, m_glob, 0.0)[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhqk,bkhd->bhqd", p, vv.astype(jnp.float32))
        l_glob = lax.psum(l_loc, "model")
        o_glob = lax.psum(o_loc, "model")
        out = (o_glob / jnp.maximum(l_glob, 1e-30)[..., None]) \
            .transpose(0, 2, 1, 3).astype(q.dtype)        # (B,1,H,hd)
        return out, ck, cv

    kvspec = P(None, "model", None, None)
    rep4 = P(None, None, None, None)
    return compat.shard_map(
        local_attn, mesh=mesh,
        in_specs=(rep4, rep4, rep4, kvspec, kvspec, P(), P()),
        out_specs=(rep4, kvspec, kvspec),
        check_vma=False)


def _layer_flags(cfg: LMConfig) -> jnp.ndarray:
    """(L,) bool — True where the layer uses global (full, RoPE-less) attn."""
    if cfg.chunk_attn == 0:
        return jnp.ones((cfg.n_layers,), bool)     # all global (plain causal)
    idx = jnp.arange(cfg.n_layers)
    return (idx + 1) % cfg.global_every == 0


def make_forward(cfg: LMConfig, mesh: Optional[Mesh] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",)):
    """Returns forward(params, tokens (B,S)) -> (logits, aux_loss)."""
    if mesh is None:
        mesh = Mesh(jax.devices()[:1], ("data",))
        batch_axes = None

    def forward(params, tokens):
        b, s = tokens.shape
        moe_ffn = make_moe_ffn(cfg, mesh, batch_axes, seq_len=s) \
            if cfg.moe_experts else None
        x = params["embed"].at[tokens].get(mode="clip").astype(cfg.jdtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        flags = _layer_flags(cfg)
        constrain = _seq_constraint(cfg, mesh, batch_axes, s)

        def block(x, scanned):
            lp, is_global = scanned
            x = constrain(x)
            attn, _ = _attention(x, lp, positions, cfg, is_global)
            x = x + _maybe_name(attn, "attn_out", cfg)
            if cfg.moe_experts:
                h = cm.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
                y, aux = moe_ffn(h, lp["router"], lp["w_gate"], lp["w_up"],
                                 lp["w_down"])
                x = x + _maybe_name(y, "ffn_out", cfg)
            else:
                aux = jnp.zeros((), jnp.float32)
                x = x + _maybe_name(_dense_ffn(x, lp, cfg), "ffn_out", cfg)
            return constrain(x), aux

        block = _remat(block, cfg)
        x, auxes = lax.scan(block, x, (params["layers"], flags))
        x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return logits, jnp.sum(auxes) * cfg.aux_loss_coef

    return forward


def make_loss_fn(cfg: LMConfig, mesh: Optional[Mesh] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",)):
    forward = make_forward(cfg, mesh, batch_axes)

    def loss_fn(params, batch):
        logits, aux = forward(params, batch["tokens"])
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["labels"][..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
        return nll + aux, {"nll": nll, "aux": aux}

    return loss_fn


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_padded, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jdtype),
            "v": jnp.zeros(shape, cfg.jdtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_shapes(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """ShapeDtypeStruct view for dry-runs (no allocation)."""
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_padded, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, cfg.jdtype),
            "v": jax.ShapeDtypeStruct(shape, cfg.jdtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_pspecs(cfg: LMConfig, batch_axes) -> Dict[str, P]:
    """KV cache sharding: batch over data axes, kv heads over model."""
    kv = P(None, batch_axes, None, "model", None)
    return {"k": kv, "v": kv, "pos": P()}


def make_decode_step(cfg: LMConfig, mesh: Optional[Mesh] = None,
                     batch_axes: Optional[Tuple[str, ...]] = ("data",)):
    """Returns decode(params, cache, tokens (B,1)) -> (logits, cache)."""
    if mesh is None:
        mesh = Mesh(jax.devices()[:1], ("data",))
        batch_axes = None
    moe_ffn = make_moe_ffn(cfg, mesh, batch_axes, seq_len=1) \
        if cfg.moe_experts else None
    seqpar = (cfg.decode_seq_shard and "model" in mesh.axis_names
              and mesh.shape["model"] > 1)
    seqpar_attn = make_seqpar_attention(cfg, mesh) if seqpar else None

    def decode(params, cache, tokens):
        b, s = tokens.shape
        pos = cache["pos"]
        x = params["embed"].at[tokens].get(mode="clip").astype(cfg.jdtype)
        positions = jnp.broadcast_to(pos + jnp.arange(s), (b, s))
        flags = _layer_flags(cfg)

        def block(x, scanned):
            lp, is_global, ck, cv = scanned
            if seqpar:
                assert s == 1, "seq-parallel decode is single-token"
                h = cm.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
                q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
                k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
                v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
                if cfg.chunk_attn == 0:
                    q = cm.rope(q, positions, cfg.rope_theta)
                    k = cm.rope(k, positions, cfg.rope_theta)
                else:
                    q = jnp.where(is_global, q,
                                  cm.rope(q, positions, cfg.rope_theta))
                    k = jnp.where(is_global, k,
                                  cm.rope(k, positions, cfg.rope_theta))
                o, k_new, v_new = seqpar_attn(q, k, v, ck, cv, pos, is_global)
                attn = jnp.einsum("bshk,hkd->bsd", o, lp["wo"]).astype(x.dtype)
                k_new, v_new = k_new, v_new
            else:
                attn, (k_new, v_new) = _attention(
                    x, lp, positions, cfg, is_global,
                    kv_cache=(ck, cv), cache_pos=pos)
            x = x + attn
            if cfg.moe_experts:
                h = cm.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
                y, _ = moe_ffn(h, lp["router"], lp["w_gate"], lp["w_up"],
                               lp["w_down"])
                x = x + y
            else:
                x = x + _dense_ffn(x, lp, cfg)
            return x, (k_new, v_new)

        x, (k_all, v_all) = lax.scan(
            block, x, (params["layers"], flags, cache["k"], cache["v"]))
        x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        new_cache = {"k": k_all, "v": v_all, "pos": pos + s}
        return logits, new_cache

    return decode


def make_prefill(cfg: LMConfig, mesh: Optional[Mesh] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",),
                 max_len: Optional[int] = None):
    """Returns prefill(params, tokens (B,S)) -> (last_logits (B,V), cache).

    Uses the forward-path attention (correct block-diagonal semantics for
    chunked layers) while collecting the per-layer K/V into a fresh cache.
    Only the last position's logits are computed — that is what serving
    needs, and it avoids a (B, S, V) logits buffer at 32k context.
    """
    if mesh is None:
        mesh = Mesh(jax.devices()[:1], ("data",))
        batch_axes = None

    def prefill(params, tokens):
        b, s = tokens.shape
        moe_ffn = make_moe_ffn(cfg, mesh, batch_axes, seq_len=s) \
            if cfg.moe_experts else None
        total = max_len or s
        x = params["embed"].at[tokens].get(mode="clip").astype(cfg.jdtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        flags = _layer_flags(cfg)
        constrain = _seq_constraint(cfg, mesh, batch_axes, s)

        def block(x, scanned):
            lp, is_global = scanned
            x = constrain(x)
            attn, (k_new, v_new) = _attention(x, lp, positions, cfg, is_global)
            x = x + attn
            if cfg.moe_experts:
                h = cm.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
                y, _ = moe_ffn(h, lp["router"], lp["w_gate"], lp["w_up"],
                               lp["w_down"])
                x = x + y
            else:
                x = x + _dense_ffn(x, lp, cfg)
            return constrain(x), (k_new, v_new)

        x, (k_all, v_all) = lax.scan(block, x, (params["layers"], flags))
        x = cm.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
        if total > s:
            pad = ((0, 0), (0, 0), (0, total - s), (0, 0), (0, 0))
            k_all = jnp.pad(k_all, pad)
            v_all = jnp.pad(v_all, pad)
        cache = {"k": k_all, "v": v_all,
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits, cache

    return prefill
