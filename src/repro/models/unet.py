"""SDXL-class U-Net (Podell et al., arXiv:2307.01952) — unet-sdxl.

Latent-space U-Net: ch=320, ch_mult=(1,2,4), 2 res blocks per level,
spatial transformers with per-level depth (1,2,10) (assigned config),
cross-attention to a 2048-d text context, GroupNorm+SiLU, time embedding
(+ pooled-context add-embedding, SDXL style).

The architecture is *plan-driven*: ``build_plan`` simulates the skip-stack
channel flow once and emits a flat list of typed block descriptors; the
param table and the forward pass both walk that plan, so they cannot
disagree. Depth-10 transformer stacks run under lax.scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str = "unet"
    img_res: int = 1024
    latent_ch: int = 4
    ch: int = 320
    ch_mult: Tuple[int, ...] = (1, 2, 4)
    n_res_blocks: int = 2
    transformer_depth: Tuple[int, ...] = (1, 2, 10)
    ctx_dim: int = 2048
    ctx_len: int = 77
    head_dim: int = 64
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def t_dim(self) -> int:
        return self.ch * 4


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Block:
    kind: str                  # res | attn | down | up
    name: str
    cin: int = 0
    cout: int = 0
    depth: int = 0             # transformer depth for attn
    skip: int = 0              # channels popped from the skip stack (res-up)


def build_plan(c: UNetConfig) -> Tuple[List[Block], List[Block], List[Block]]:
    """Returns (down_plan, mid_plan, up_plan)."""
    chs = [c.ch * m for m in c.ch_mult]
    down: List[Block] = []
    stack = [c.ch]                       # conv_in output
    cur = c.ch
    for lvl, ch in enumerate(chs):
        for i in range(c.n_res_blocks):
            down.append(Block("res", f"d{lvl}_res{i}", cur, ch))
            cur = ch
            if c.transformer_depth[lvl]:
                down.append(Block("attn", f"d{lvl}_attn{i}", cur, cur,
                                  depth=c.transformer_depth[lvl]))
            stack.append(cur)
        if lvl < len(chs) - 1:
            down.append(Block("down", f"d{lvl}_down", cur, cur))
            stack.append(cur)
    mid = [Block("res", "mid_res0", cur, cur),
           Block("attn", "mid_attn", cur, cur, depth=c.transformer_depth[-1]),
           Block("res", "mid_res1", cur, cur)]
    up: List[Block] = []
    for lvl in reversed(range(len(chs))):
        ch = chs[lvl]
        for i in range(c.n_res_blocks + 1):
            skip = stack.pop()
            up.append(Block("res", f"u{lvl}_res{i}", cur + skip, ch, skip=skip))
            cur = ch
            if c.transformer_depth[lvl]:
                up.append(Block("attn", f"u{lvl}_attn{i}", cur, cur,
                                depth=c.transformer_depth[lvl]))
        if lvl > 0:
            up.append(Block("up", f"u{lvl}_up", cur, cur))
    assert not stack
    return down, mid, up


# ---------------------------------------------------------------------------
# Param table
# ---------------------------------------------------------------------------

def _gn(ch, dt, lead=(), la=()):
    return {"s": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="ones"),
            "b": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="zeros")}


def _res_table(b: Block, c: UNetConfig, dt):
    t = {
        "gn1": _gn(b.cin, dt),
        "conv1": ParamSpec((3, 3, b.cin, b.cout), (None, None, None, "conv_out"), dt),
        "t_proj": ParamSpec((c.t_dim, b.cout), (None, "conv_out"), dt),
        "t_proj_b": ParamSpec((b.cout,), ("conv_out",), dt, init="zeros"),
        "gn2": _gn(b.cout, dt),
        "conv2": ParamSpec((3, 3, b.cout, b.cout), (None, None, None, "conv_out"), dt),
    }
    if b.cin != b.cout:
        t["skip_proj"] = ParamSpec((1, 1, b.cin, b.cout),
                                   (None, None, None, "conv_out"), dt)
    return t


def _attn_table(b: Block, c: UNetConfig, dt):
    ch, d = b.cout, b.depth
    lead, la = (d,), ("layers",)
    heads = ch // c.head_dim
    inner = {
        "ln1_s": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="ones"),
        "ln1_b": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="zeros"),
        "self_q": ParamSpec(lead + (ch, ch), la + (None, "heads_flat"), dt),
        "self_k": ParamSpec(lead + (ch, ch), la + (None, "heads_flat"), dt),
        "self_v": ParamSpec(lead + (ch, ch), la + (None, "heads_flat"), dt),
        "self_o": ParamSpec(lead + (ch, ch), la + ("heads_flat", None), dt),
        "ln2_s": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="ones"),
        "ln2_b": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="zeros"),
        "cross_q": ParamSpec(lead + (ch, ch), la + (None, "heads_flat"), dt),
        "cross_k": ParamSpec(lead + (c.ctx_dim, ch), la + (None, "heads_flat"), dt),
        "cross_v": ParamSpec(lead + (c.ctx_dim, ch), la + (None, "heads_flat"), dt),
        "cross_o": ParamSpec(lead + (ch, ch), la + ("heads_flat", None), dt),
        "ln3_s": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="ones"),
        "ln3_b": ParamSpec(lead + (ch,), la + ("conv_out",), dt, init="zeros"),
        "ff1": ParamSpec(lead + (ch, 8 * ch), la + (None, "mlp"), dt),
        "ff2": ParamSpec(lead + (4 * ch, ch), la + ("mlp", None), dt),
    }
    del heads
    return {
        "gn": _gn(ch, dt),
        "proj_in": ParamSpec((ch, ch), (None, None), dt),
        "blocks": inner,
        "proj_out": ParamSpec((ch, ch), (None, None), dt, init="zeros"),
    }


def unet_param_table(c: UNetConfig) -> Dict[str, Any]:
    dt = c.jdtype
    down, mid, up = build_plan(c)
    t: Dict[str, Any] = {
        "conv_in": ParamSpec((3, 3, c.latent_ch, c.ch),
                             (None, None, None, "conv_out"), dt),
        "t_mlp1": ParamSpec((c.ch, c.t_dim), (None, None), dt),
        "t_mlp2": ParamSpec((c.t_dim, c.t_dim), (None, None), dt),
        "pool_proj": ParamSpec((c.ctx_dim, c.t_dim), (None, None), dt),
        "out_gn": _gn(c.ch, dt),
        "conv_out": ParamSpec((3, 3, c.ch, c.latent_ch),
                              (None, None, None, None), dt, init="zeros"),
    }
    for b in down + mid + up:
        if b.kind == "res":
            t[b.name] = _res_table(b, c, dt)
        elif b.kind == "attn":
            t[b.name] = _attn_table(b, c, dt)
        elif b.kind == "down":
            t[b.name] = ParamSpec((3, 3, b.cin, b.cout),
                                  (None, None, None, "conv_out"), dt)
        elif b.kind == "up":
            t[b.name] = ParamSpec((3, 3, b.cin, b.cout),
                                  (None, None, None, "conv_out"), dt)
    return t


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _resblock(p, x, t_emb, dt):
    h = cm.group_norm(x, p["gn1"]["s"], p["gn1"]["b"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(dt)
    h = cm.conv2d(h, p["conv1"])
    h = h + (jax.nn.silu(t_emb.astype(jnp.float32)).astype(dt)
             @ p["t_proj"] + p["t_proj_b"])[:, None, None, :]
    h = cm.group_norm(h, p["gn2"]["s"], p["gn2"]["b"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(dt)
    h = cm.conv2d(h, p["conv2"])
    skip = cm.conv2d(x, p["skip_proj"]) if "skip_proj" in p else x
    return h + skip


def _mha(q_in, kv_in, wq, wk, wv, wo, head_dim):
    b, sq, _ = q_in.shape
    h = wq.shape[-1] // head_dim
    q = (q_in @ wq).reshape(b, sq, h, head_dim)
    k = (kv_in @ wk).reshape(b, kv_in.shape[1], h, head_dim)
    v = (kv_in @ wv).reshape(b, kv_in.shape[1], h, head_dim)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(head_dim))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, -1).astype(q_in.dtype) @ wo


def _spatial_transformer(p, x, ctx, cfg: UNetConfig):
    b, hh, ww, ch = x.shape
    h = cm.group_norm(x, p["gn"]["s"], p["gn"]["b"])
    h = h.reshape(b, hh * ww, ch) @ p["proj_in"]

    def block(h, lp):
        y = cm.layer_norm(h, lp["ln1_s"], lp["ln1_b"])
        h = h + _mha(y, y, lp["self_q"], lp["self_k"], lp["self_v"],
                     lp["self_o"], cfg.head_dim)
        y = cm.layer_norm(h, lp["ln2_s"], lp["ln2_b"])
        h = h + _mha(y, ctx, lp["cross_q"], lp["cross_k"], lp["cross_v"],
                     lp["cross_o"], cfg.head_dim)
        y = cm.layer_norm(h, lp["ln3_s"], lp["ln3_b"])
        ff = y @ lp["ff1"]
        gate, val = jnp.split(ff, 2, axis=-1)
        ff = jax.nn.gelu(gate.astype(jnp.float32)).astype(h.dtype) * val
        h = h + ff @ lp["ff2"]
        return h, None

    if cfg.remat:
        block = jax.checkpoint(block)
    h, _ = lax.scan(block, h, p["blocks"])
    h = h @ p["proj_out"]
    return x + h.reshape(b, hh, ww, ch)


def make_forward(cfg: UNetConfig, mesh: Optional[Any] = None,
                 batch_axes: Optional[Tuple[str, ...]] = ("data",),
                 img_res: Optional[int] = None):
    """forward(params, latents (B,r,r,4), t (B,), ctx (B,77,2048),
    pooled (B,2048)) -> (B,r,r,4)."""
    del mesh, batch_axes, img_res
    down, mid, up = build_plan(cfg)
    dt = cfg.jdtype

    def forward(params, latents, t, ctx, pooled):
        ctx = ctx.astype(dt)
        t_emb = cm.timestep_embedding(t, cfg.ch).astype(dt)
        t_emb = jax.nn.silu((t_emb @ params["t_mlp1"]).astype(jnp.float32)
                            ).astype(dt) @ params["t_mlp2"]
        t_emb = t_emb + pooled.astype(dt) @ params["pool_proj"]

        x = cm.conv2d(latents.astype(dt), params["conv_in"])
        hs = [x]
        for b in down:
            p = params[b.name]
            if b.kind == "res":
                x = _resblock(p, x, t_emb, dt)
                hs.append(x)
            elif b.kind == "attn":
                x = _spatial_transformer(p, x, ctx, cfg)
                hs[-1] = x
            elif b.kind == "down":
                x = cm.conv2d(x, p, stride=2)
                hs.append(x)
        for b in mid:
            p = params[b.name]
            x = _resblock(p, x, t_emb, dt) if b.kind == "res" \
                else _spatial_transformer(p, x, ctx, cfg)
        for b in up:
            p = params[b.name]
            if b.kind == "res":
                x = jnp.concatenate([x, hs.pop()], axis=-1)
                x = _resblock(p, x, t_emb, dt)
            elif b.kind == "attn":
                x = _spatial_transformer(p, x, ctx, cfg)
            elif b.kind == "up":
                bsz, hh, ww, ch = x.shape
                x = jax.image.resize(x, (bsz, hh * 2, ww * 2, ch), "nearest")
                x = cm.conv2d(x, p)
        assert not hs
        x = cm.group_norm(x, params["out_gn"]["s"], params["out_gn"]["b"])
        x = jax.nn.silu(x.astype(jnp.float32)).astype(dt)
        return cm.conv2d(x, params["conv_out"])

    return forward


def make_loss_fn(cfg: UNetConfig, mesh=None, batch_axes=("data",),
                 img_res: Optional[int] = None):
    forward = make_forward(cfg, mesh, batch_axes, img_res)

    def loss_fn(params, batch):
        z0, t = batch["latents"], batch["timesteps"]
        noise = batch["noise"]
        abar = jnp.cos((t.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar = abar[:, None, None, None]
        zt = jnp.sqrt(abar) * z0 + jnp.sqrt(1 - abar) * noise
        eps_hat = forward(params, zt, t, batch["context"],
                          batch["pooled"]).astype(jnp.float32)
        loss = jnp.mean(jnp.square(eps_hat - noise))
        return loss, {"mse": loss}

    return loss_fn


def make_sample_step(cfg: UNetConfig, mesh=None, batch_axes=("data",),
                     img_res: Optional[int] = None, guidance: float = 7.5):
    forward = make_forward(cfg, mesh, batch_axes, img_res)

    def sample_step(params, zt, t, t_next, ctx, pooled):
        # CFG: null context = zeros.
        z2 = jnp.concatenate([zt, zt], axis=0)
        t2 = jnp.concatenate([t, t], axis=0)
        c2 = jnp.concatenate([ctx, jnp.zeros_like(ctx)], axis=0)
        p2 = jnp.concatenate([pooled, jnp.zeros_like(pooled)], axis=0)
        eps2 = forward(params, z2, t2, c2, p2).astype(jnp.float32)
        eps_c, eps_u = jnp.split(eps2, 2, axis=0)
        eps = eps_u + guidance * (eps_c - eps_u)
        abar = jnp.cos((t.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar_n = jnp.cos((t_next.astype(jnp.float32) / 1000.0) * jnp.pi / 2) ** 2
        abar = abar[:, None, None, None]
        abar_n = abar_n[:, None, None, None]
        z0 = (zt - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        return jnp.sqrt(abar_n) * z0 + jnp.sqrt(1 - abar_n) * eps

    return sample_step
