"""ConvNeXt (Liu et al., arXiv:2201.03545) — convnext-b.

Stages are homogeneous -> per-stage lax.scan over stacked block params.
LayerNorm (channel-last), 7x7 depthwise, 4x pointwise MLP, LayerScale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class ConvNeXtConfig:
    name: str = "convnext"
    img_res: int = 224
    depths: Tuple[int, ...] = (3, 3, 27, 3)
    dims: Tuple[int, ...] = (128, 256, 512, 1024)
    n_classes: int = 1000
    layerscale_init: float = 1e-6
    dtype: str = "bfloat16"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _block_table(n, dim, dt):
    return {
        "dw": ParamSpec((n, 7, 7, 1, dim), ("layers", None, None, None, "conv_out"), dt),
        "ln_s": ParamSpec((n, dim), ("layers", "conv_out"), dt, init="ones"),
        "ln_b": ParamSpec((n, dim), ("layers", "conv_out"), dt, init="zeros"),
        "pw1": ParamSpec((n, dim, 4 * dim), ("layers", "conv_out", "mlp"), dt),
        "pw1_b": ParamSpec((n, 4 * dim), ("layers", "mlp"), dt, init="zeros"),
        "pw2": ParamSpec((n, 4 * dim, dim), ("layers", "mlp", "conv_out"), dt),
        "pw2_b": ParamSpec((n, dim), ("layers", "conv_out"), dt, init="zeros"),
        "gamma": ParamSpec((n, dim), ("layers", "conv_out"), dt, init="ones",
                           scale=1.0),
    }


def convnext_param_table(c: ConvNeXtConfig) -> Dict[str, Any]:
    dt = c.jdtype
    t: Dict[str, Any] = {
        "stem": ParamSpec((4, 4, 3, c.dims[0]), (None, None, None, "conv_out"), dt),
        "stem_ln_s": ParamSpec((c.dims[0],), ("conv_out",), dt, init="ones"),
        "stem_ln_b": ParamSpec((c.dims[0],), ("conv_out",), dt, init="zeros"),
    }
    for i, (d, dim) in enumerate(zip(c.depths, c.dims)):
        t[f"stage{i}"] = _block_table(d, dim, dt)
        if i < len(c.depths) - 1:
            t[f"down{i}_ln_s"] = ParamSpec((dim,), ("conv_out",), dt, init="ones")
            t[f"down{i}_ln_b"] = ParamSpec((dim,), ("conv_out",), dt, init="zeros")
            t[f"down{i}"] = ParamSpec((2, 2, dim, c.dims[i + 1]),
                                      (None, None, None, "conv_out"), dt)
    t["final_ln_s"] = ParamSpec((c.dims[-1],), ("conv_out",), dt, init="ones")
    t["final_ln_b"] = ParamSpec((c.dims[-1],), ("conv_out",), dt, init="zeros")
    t["head"] = ParamSpec((c.dims[-1], c.n_classes), (None, "vocab"), dt)
    t["head_bias"] = ParamSpec((c.n_classes,), (None,), dt, init="zeros")
    return t


def _block(x, lp, ls_init):
    y = cm.depthwise_conv2d(x, lp["dw"])
    y = cm.layer_norm(y, lp["ln_s"], lp["ln_b"])
    y = jnp.einsum("bhwc,cf->bhwf", y, lp["pw1"]) + lp["pw1_b"]
    y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bhwf,fc->bhwc", y, lp["pw2"]) + lp["pw2_b"]
    return x + (ls_init * lp["gamma"]) * y


def make_forward(cfg: ConvNeXtConfig, mesh=None, batch_axes=("data",),
                 training: bool = False):
    del training

    def forward(params, images):
        x = cm.conv2d(images.astype(cfg.jdtype), params["stem"], stride=4,
                      padding="VALID")
        x = cm.layer_norm(x, params["stem_ln_s"], params["stem_ln_b"])
        for i in range(len(cfg.depths)):
            def body(x, lp):
                return _block(x, lp, cfg.layerscale_init), None
            x, _ = lax.scan(body, x, params[f"stage{i}"])
            if i < len(cfg.depths) - 1:
                x = cm.layer_norm(x, params[f"down{i}_ln_s"],
                                  params[f"down{i}_ln_b"])
                x = cm.conv2d(x, params[f"down{i}"], stride=2, padding="VALID")
        x = jnp.mean(x, axis=(1, 2))
        x = cm.layer_norm(x, params["final_ln_s"], params["final_ln_b"])
        return x @ params["head"] + params["head_bias"]

    return forward


def make_loss_fn(cfg: ConvNeXtConfig, mesh=None, batch_axes=("data",)):
    forward = make_forward(cfg, mesh, batch_axes, training=True)

    def loss_fn(params, batch):
        logits = forward(params, batch["images"]).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        nll = jnp.mean(logz - gold)
        return nll, {"nll": nll}

    return loss_fn
