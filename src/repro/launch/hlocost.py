"""Corrected per-device cost model parsed from post-SPMD optimized HLO.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (scan-over-
layers would be undercounted by the layer count), so we parse the HLO
text ourselves:

  * computations are segmented; ``while`` ops carry
    ``backend_config known_trip_count`` -> call edges with multipliers;
    fusions/calls are x1 edges; conditionals take the max branch.
  * FLOPs: dot (2 * out_elems * contracted_elems) and convolution
    (2 * out_elems * prod(kernel)/cout) — the MXU terms. Elementwise ops
    ride along with the memory term.
  * HBM traffic: per top-level instruction, operand bytes + output bytes
    (fusion nodes count their boundary only — internals live in
    registers/VMEM, which matches how a fused TPU kernel touches HBM).
  * Collective wire bytes per device, ring-derated: all-gather /
    reduce-scatter / all-to-all move (g-1)/g of the gathered/scattered
    bytes for group size g; all-reduce moves 2x that; collective-permute
    moves its full payload.

Everything is per-device: the HLO module is the per-device SPMD program.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_COMP_HEAD = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s+\((.*)\)\s+->")
_ASSIGN = re.compile(r"^\s+(?:ROOT )?%?([\w\.\-]+)\s+=\s+(.*)$")
_OP = re.compile(r"([\w\-]+)\(")
_PARAM = re.compile(r"([\w\.\-]+):\s+((?:\([^)]*\))|[^,()]+(?:\[[\d,]*\])?(?:\{[\d,]*\})?)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r"known_trip_count\":\{\"n\":\"(\d+)\"")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES.get(dt, 4)
    return elems, byts


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Comp:
    flops: float = 0.0
    traffic: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    calls: List[Tuple[str, float]] = field(default_factory=list)
    branch_sets: List[List[str]] = field(default_factory=list)


@dataclass
class HloCost:
    flops: float
    traffic_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "bitcast-convert", "after-all", "partition-id",
                 "replica-id", "iota", "reshape"}


def parse_hlo(text: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    symtab: Dict[str, str] = {}
    for raw in text.splitlines():
        head = _COMP_HEAD.match(raw)
        if head and raw.rstrip().endswith("{"):
            cur = Comp()
            comps[head.group(1)] = cur
            symtab = {}
            for pname, ptype in _PARAM.findall(head.group(2)):
                symtab[pname] = ptype
            continue
        if cur is None:
            continue
        m = _ASSIGN.match(raw)
        if not m:
            continue
        var, rhs = m.groups()
        opm = _OP.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        vtype = rhs[:opm.start()].strip()
        rest = rhs[opm.end():]
        symtab[var] = vtype

        if op == "dot":
            out_elems, _ = _shape_elems_bytes(vtype)
            lhs_m = _OPERANDS.search(rest)
            contract = 1
            if lhs_m:
                lhs_type = symtab.get(lhs_m.group(1), "")
                ldims = _dims_of(lhs_type)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                if cm and ldims:
                    for i in cm.group(1).split(","):
                        if i:
                            contract *= ldims[int(i)]
            cur.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems, _ = _shape_elems_bytes(vtype)
            ops = _OPERANDS.findall(rest)
            if len(ops) >= 2:
                rdims = _dims_of(symtab.get(ops[1], ""))
                odims = _dims_of(vtype)
                dl = re.search(r"dim_labels=\S*_(\S*?)->(\S*)", rest)
                cout = 1
                if dl and rdims:
                    o_pos = dl.group(1).replace("\"", "").find("o")
                    if 0 <= o_pos < len(rdims):
                        cout = rdims[o_pos]
                k = (math.prod(rdims) / max(cout, 1)) if rdims else 1
                cur.flops += 2.0 * out_elems * k
        elif op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", rest)
            trip = _TRIP.search(rest)
            n = float(trip.group(1)) if trip else 1.0
            if body:
                cur.calls.append((body.group(1), n))
        elif op == "fusion" or op == "call" or op == "async-start":
            callee = re.search(r"(?:calls|to_apply|called_computations)=\{?%?([\w\.\-]+)", rest)
            if callee:
                cur.calls.append((callee.group(1), 1.0))
        elif op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", rest)
            if branches:
                names = re.findall(r"%?([\w\.\-]+)", branches[0])
                cur.branch_sets.append(names)
            else:
                tb = re.search(r"true_computation=%?([\w\.\-]+)", rest)
                fb = re.search(r"false_computation=%?([\w\.\-]+)", rest)
                if tb and fb:
                    cur.branch_sets.append([tb.group(1), fb.group(1)])
        elif op in ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "all-gather-start",
                    "all-reduce-start", "collective-permute-start"):
            kind = op.replace("-start", "")
            _, out_b = _shape_elems_bytes(vtype)
            g = None
            gm = _GROUPS.search(rest)
            if gm:
                g = int(gm.group(2))
            else:
                ge = _GROUPS_EXPL.search(rest)
                if ge:
                    first = ge.group(1).split("}")[0]
                    g = len([x for x in first.replace("{", "").split(",") if x.strip()])
            g = g or 1
            derate = (g - 1) / g if g > 1 else 0.0
            if kind == "all-reduce":
                wire = 2.0 * out_b * derate
            elif kind == "collective-permute":
                wire = float(out_b)
            else:
                # all-gather: out is the gathered buffer; reduce-scatter:
                # out is the scattered shard (wire moves the big buffer).
                if kind == "reduce-scatter":
                    wire = out_b * g * derate
                else:
                    wire = out_b * derate
            cur.coll[kind] = cur.coll.get(kind, 0.0) + wire

        # HBM traffic: boundary bytes of every real instruction.
        if op in ("dynamic-update-slice", "scatter"):
            # In-place update (donated/aliased buffers): traffic is the
            # updated region (read+write), not the whole target buffer —
            # e.g. a KV-cache append touches one token column, not 5 GB.
            ops_ = _OPERANDS.findall(rest.split(", metadata=")[0])
            upd_b = 0
            if len(ops_) >= 2 and ops_[1] in symtab:
                _, upd_b = _shape_elems_bytes(symtab[ops_[1]])
            cur.traffic += 2.0 * upd_b
        elif op not in _SKIP_TRAFFIC:
            _, out_b = _shape_elems_bytes(vtype)
            in_b = 0
            for o in _OPERANDS.findall(rest.split(", metadata=")[0])[:12]:
                if o in symtab:
                    _, ob = _shape_elems_bytes(symtab[o])
                    in_b += ob
            cur.traffic += out_b + in_b
    return comps


def resolve(comps: Dict[str, Comp], entry: str) -> HloCost:
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, {})
        # Fusion-internal instructions never touch HBM individually — the
        # caller's fusion node already accounts the boundary bytes.
        internal = name.startswith(("fused_computation", "wrapped_")) \
            or ".fused_computation" in name
        f, t = c.flops, (0.0 if internal else c.traffic)
        coll = dict(c.coll)
        for callee, n in c.calls:
            cf, ct, cc = total(callee, depth + 1)
            f += n * cf
            t += n * ct
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + n * v
        for branches in c.branch_sets:
            best = (0.0, 0.0, {})
            for b in branches:
                cand = total(b, depth + 1)
                if cand[0] >= best[0]:
                    best = cand
            f += best[0]
            t += best[1]
            for k, v in best[2].items():
                coll[k] = coll.get(k, 0.0) + v
        memo[name] = (f, t, coll)
        return memo[name]

    f, t, coll = total(entry)
    return HloCost(flops=f, traffic_bytes=t, collective_bytes=coll)


def cost_from_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry_m = re.search(r"^ENTRY %?([\w\.\-]+)", text, flags=re.M)
    if not entry_m:
        raise ValueError("no ENTRY computation found")
    return resolve(comps, entry_m.group(1))
