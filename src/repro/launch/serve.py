"""Serving driver: the paper's full pipeline over synthetic hazy streams.

Spout -> dehaze workers (jitted component chain) -> monitor (reorder +
timeout skip) -> sink, with per-stream EMA state, elastic resize and
stream-state checkpointing.

Single stream:
  PYTHONPATH=src python -m repro.launch.serve --algorithm dcp \
      --resolution 480p --frames 96 --workers 3 --batch 8

Multi-tenant (N videos continuously batched over L device lanes):
  PYTHONPATH=src python -m repro.launch.serve --streams 4 --lanes 4 \
      --resolution 120p --frames 32

Elastic autoscaling (lane count walks a precompiled ladder under load;
--ramp staggers stream lengths so the burst forces a grow and the long
tail a shrink — the CI smoke leg asserts the switches happened):
  PYTHONPATH=src python -m repro.launch.serve --streams 6 --lanes 4 \
      --autoscale --ladder 2,4 --ramp --expect-switches 2

Fleet serving (2 simulated hosts x 4 lanes behind one global-EDF front
door; sticky placement keeps every stream's EMA on one host, overflow
spills to the other — the CI smoke leg asserts >= 1 spillover):
  PYTHONPATH=src python -m repro.launch.serve --streams 8 --hosts 2 \
      --lanes 4 --resolution 120p --frames 32 --expect-spillover 1
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import DehazeConfig
from repro.data import HazeVideoSpec, generate_haze_video
from repro.kernels import ref as kref
from repro.stream import (ElasticServer, ScalePolicy, StreamRequest,
                          ladder_rungs)

RESOLUTIONS = {"120p": (120, 160), "240p": (240, 320), "480p": (480, 640),
               "576p": (576, 1024)}


def _make_videos(n: int, h: int, w: int, frames, seed0: int = 100):
    """N synthetic videos with distinct scenes + base atmospheric lights,
    so each lane exercises its own coherence trajectory. ``frames`` is an
    int or a per-stream list (the --ramp workload)."""
    lengths = frames if isinstance(frames, (list, tuple)) else [frames] * n
    vids = []
    for i in range(n):
        base = 0.75 + 0.05 * (i % 4)
        vids.append(generate_haze_video(HazeVideoSpec(
            height=h, width=w, n_frames=lengths[i], seed=seed0 + i,
            a_noise=0.0, a_base=(base, base, min(1.0, base + 0.02)))))
    return vids


def _wire_hazy(vid, io_dtype: str) -> np.ndarray:
    """The stream actually put on the wire: the synthetic f32 hazy video
    quantized/cast to the serving ingest dtype (no-op for float32)."""
    if io_dtype == "float32":
        return vid.hazy
    return kref.quantize_frames(vid.hazy, io_dtype)


def _print_tick_io(rep) -> None:
    """One line of tick-I/O accounting (README §Tick I/O & overlap):
    how many ticks took the zero-copy path, the valid-only D2H volume,
    and where the tick wall went (host staging / device step / deliver)."""
    ph = rep.phases or {}
    phase_txt = " ".join(f"{k}={ph[k] * 1e3:.1f}ms" for k in sorted(ph))
    print(f"tick_io: overlap_ticks={rep.overlap_ticks}/{rep.ticks} "
          f"d2h_bytes={rep.d2h_bytes} stragglers={rep.stragglers}"
          + (f" {phase_txt}" if phase_txt else ""))


def _gate_overlap(args, rep) -> None:
    """--expect-overlap: a serve that expects the zero-copy tick path
    cannot tolerate a silent fallback to the blocking oracle (donation
    probe failing, env knob ignored) — that is exactly the regression
    the CI overlap leg exists to catch."""
    if args.expect_overlap and rep.overlap_ticks < rep.ticks:
        print(f"FAIL: expected every tick on the overlapped path, got "
              f"{rep.overlap_ticks}/{rep.ticks} (silent fallback to the "
              f"blocking path)", file=sys.stderr)
        sys.exit(1)


def _serve_single(args, cfg, h: int, w: int) -> int:
    vid = _make_videos(1, h, w, args.frames)[0]
    hazy = _wire_hazy(vid, args.io_dtype)
    srv = ElasticServer(cfg, n_workers=args.workers, batch=args.batch,
                        timeout_s=args.timeout_ms / 1e3)
    outs = {}
    t0 = time.perf_counter()
    rep = srv.serve(iter(hazy), sink=lambda fid, f: outs.setdefault(fid, f))
    wall = time.perf_counter() - t0

    got = np.stack([np.asarray(outs[k], np.float32) for k in sorted(outs)])
    err_hazy = np.abs(vid.hazy[:len(got)] - vid.clear[:len(got)]).mean()
    err_out = np.abs(got - vid.clear[sorted(outs)]).mean()
    print(f"algorithm={args.algorithm} resolution={args.resolution} "
          f"workers={rep.n_workers}")
    print(f"frames={rep.frames} skipped={rep.skipped} "
          f"fps={rep.fps:.2f} wall={wall:.2f}s")
    _print_tick_io(rep)
    print(f"L1 vs ground truth: hazy={err_hazy:.4f} dehazed={err_out:.4f}")
    a = srv.store.get("default").A
    print(f"final shared A = {np.asarray(a)}")
    _gate_overlap(args, rep)
    return rep.skipped


def _serve_many(args, cfg, h: int, w: int) -> int:
    if args.ramp:
        # Burst of short clips, then long tails: queue depth forces a
        # ladder grow, the drained tail forces a shrink.
        n_long = min(2, args.streams)
        lengths = [max(args.batch, args.frames // 4)] \
            * (args.streams - n_long) + [args.frames] * n_long
    else:
        lengths = [args.frames] * args.streams
    vids = _make_videos(args.streams, h, w, lengths)
    wires = [_wire_hazy(v, args.io_dtype) for v in vids]
    lanes = args.lanes if args.lanes > 0 else args.streams
    srv = ElasticServer(cfg, batch=args.batch,
                        timeout_s=args.timeout_ms / 1e3)
    counts: dict = {}
    cam0_out: dict = {}

    def sink(sid: str, fid: int, f) -> None:
        counts[sid] = counts.get(sid, 0) + 1
        if sid == "cam0":
            cam0_out[fid] = np.asarray(f, np.float32)

    policy = None
    if args.autoscale:
        rungs = tuple(int(r) for r in args.ladder.split(","))
        policy = ScalePolicy(rungs=rungs, dwell_up=1, dwell_down=2)
        # Prime every rung's executable so the smoke run's switches gate
        # on load, not on compile latency racing short streams.
        warm = _make_videos(1, h, w, args.batch, seed0=90)[0]
        for r in ladder_rungs(rungs, lanes):
            srv.serve_many([StreamRequest(f"_warm{r}", iter(warm.hazy))],
                           n_lanes=r)

    rep = srv.serve_many(
        [StreamRequest(f"cam{i}", iter(wire))
         for i, wire in enumerate(wires)],
        n_lanes=lanes, sink=sink, autoscale=args.autoscale, policy=policy,
        n_hosts=args.hosts)
    print(f"algorithm={args.algorithm} resolution={args.resolution} "
          f"streams={args.streams} lanes={rep.n_lanes} batch={args.batch} "
          f"hosts={rep.n_hosts}")
    print(f"frames={rep.frames} skipped={rep.skipped} ticks={rep.ticks} "
          f"aggregate_fps={rep.aggregate_fps:.2f} wall={rep.wall_s:.2f}s")
    _print_tick_io(rep)
    if args.hosts > 1:
        print(f"spillovers={rep.spillovers} migrations={rep.migrations}")
        if rep.migrations != 0:
            print(f"FAIL: sticky placement violated — {rep.migrations} EMA "
                  f"migration(s)", file=sys.stderr)
            sys.exit(1)
    if args.autoscale:
        print(f"ladder_switches={rep.ladder_switches} "
              f"switch_wall={rep.switch_wall_s * 1e3:.1f}ms "
              f"evictions={rep.evictions} final_lanes={rep.n_lanes} "
              f"warm_failures={rep.warm_failures}")
        if args.expect_switches and rep.warm_failures:
            # A serve that *expects* ladder switches cannot tolerate part
            # of the ladder silently failing to warm — that is exactly the
            # bug class where the fleet never scales and nobody notices.
            print(f"FAIL: {rep.warm_failures} ladder rung(s) failed to "
                  f"warm (retried once); the expected switches cannot be "
                  f"trusted", file=sys.stderr)
            sys.exit(1)
    for sid in sorted(rep.per_stream):
        if sid.startswith("_warm"):
            continue
        r = rep.per_stream[sid]
        a = np.asarray(srv.store.get(sid).A).round(3)
        print(f"  {sid}: frames={r.frames} emitted={counts.get(sid, 0)} "
              f"skipped={r.skipped} fps={r.fps:.2f} A={a}")
    if rep.ladder_switches < args.expect_switches:
        print(f"FAIL: expected >= {args.expect_switches} ladder switches, "
              f"got {rep.ladder_switches}", file=sys.stderr)
        sys.exit(1)
    if rep.spillovers < args.expect_spillover:
        print(f"FAIL: expected >= {args.expect_spillover} spillover "
              f"admission(s), got {rep.spillovers}", file=sys.stderr)
        sys.exit(1)
    _gate_overlap(args, rep)
    if args.io_dtype != "float32" and cam0_out:
        # Non-f32 wire dtype: replay cam0 alone through a fresh server
        # (same config, same quantized stream) and gate on parity — the
        # multi-tenant lane path must dehaze a uint8/bf16 stream exactly
        # as the single-stream path does.
        ref_srv = ElasticServer(cfg, batch=args.batch,
                                timeout_s=args.timeout_ms / 1e3)
        ref_out: dict = {}
        ref_srv.serve(iter(wires[0]), stream_id="cam0",
                      sink=lambda fid, f: ref_out.setdefault(
                          fid, np.asarray(f, np.float32)))
        common = sorted(set(cam0_out) & set(ref_out))
        drift = max((np.abs(cam0_out[k] - ref_out[k]).max()
                     for k in common), default=0.0)
        print(f"io_dtype={args.io_dtype} parity(cam0): "
              f"frames={len(common)} maxerr={drift:.2e}")
        if not common or drift > 1e-5:
            print(f"FAIL: cam0 parity drift {drift:.2e} > 1e-5 between the "
                  f"lane-batched and single-stream serves at "
                  f"io_dtype={args.io_dtype}", file=sys.stderr)
            sys.exit(1)
    return rep.skipped


def _tune_for_serve(args, h: int, w: int) -> None:
    """--tune: measured-search the tile space for *this serve's* shapes
    before serving, so the run resolves freshly measured winners for the
    current device kind instead of defaults (or a stale table)."""
    from repro.kernels import tuning

    stats = tuning.TuneStats()
    kw = dict(method="search", persist=True, stats=stats)
    tuning.autotune_fused(shapes=((args.batch, h, w),),
                          algorithms=(args.algorithm,), topks=(1,),
                          io_dtypes=(args.io_dtype,), **kw)
    if args.streams > 1:
        lanes = args.lanes if args.lanes > 0 else args.streams
        tuning.autotune_fused_lanes(
            shapes=((lanes, args.batch, h, w),), **kw)
    print(f"tune: device_kind={tuning.device_kind()} "
          f"table={tuning.table_path()} timed_runs={stats.timed_runs} "
          f"(exhaustive would be {stats.exhaustive_runs}) "
          f"skipped={stats.skipped}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="dcp", choices=["dcp", "cap"])
    ap.add_argument("--resolution", default="240p",
                    choices=sorted(RESOLUTIONS))
    ap.add_argument("--frames", type=int, default=64,
                    help="frames per stream")
    ap.add_argument("--streams", type=int, default=1,
                    help="number of concurrent videos (>1 uses the "
                         "lane-batched multi-tenant scheduler)")
    ap.add_argument("--lanes", type=int, default=0,
                    help="device lanes for --streams > 1 "
                         "(default 0 = one lane per stream; per-host count "
                         "when --hosts > 1)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulated fleet hosts: >1 serves through the "
                         "FleetScheduler (global EDF, sticky placement, "
                         "spillover admission)")
    ap.add_argument("--expect-spillover", type=int, default=0,
                    help="exit nonzero unless at least this many spillover "
                         "admissions happened (CI fleet gating)")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic lane count: --lanes becomes the cap and "
                         "the fleet walks the --ladder under load")
    ap.add_argument("--ladder", default="4,8,16,32",
                    help="comma-separated lane-count rungs (capped by "
                         "--lanes)")
    ap.add_argument("--ramp", action="store_true",
                    help="stagger stream lengths (short burst + long "
                         "tails) to force a grow and a shrink")
    ap.add_argument("--expect-switches", type=int, default=0,
                    help="exit nonzero unless at least this many ladder "
                         "switches were committed (CI autoscale gating)")
    ap.add_argument("--timeout-ms", type=float, default=20.0,
                    help="monitor reader timeout (paper: 20 ms)")
    ap.add_argument("--update-period", type=int, default=8)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--kernel-mode", default="auto")
    ap.add_argument("--io-dtype", default="float32",
                    choices=["float32", "bfloat16", "uint8"],
                    help="wire dtype of the frame streams: the synthetic "
                         "videos are quantized host-side and stay at this "
                         "dtype through spout/scheduler to the kernels "
                         "(uint8 = 4x less ingest traffic). With "
                         "--streams > 1 a non-f32 run also replays cam0 "
                         "single-stream and fails on parity drift")
    ap.add_argument("--tune", action="store_true",
                    help="run the successive-halving measured search for "
                         "this serve's exact shapes/dtype first (winners "
                         "persist under the current device kind in the "
                         "tuning table), then serve with them")
    ap.add_argument("--expect-overlap", action="store_true",
                    help="exit nonzero unless every tick took the "
                         "zero-copy overlapped path (pair with "
                         "REPRO_TICK_OVERLAP=1; CI gating against a "
                         "silent fallback to the blocking path)")
    ap.add_argument("--fail-on-skipped", action="store_true",
                    help="exit nonzero if any frame was timeout-skipped "
                         "(CI smoke gating)")
    args = ap.parse_args()

    h, w = RESOLUTIONS[args.resolution]
    cfg = DehazeConfig(algorithm=args.algorithm,
                       update_period=args.update_period, lam=args.lam,
                       kernel_mode=args.kernel_mode,
                       io_dtype=args.io_dtype)
    if args.tune:
        _tune_for_serve(args, h, w)
    if args.streams > 1:
        if args.workers != ap.get_default("workers"):
            print("note: --workers applies to single-stream serving only; "
                  "the multi-stream scheduler parallelizes over --lanes "
                  "instead", file=sys.stderr)
        skipped = _serve_many(args, cfg, h, w)
    else:
        skipped = _serve_single(args, cfg, h, w)
    if args.fail_on_skipped and skipped > 0:
        print(f"FAIL: {skipped} frame(s) timeout-skipped", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
