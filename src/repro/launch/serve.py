"""Serving driver: the paper's full pipeline over a synthetic hazy stream.

Spout -> dehaze workers (jitted component chain) -> monitor (reorder +
timeout skip) -> sink, with per-stream EMA state, elastic resize and
stream-state checkpointing.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --algorithm dcp \
      --resolution 480p --frames 96 --workers 3 --batch 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import DehazeConfig
from repro.data import HazeVideoSpec, generate_haze_video
from repro.stream import ElasticServer

RESOLUTIONS = {"240p": (240, 320), "480p": (480, 640), "576p": (576, 1024)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="dcp", choices=["dcp", "cap"])
    ap.add_argument("--resolution", default="240p",
                    choices=sorted(RESOLUTIONS))
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--timeout-ms", type=float, default=20.0,
                    help="monitor reader timeout (paper: 20 ms)")
    ap.add_argument("--update-period", type=int, default=8)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--kernel-mode", default="auto")
    args = ap.parse_args()

    h, w = RESOLUTIONS[args.resolution]
    vid = generate_haze_video(HazeVideoSpec(
        height=h, width=w, n_frames=args.frames, a_noise=0.0))
    cfg = DehazeConfig(algorithm=args.algorithm,
                       update_period=args.update_period, lam=args.lam,
                       kernel_mode=args.kernel_mode)
    srv = ElasticServer(cfg, n_workers=args.workers, batch=args.batch,
                        timeout_s=args.timeout_ms / 1e3)

    outs = {}
    t0 = time.perf_counter()
    rep = srv.serve(iter(vid.hazy), sink=lambda fid, f: outs.setdefault(fid, f))
    wall = time.perf_counter() - t0

    got = np.stack([outs[k] for k in sorted(outs)])
    err_hazy = np.abs(vid.hazy[:len(got)] - vid.clear[:len(got)]).mean()
    err_out = np.abs(got - vid.clear[sorted(outs)]).mean()
    print(f"algorithm={args.algorithm} resolution={args.resolution} "
          f"workers={rep.n_workers}")
    print(f"frames={rep.frames} skipped={rep.skipped} "
          f"fps={rep.fps:.2f} wall={wall:.2f}s")
    print(f"L1 vs ground truth: hazy={err_hazy:.4f} dehazed={err_out:.4f}")
    a = srv.store.get("default").A
    print(f"final shared A = {np.asarray(a)}")


if __name__ == "__main__":
    main()
