"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (not module state) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the pod
    axis is an outer data axis (batch shards over pod x data, gradient
    all-reduce crosses pods once per step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = data * model
    devs = jax.devices()[:n]
    assert len(devs) == n, f"need {n} devices, have {len(jax.devices())}"
    return jax.sharding.Mesh(
        __import__("numpy").array(devs).reshape(data, model),
        ("data", "model"))


def batch_axes_for(mesh: jax.sharding.Mesh, batch: int
                   ) -> Optional[Tuple[str, ...]]:
    """Largest prefix of (pod, data) that divides ``batch``; None if no
    non-empty prefix divides (then the batch stays replicated)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    # Longest dividing prefix first: (pod, data), then (pod,) / (data,)
    # for a batch divisible by the outer axis but not the full product.
    for end in range(len(axes), 0, -1):
        prefix = axes[:end]
        size = 1
        for a in prefix:
            size *= mesh.shape[a]
        if batch % size == 0:
            return tuple(prefix)
    return None
