"""Cell builder: (arch x shape x mesh) -> loweable jitted step.

A *cell* is one entry of the assigned architecture x input-shape grid.
``build_cell`` returns everything ``dryrun.py`` (and train.py/serve.py)
needs: the step function, allocation-free argument ShapeDtypeStructs, the
matching NamedSharding trees, donation hints, and the analytic
MODEL_FLOPS terms for the roofline table.

Sharding policy (DESIGN.md §3):
  - batch over (pod, data) when divisible (else data, else replicated);
  - TP over "model" per the logical-axis rules of each param table,
    with LM head padding to the model-axis size;
  - LM residual stream sequence-sharded over "model" between layers
    (memory: remat-saved activations drop by the TP degree);
  - optimizer moments ZeRO-1 sharded: params' specs plus a "data" axis on
    the first still-unsharded divisible dimension;
  - KV caches: batch over data axes, kv heads over "model".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as cfgreg
from repro.launch import flops as flops_mod
from repro.launch.mesh import batch_axes_for
from repro.models import common as cm
from repro.models import steps as steps_mod
from repro.optim import AdamWState, adamw_init, cosine_schedule


class CellSkip(Exception):
    """Raised when an (arch, shape) cell is a documented skip."""


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str                    # train | prefill | decode | sample | serve | dehaze
    step_fn: Callable
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    model_flops: float           # traced ideal FLOPs per step execution
    six_nd: Optional[float]      # brief's 6·N·D / 2·N·D convention (LM/DiT)
    steps_multiplier: int = 1    # e.g. sampler steps for diffusion inference
    note: str = ""


def _shard(mesh: Mesh, tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _zero1_pspecs(table, pspecs, data_axes: Tuple[str, ...], n_data: int):
    """Moment specs: param specs + 'data' on the first unsharded divisible
    dim (ZeRO-1 optimizer-state sharding)."""

    def one(spec: cm.ParamSpec, ps: P):
        parts = list(ps) + [None] * (len(spec.shape) - len(ps))
        for i, (dim, cur) in enumerate(zip(spec.shape, parts)):
            if cur is None and dim % n_data == 0 and dim >= n_data:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return P(*parts)

    return jax.tree.map(one, table, pspecs,
                        is_leaf=lambda x: isinstance(x, cm.ParamSpec))


def _opt_shapes_and_shardings(table, mesh, data_axes, rules=None):
    params_sh = cm.param_shapes(table)
    opt_shapes = jax.eval_shape(adamw_init, params_sh)
    pspecs = cm.param_pspecs(table, rules=rules, mesh=mesh)
    if data_axes:
        n_data = math.prod(mesh.shape[a] for a in data_axes)
        mspecs = _zero1_pspecs(table, pspecs, data_axes, n_data)
    else:
        mspecs = pspecs
    opt_specs = AdamWState(step=P(), mu=mspecs, nu=mspecs)
    return params_sh, opt_shapes, pspecs, opt_specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id, shape_name, shape, mesh,
             overrides: Optional[Dict] = None) -> Cell:
    from repro.models import transformer as T
    mod = cfgreg.get_module(arch_id)
    n_model = mesh.shape.get("model", 1)
    bt = batch_axes_for(mesh, shape["global_batch"])
    cfg: T.LMConfig = mod.config(pad_heads_to=n_model, **(overrides or {}))
    ref_cfg: T.LMConfig = mod.config(remat=False)      # unpadded reference
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]
    table = T.lm_param_table(cfg)
    rules = T.lm_rules(cfg)
    pspecs = cm.param_pspecs(table, rules=rules, mesh=mesh)
    params_sh = cm.param_shapes(table)

    n_active = cfg.active_param_count() - cfg.vocab * cfg.d_model

    if kind == "train":
        params_sh, opt_shapes, pspecs, opt_specs = _opt_shapes_and_shardings(
            table, mesh, bt, rules=rules)
        loss_fn = T.make_loss_fn(cfg, mesh, bt)
        step = steps_mod.make_train_step(
            loss_fn, cosine_schedule(3e-4, 100, 1000),
            microbatches=cfg.microbatch,
            accum_shardings=(_shard(mesh, opt_specs.mu)
                             if cfg.microbatch > 1 else None))
        batch_sh = {"tokens": _sds((B, S), jnp.int32),
                    "labels": _sds((B, S), jnp.int32)}
        batch_spec = {"tokens": P(bt, None), "labels": P(bt, None)}
        ref_loss = T.make_loss_fn(ref_cfg, None, None)
        mf = flops_mod.traced_flops(
            lambda p, b: jax.grad(lambda pp: ref_loss(pp, b)[0])(p),
            cm.param_shapes(T.lm_param_table(ref_cfg)), batch_sh)
        return Cell(arch_id, shape_name, kind, step,
                    (params_sh, opt_shapes, batch_sh),
                    (_shard(mesh, pspecs), _shard(mesh, opt_specs),
                     _shard(mesh, batch_spec)),
                    donate_argnums=(0, 1),
                    model_flops=mf, six_nd=6.0 * n_active * B * S)

    if kind == "prefill":
        step = T.make_prefill(cfg, mesh, bt)
        toks = _sds((B, S), jnp.int32)
        mf = flops_mod.traced_flops(
            T.make_prefill(ref_cfg, None, None),
            cm.param_shapes(T.lm_param_table(ref_cfg)), toks)
        return Cell(arch_id, shape_name, kind, step, (params_sh, toks),
                    (_shard(mesh, pspecs), _shard(mesh, P(bt, None))),
                    donate_argnums=(),
                    model_flops=mf, six_nd=2.0 * n_active * B * S)

    if kind == "decode":
        if cfg.decode_seq_shard:
            # Flash-decoding mode: heads replicated (no TP padding), KV
            # cache sequence-sharded over the model axis.
            cfg = mod.config(pad_heads_to=1, **(overrides or {}))
            rules = dict(T.lm_rules(cfg), heads=None, kv_heads=None)
            table = T.lm_param_table(cfg)
            pspecs = cm.param_pspecs(table, rules=rules, mesh=mesh)
            params_sh = cm.param_shapes(table)
            cache_spec = {"k": P(None, bt, "model", None, None),
                          "v": P(None, bt, "model", None, None),
                          "pos": P()}
        else:
            cache_spec = T.cache_pspecs(cfg, bt)
        step = T.make_decode_step(cfg, mesh, bt)
        cache_sh = T.cache_shapes(cfg, B, S)
        toks = _sds((B, 1), jnp.int32)
        ref_cache = T.cache_shapes(ref_cfg, B, S)
        mf = flops_mod.traced_flops(
            T.make_decode_step(ref_cfg, None, None),
            cm.param_shapes(T.lm_param_table(ref_cfg)), ref_cache, toks)
        return Cell(arch_id, shape_name, kind, step,
                    (params_sh, cache_sh, toks),
                    (_shard(mesh, pspecs), _shard(mesh, cache_spec),
                     _shard(mesh, P(bt, None))),
                    donate_argnums=(1,),
                    model_flops=mf, six_nd=2.0 * n_active * B)

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Diffusion cells
# ---------------------------------------------------------------------------

def _diffusion_cell(arch_id, shape_name, shape, mesh) -> Cell:
    mod = cfgreg.get_module(arch_id)
    B, R = shape["batch"], shape["img_res"]
    bt = batch_axes_for(mesh, shape["batch"])
    kind = shape["kind"]
    lat = R // 8
    steps_mult = 1 if kind == "train" else shape["steps"]

    if arch_id == "dit-l2":
        from repro.models import dit as M
        cfg = mod.config()
        ref_cfg = mod.config(remat=False, dtype="float32")
        table = M.dit_param_table(cfg)
        batch_sh = {"latents": _sds((B, lat, lat, 4), jnp.float32),
                    "timesteps": _sds((B,), jnp.int32),
                    "labels": _sds((B,), jnp.int32),
                    "noise": _sds((B, lat, lat, 4), jnp.float32)}
        batch_spec = {"latents": P(bt, None, None, None),
                      "timesteps": P(bt), "labels": P(bt),
                      "noise": P(bt, None, None, None)}
        if kind == "train":
            loss = M.make_loss_fn(cfg, mesh, bt, img_res=R)
            ref_loss = M.make_loss_fn(ref_cfg, None, None, img_res=R)
            sample_args = None
        else:
            step_fn = M.make_sample_step(cfg, mesh, bt, img_res=R)
            ref_fn = M.make_sample_step(ref_cfg, None, None, img_res=R)
            sample_args = ({"zt": _sds((B, lat, lat, 4), jnp.float32),
                            "t": _sds((B,), jnp.int32),
                            "t_next": _sds((B,), jnp.int32),
                            "y": _sds((B,), jnp.int32)},
                           {"zt": P(bt, None, None, None), "t": P(bt),
                            "t_next": P(bt), "y": P(bt)})

            def step(params, a):
                return step_fn(params, a["zt"], a["t"], a["t_next"], a["y"])

            def ref_step(params, a):
                return ref_fn(params, a["zt"], a["t"], a["t_next"], a["y"])
        ref_table = M.dit_param_table(ref_cfg)
    else:  # unet-sdxl
        from repro.models import unet as M
        cfg = mod.config(img_res=R)
        ref_cfg = mod.config(img_res=R, remat=False, dtype="float32")
        table = M.unet_param_table(cfg)
        batch_sh = {"latents": _sds((B, lat, lat, 4), jnp.float32),
                    "timesteps": _sds((B,), jnp.int32),
                    "noise": _sds((B, lat, lat, 4), jnp.float32),
                    "context": _sds((B, cfg.ctx_len, cfg.ctx_dim), jnp.float32),
                    "pooled": _sds((B, cfg.ctx_dim), jnp.float32)}
        batch_spec = {"latents": P(bt, None, None, None), "timesteps": P(bt),
                      "noise": P(bt, None, None, None),
                      "context": P(bt, None, None), "pooled": P(bt, None)}
        if kind == "train":
            loss = M.make_loss_fn(cfg, mesh, bt, img_res=R)
            ref_loss = M.make_loss_fn(ref_cfg, None, None, img_res=R)
            sample_args = None
        else:
            step_fn = M.make_sample_step(cfg, mesh, bt, img_res=R)
            ref_fn = M.make_sample_step(ref_cfg, None, None, img_res=R)
            sample_args = ({"zt": _sds((B, lat, lat, 4), jnp.float32),
                            "t": _sds((B,), jnp.int32),
                            "t_next": _sds((B,), jnp.int32),
                            "context": batch_sh["context"],
                            "pooled": batch_sh["pooled"]},
                           {"zt": P(bt, None, None, None), "t": P(bt),
                            "t_next": P(bt),
                            "context": P(bt, None, None),
                            "pooled": P(bt, None)})

            def step(params, a):
                return step_fn(params, a["zt"], a["t"], a["t_next"],
                               a["context"], a["pooled"])

            def ref_step(params, a):
                return ref_fn(params, a["zt"], a["t"], a["t_next"],
                              a["context"], a["pooled"])
        ref_table = M.unet_param_table(ref_cfg)

    pspecs = cm.param_pspecs(table, mesh=mesh)
    params_sh = cm.param_shapes(table)
    n_params = cm.param_count(table)

    if kind == "train":
        params_sh, opt_shapes, pspecs, opt_specs = _opt_shapes_and_shardings(
            table, mesh, bt)
        step = steps_mod.make_train_step(loss, cosine_schedule(1e-4, 100, 1000))
        mf = flops_mod.traced_flops(
            lambda p, b: jax.grad(lambda pp: ref_loss(pp, b)[0])(p),
            cm.param_shapes(ref_table), batch_sh)
        six_nd = 6.0 * n_params * B * (lat // 2) ** 2 \
            if arch_id == "dit-l2" else None
        return Cell(arch_id, shape_name, kind, step,
                    (params_sh, opt_shapes, batch_sh),
                    (_shard(mesh, pspecs), _shard(mesh, opt_specs),
                     _shard(mesh, batch_spec)),
                    donate_argnums=(0, 1), model_flops=mf, six_nd=six_nd)

    args_sh, args_spec = sample_args
    mf = flops_mod.traced_flops(ref_step, cm.param_shapes(ref_table), args_sh)
    six_nd = 2.0 * n_params * 2 * B * (lat // 2) ** 2 \
        if arch_id == "dit-l2" else None
    return Cell(arch_id, shape_name, kind, step, (params_sh, args_sh),
                (_shard(mesh, pspecs), _shard(mesh, args_spec)),
                donate_argnums=(), model_flops=mf, six_nd=six_nd,
                steps_multiplier=steps_mult,
                note=f"one denoise step; totals scale x{steps_mult}")


# ---------------------------------------------------------------------------
# Vision cells
# ---------------------------------------------------------------------------

def _vision_cell(arch_id, shape_name, shape, mesh) -> Cell:
    mod = cfgreg.get_module(arch_id)
    B, R = shape["batch"], shape["img_res"]
    bt = batch_axes_for(mesh, B)
    kind = shape["kind"]
    has_bn = arch_id in ("resnet-50", "efficientnet-b7")

    if arch_id == "vit-l16":
        from repro.models import vit as M
        cfg = mod.config()
        table = M.vit_param_table(cfg, img_res=R)
        ref_cfg = mod.config(remat=False, dtype="float32")
        ref_table = M.vit_param_table(ref_cfg, img_res=R)
        make_fwd = lambda c, trn: M.make_forward(c)
        make_loss = lambda c: M.make_loss_fn(c)
    elif arch_id == "resnet-50":
        from repro.models import resnet as M
        cfg = ref_cfg = mod.config()
        table = ref_table = M.resnet_param_table(cfg)
        make_fwd = lambda c, trn: (
            lambda p, x: M.make_forward(c, training=trn)(p, x)[0])
        make_loss = lambda c: M.make_loss_fn(c)
    elif arch_id == "efficientnet-b7":
        from repro.models import efficientnet as M
        cfg = ref_cfg = mod.config()
        table = ref_table = M.efficientnet_param_table(cfg)
        make_fwd = lambda c, trn: (
            lambda p, x: M.make_forward(c, training=trn)(p, x)[0])
        make_loss = lambda c: M.make_loss_fn(c)
    else:  # convnext-b
        from repro.models import convnext as M
        cfg = mod.config()
        ref_cfg = mod.config(dtype="float32")
        table = M.convnext_param_table(cfg)
        ref_table = M.convnext_param_table(ref_cfg)
        make_fwd = lambda c, trn: M.make_forward(c)
        make_loss = lambda c: M.make_loss_fn(c)

    pspecs = cm.param_pspecs(table, mesh=mesh)
    params_sh = cm.param_shapes(table)
    images = _sds((B, R, R, 3), jnp.float32)
    labels = _sds((B,), jnp.int32)

    if kind == "train":
        params_sh, opt_shapes, pspecs, opt_specs = _opt_shapes_and_shardings(
            table, mesh, bt)
        step = steps_mod.make_train_step(
            make_loss(cfg), cosine_schedule(1e-3, 100, 1000), has_bn=has_bn)
        batch_sh = {"images": images, "labels": labels}
        batch_spec = {"images": P(bt, None, None, None), "labels": P(bt)}
        mf = flops_mod.traced_flops(
            lambda p, b: jax.grad(lambda pp: make_loss(ref_cfg)(pp, b)[0])(p),
            cm.param_shapes(ref_table), batch_sh)
        return Cell(arch_id, shape_name, kind, step,
                    (params_sh, opt_shapes, batch_sh),
                    (_shard(mesh, pspecs), _shard(mesh, opt_specs),
                     _shard(mesh, batch_spec)),
                    donate_argnums=(0, 1), model_flops=mf, six_nd=None)

    step = make_fwd(cfg, False)
    mf = flops_mod.traced_flops(make_fwd(ref_cfg, False),
                                cm.param_shapes(ref_table), images)
    return Cell(arch_id, shape_name, "serve", step, (params_sh, images),
                (_shard(mesh, pspecs),
                 _shard(mesh, P(bt, None, None, None))),
                donate_argnums=(), model_flops=mf, six_nd=None)


# ---------------------------------------------------------------------------
# Dehaze cells (the paper's own pipeline)
# ---------------------------------------------------------------------------

def _dehaze_cell(arch_id, shape_name, shape, mesh,
                 overrides: Optional[Dict] = None) -> Cell:
    from repro.core import (AtmoState, init_atmo_state, make_dehaze_step,
                            make_sharded_dehaze_step)
    mod = cfgreg.get_module(arch_id)
    cfg = mod.config(kernel_mode="ref", **(overrides or {}))
    B, H, W = shape["batch"], shape["height"], shape["width"]
    bt = batch_axes_for(mesh, B)
    n_model = mesh.shape.get("model", 1)
    height_axis = "model" if H % n_model == 0 else None
    step, fspec, ispec = make_sharded_dehaze_step(
        cfg, mesh, batch_axes=bt or (), height_axis=height_axis)

    frames = _sds((B, H, W, 3), jnp.float32)
    ids = _sds((B,), jnp.int32)
    state_sh = jax.eval_shape(init_atmo_state)
    state_spec = AtmoState(A=P(), last_update=P(), initialized=P())

    mf = flops_mod.traced_flops(
        make_dehaze_step(cfg), frames, ids, state_sh)
    return Cell(arch_id, shape_name, "dehaze", step, (frames, ids, state_sh),
                (_shard(mesh, fspec), _shard(mesh, ispec),
                 _shard(mesh, state_spec)),
                donate_argnums=(), model_flops=mf, six_nd=None,
                note=f"height_axis={height_axis}")


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               overrides: Optional[Dict] = None) -> Cell:
    """``overrides``: config-field overrides for perf iteration (e.g.
    {"seq_shard": True}); applied to the lowered config only — the
    reference MODEL_FLOPS trace stays at the paper-faithful baseline so
    the useful-FLOPs ratio remains comparable across variants."""
    skip = cfgreg.cell_skip_reason(arch_id, shape_name)
    if skip:
        raise CellSkip(skip)
    shape = cfgreg.shapes_for(arch_id)[shape_name]
    family = cfgreg.get_module(arch_id).FAMILY
    if family == "lm":
        return _lm_cell(arch_id, shape_name, shape, mesh, overrides)
    if family == "dehaze":
        return _dehaze_cell(arch_id, shape_name, shape, mesh, overrides)
    if overrides:
        raise ValueError(f"overrides unsupported for family {family}")
    if family == "diffusion":
        return _diffusion_cell(arch_id, shape_name, shape, mesh)
    if family == "vision":
        return _vision_cell(arch_id, shape_name, shape, mesh)
    raise ValueError(family)
