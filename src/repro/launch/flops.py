"""Analytic MODEL_FLOPS: a jaxpr walker counting ideal compute.

Traces the *reference* computation (no TP head padding, no remat, no SPMD
partitioning) with ``jax.make_jaxpr`` — cheap, no compilation — and counts:

  - dot_general: 2 * prod(batch) * M * N * K
  - conv_general_dilated: 2 * out_spatial * k_spatial * Cin/g * Cout * B
  - elementwise / reductions / reduce_window: 1 FLOP per output (x window)
  - scan bodies multiplied by trip count; cond branches take the max

This is the "useful FLOPs" denominator for the roofline table: the ratio
MODEL_FLOPS / HLO_FLOPs exposes padding, remat recompute, and capacity
waste in the compiled program. The brief's 6·N·D convention is reported
alongside (``six_nd``) for LM cells.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "abs", "floor", "ceil", "sign",
    "erf", "cos", "sin", "integer_pow", "select_n", "clamp", "and", "or",
    "xor", "not", "rem",
}
REDUCTIONS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
              "reduce_and", "reduce_or", "argmax", "argmin",
              "cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod"}


def _size(v) -> int:
    try:
        return int(np.prod(v.aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                  if i not in set(lc) | set(lb))
    n = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                  if i not in set(rc) | set(rb))
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval                     # kernel (HWIO order via spec)
    dn = eqn.params["dimension_numbers"]
    k_spatial = math.prod(rhs.shape[i] for i in dn.rhs_spec[2:])
    cin_per_g = rhs.shape[dn.rhs_spec[1]]        # already per-group
    return 2.0 * _size(eqn.outvars[0]) * k_spatial * cin_per_g


def _jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            total += eqn.params["length"] * _jaxpr_flops(
                eqn.params["jaxpr"].jaxpr)
        elif prim == "while":
            total += _jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        elif prim == "cond":
            total += max((_jaxpr_flops(b.jaxpr)
                          for b in eqn.params["branches"]), default=0.0)
        elif prim in ("pjit", "closed_call", "core_call", "remat2",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "shard_map"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                total += _jaxpr_flops(getattr(inner, "jaxpr", inner))
        elif prim in ELEMENTWISE:
            total += _size(eqn.outvars[0])
        elif prim in REDUCTIONS:
            total += _size(eqn.invars[0])
        elif prim == "reduce_window_sum" or prim == "reduce_window":
            w = eqn.params.get("window_dimensions", ())
            total += _size(eqn.outvars[0]) * math.prod(w)
        elif prim == "reduce_window_max" or prim == "reduce_window_min":
            w = eqn.params.get("window_dimensions", ())
            total += _size(eqn.outvars[0]) * math.prod(w)
    return total


def traced_flops(fn, *args, **kwargs) -> float:
    """FLOPs of fn(*args) per the walker above (args: ShapeDtypeStructs ok)."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    return _jaxpr_flops(jaxpr.jaxpr)
