import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (the SPMD
partitioner accepts it, no sharding mismatch, no unsupported collective)
and extracts the roofline inputs:

  - compiled.memory_analysis()  -> bytes per device (does it fit HBM)
  - compiled.cost_analysis()    -> HLO FLOPs / HBM bytes
  - compiled.as_text() parse    -> collective bytes per kind

Results are cached as JSON under results/dryrun/ so the full 40-cell x
2-mesh sweep can run incrementally.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--include-dehaze]
  python -m repro.launch.dryrun --summary
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro import configs as cfgreg
from repro.launch.cells import Cell, CellSkip, build_cell
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# TPU v5e constants (per chip).
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

def _analyze(rec: dict, hlo: str, mem, cell) -> dict:
    """Fill the roofline fields of ``rec`` from the HLO text + memory
    analysis. Kept separate so --reanalyze can recompute metrics from the
    saved HLO without recompiling."""
    from repro.launch import hlocost
    hcost = hlocost.cost_from_hlo_text(hlo)
    flops = hcost.flops
    bytes_acc = hcost.traffic_bytes
    coll = dict(hcost.collective_bytes)
    coll_total = hcost.collective_total

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_total / ICI_BW
    rec.update(
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll,
        collective_bytes_total=coll_total,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=max((("compute", compute_s), ("memory", memory_s),
                        ("collective", collective_s)),
                       key=lambda kv: kv[1])[0],
    )
    if mem is not None:
        rec["memory_analysis"] = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "output_size_in_bytes", 0) or 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    return rec


def _paths(arch_id, shape_name, mesh_name):
    base = f"{arch_id}__{shape_name}__{mesh_name}"
    return (os.path.join(RESULTS_DIR, base + ".json"),
            os.path.join(RESULTS_DIR, "hlo", base + ".txt.gz"))


def reanalyze_all() -> None:
    """Recompute roofline metrics from cached HLO (no recompilation)."""
    import gzip
    n = 0
    for name in sorted(os.listdir(RESULTS_DIR)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(RESULTS_DIR, name)
        with open(path) as f:
            rec = json.load(f)
        hlo_path = os.path.join(RESULTS_DIR, "hlo",
                                name[:-5] + ".txt.gz")
        if rec.get("status") != "ok" or not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        _analyze(rec, hlo, None, None)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reanalyzed {n} records")


def run_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
             force: bool = False, save: bool = True,
             overrides: Optional[dict] = None,
             variant: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if variant:
        mesh_name += f"__{variant}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    os.makedirs(os.path.join(RESULTS_DIR, "hlo"), exist_ok=True)
    out_path, hlo_path = _paths(arch_id, shape_name, mesh_name)
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "status": "error"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        try:
            cell = build_cell(arch_id, shape_name, mesh,
                              overrides=overrides)
        except CellSkip as e:
            rec.update(status="skip", reason=str(e))
            if save:
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
            return rec

        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        with mesh:
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)

        rec.update(
            status="ok",
            n_devices=n_dev,
            kind=cell.kind,
            note=cell.note,
            steps_multiplier=cell.steps_multiplier,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            xla_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                               "bytes": float(cost.get("bytes accessed", 0.0)),
                               "note": "while-bodies counted once by XLA"},
            model_flops=cell.model_flops,
            six_nd=cell.six_nd,
        )
        # Corrected per-device cost model: parses the SPMD HLO with while
        # trip counts (XLA's cost_analysis counts loop bodies once), ring-
        # derated collective wire bytes, fusion-boundary HBM traffic
        # (upper bound — CPU-backend fusion is coarser than TPU's).
        _analyze(rec, hlo, mem, cell)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    if save:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def summary() -> None:
    rows = []
    for name in sorted(os.listdir(RESULTS_DIR)):
        if name.endswith(".json"):
            with open(os.path.join(RESULTS_DIR, name)) as f:
                rows.append(json.load(f))
    print(f"{'arch':26s} {'shape':12s} {'mesh':11s} {'status':7s} "
          f"{'bottleneck':10s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'useful%':>8s} {'peakGB':>7s}")
    for r in rows:
        if r["status"] == "ok":
            useful = (100.0 * r["model_flops"] / r["hlo_flops_per_device"]
                      / r["n_devices"] if r["hlo_flops_per_device"] else 0.0)
            peak = (r["memory_analysis"]["peak_bytes"] or 0) / 1e9
            print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:11s} "
                  f"{r['status']:7s} {r.get('bottleneck',''):10s} "
                  f"{r['compute_s']:10.4g} {r['memory_s']:10.4g} "
                  f"{r['collective_s']:10.4g} {useful:8.1f} {peak:7.2f}")
        else:
            msg = r.get("reason") or r.get("error", "")
            print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:11s} "
                  f"{r['status']:7s} {msg[:70]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-dehaze", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (perf iteration); "
                         "repeatable. Adds a __<variant> suffix to the record.")
    ap.add_argument("--variant", default="",
                    help="label for the override variant record")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute metrics from cached HLO, no recompile")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze_all()
        return
    if args.summary:
        summary()
        return

    import ast
    overrides = None
    if args.override:
        overrides = {}
        for kv in args.override:
            k, v = kv.split("=", 1)
            try:
                overrides[k] = ast.literal_eval(v)
            except (ValueError, SyntaxError):
                overrides[k] = v
        if not args.variant:
            args.variant = "-".join(f"{k}={v}" for k, v in overrides.items())

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = cfgreg.all_cells(include_dehaze=args.include_dehaze)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = 0
    for multi_pod in meshes:
        for arch_id, shape_name in cells:
            rec = run_cell(arch_id, shape_name, multi_pod=multi_pod,
                           force=args.force, overrides=overrides,
                           variant=args.variant)
            status = rec["status"]
            extra = rec.get("reason") or rec.get("error", "")
            print(f"[{rec['mesh']}] {arch_id} x {shape_name}: {status} "
                  f"({rec.get('wall_s', 0)}s) {extra[:100]}", flush=True)
            if status == "error":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
