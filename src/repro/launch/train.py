"""Training driver: synthetic data -> train_step -> checkpoints, resumable.

On this CPU container it runs REDUCED (--smoke) configs end-to-end; on a
pod the same driver runs the full config against the production mesh (the
dry-run proves those executables compile). Fault tolerance: checkpoints
every --ckpt-every steps (atomic, async), auto-resumes from the latest.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 4 --seq-len 32 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgreg
from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.data import ImageStream, TokenStream, prefetch_to_device
from repro.models import common as cm
from repro.models.steps import make_train_step
from repro.optim import adamw_init, cosine_schedule


def build(arch: str, smoke: bool, batch: int, seq_len: int, img_res: int):
    mod = cfgreg.get_module(arch)
    cfg = mod.smoke_config() if smoke else mod.config()
    fam = mod.FAMILY
    if fam == "lm":
        from repro.models import transformer as T
        table = T.lm_param_table(cfg)
        loss = T.make_loss_fn(cfg, None, None)
        data = TokenStream(batch, seq_len, cfg.vocab)
        has_bn = False
    elif fam == "vision":
        res = img_res or cfg.img_res
        if arch.startswith("vit"):
            from repro.models import vit as M
            table = M.vit_param_table(cfg, img_res=res)
            loss = M.make_loss_fn(cfg)
        elif arch.startswith("resnet"):
            from repro.models import resnet as M
            table = M.resnet_param_table(cfg)
            loss = M.make_loss_fn(cfg)
        elif arch.startswith("efficientnet"):
            from repro.models import efficientnet as M
            table = M.efficientnet_param_table(cfg)
            loss = M.make_loss_fn(cfg)
        else:
            from repro.models import convnext as M
            table = M.convnext_param_table(cfg)
            loss = M.make_loss_fn(cfg)
        data = ImageStream(batch, res, res, cfg.n_classes)
        has_bn = arch.startswith(("resnet", "efficientnet"))
    else:
        raise SystemExit(f"use examples/train_diffusion.py for {fam}")
    return cfg, table, loss, data, has_bn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--img-res", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg, table, loss, data, has_bn = build(
        args.arch, args.smoke, args.batch, args.seq_len, args.img_res)
    params = cm.init_params(jax.random.key(0), table)
    opt = adamw_init(params)
    n_params = cm.param_count(table)
    print(f"arch={args.arch} params={n_params/1e6:.2f}M smoke={args.smoke}")

    step_fn = jax.jit(make_train_step(
        loss, cosine_schedule(args.lr, max(args.steps // 10, 1), args.steps),
        has_bn=has_bn))

    start = 0
    ck = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        ck = AsyncCheckpointer(mgr)
        if mgr.latest_step() is not None:
            restored, _, start = mgr.restore({"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from step {start}")

    it = prefetch_to_device(iter(data), size=2)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = next(it)
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()
                 if hasattr(v, "ndim") and getattr(v, "ndim", 1) == 0}
            print(f"step {step + 1}: " + " ".join(
                f"{k}={v:.4f}" for k, v in sorted(m.items())), flush=True)
        if ck is not None and (step + 1) % args.ckpt_every == 0:
            ck.save(step + 1, {"params": params, "opt": opt},
                    {"arch": args.arch})
    if ck is not None:
        ck.wait()
    dt = time.perf_counter() - t0
    done = args.steps - start
    print(f"trained {done} steps in {dt:.1f}s "
          f"({done / max(dt, 1e-9):.2f} steps/s)")


if __name__ == "__main__":
    main()
