"""Synthetic hazy-video generator driven by the paper's physics (Eq. 1-2).

Produces procedurally animated clear scenes, smooth depth maps, and a
slowly drifting + per-frame-noisy atmospheric light — the exact failure
mode Fig. 6 shows (independent per-frame A estimates flicker). Ground
truth (J, t, A per frame) is returned for quantitative evaluation, which
no real foggy video can provide.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class HazeVideoSpec:
    height: int = 240
    width: int = 320
    n_frames: int = 64
    beta: float = 1.0
    a_base: Tuple[float, float, float] = (0.90, 0.92, 0.95)
    a_drift_amp: float = 0.04      # slow sinusoidal drift of A (scene change)
    a_noise: float = 0.02          # per-frame estimation-noise analogue
    motion: float = 2.0            # scene translation px/frame
    # Fraction of near-black "shadow" pixels. Real scenes satisfy the dark
    # channel prior (He et al.) through shadows/dark texture; purely smooth
    # procedural albedo would not, so we inject it explicitly.
    dark_speckle: float = 0.03
    seed: int = 0


def _smooth_noise(rng: np.random.Generator, h: int, w: int,
                  octaves: int = 4) -> np.ndarray:
    """Multi-octave value noise in [0, 1] (cheap Perlin stand-in)."""
    out = np.zeros((h, w), np.float32)
    amp, total = 1.0, 0.0
    for o in range(octaves):
        gh, gw = max(2, h >> (octaves - o)), max(2, w >> (octaves - o))
        grid = rng.random((gh, gw)).astype(np.float32)
        ys = np.linspace(0, gh - 1, h)
        xs = np.linspace(0, gw - 1, w)
        y0 = np.clip(ys.astype(int), 0, gh - 2)
        x0 = np.clip(xs.astype(int), 0, gw - 2)
        fy = (ys - y0)[:, None].astype(np.float32)
        fx = (xs - x0)[None, :].astype(np.float32)
        v = (grid[y0][:, x0] * (1 - fy) * (1 - fx)
             + grid[y0 + 1][:, x0] * fy * (1 - fx)
             + grid[y0][:, x0 + 1] * (1 - fy) * fx
             + grid[y0 + 1][:, x0 + 1] * fy * fx)
        out += amp * v
        total += amp
        amp *= 0.5
    return out / total


@dataclasses.dataclass
class HazeVideo:
    """Materialized synthetic video with ground truth."""
    hazy: np.ndarray     # (N, H, W, 3)
    clear: np.ndarray    # (N, H, W, 3)
    t: np.ndarray        # (N, H, W)
    A: np.ndarray        # (N, 3)

    def __iter__(self) -> Iterator[np.ndarray]:
        return iter(self.hazy)


def generate_haze_video(spec: HazeVideoSpec) -> HazeVideo:
    rng = np.random.default_rng(spec.seed)
    h, w = spec.height, spec.width
    # Static "world" textures larger than the viewport; the camera pans.
    pad = int(spec.motion * spec.n_frames) + 8
    albedo = np.stack([_smooth_noise(rng, h + pad, w + pad) for _ in range(3)],
                      axis=-1)
    albedo = 0.15 + 0.7 * albedo
    if spec.dark_speckle > 0:
        shadow = rng.random((h + pad, w + pad)) < spec.dark_speckle
        albedo = np.where(shadow[..., None], albedo * 0.05, albedo)
    depth_world = 0.3 + 2.2 * _smooth_noise(rng, h + pad, w + pad)

    hazy = np.empty((spec.n_frames, h, w, 3), np.float32)
    clear = np.empty_like(hazy)
    t_all = np.empty((spec.n_frames, h, w), np.float32)
    a_all = np.empty((spec.n_frames, 3), np.float32)
    base = np.asarray(spec.a_base, np.float32)
    for i in range(spec.n_frames):
        off = int(spec.motion * i)
        J = albedo[off:off + h, off:off + w]
        d = depth_world[off:off + h, off:off + w]
        t = np.exp(-spec.beta * d).astype(np.float32)
        drift = spec.a_drift_amp * np.sin(2 * np.pi * i / max(spec.n_frames, 1))
        noise = spec.a_noise * rng.standard_normal(3).astype(np.float32)
        A = np.clip(base + drift + noise, 0.6, 1.0)
        I = J * t[..., None] + A * (1.0 - t[..., None])
        hazy[i] = np.clip(I, 0.0, 1.0)
        clear[i] = J
        t_all[i] = t
        a_all[i] = A
    return HazeVideo(hazy=hazy, clear=clear, t=t_all, A=a_all)
