"""Data substrates: synthetic hazy video (paper physics) + arch pipelines."""
from repro.data.haze_video import HazeVideo, HazeVideoSpec, generate_haze_video
from repro.data.synthetic import (DiffusionStream, ImageStream, TokenStream,
                                  prefetch_to_device)

__all__ = ["HazeVideo", "HazeVideoSpec", "generate_haze_video",
           "TokenStream", "ImageStream", "DiffusionStream",
           "prefetch_to_device"]
