"""Synthetic input pipelines for the assigned architectures + prefetch.

Deterministic, seeded, and cheap: LM token streams, labeled image batches,
and diffusion (latent, timestep, conditioning) tuples. A double-buffered
host→device prefetcher overlaps input generation/transfer with compute —
the training-loop analogue of the paper's spout → worker overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional, Tuple

import jax
import numpy as np


class TokenStream:
    """Endless (batch, seq) int32 token batches with next-token labels."""

    def __init__(self, batch: int, seq_len: int, vocab: int, seed: int = 0):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab
        self._rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict]:
        while True:
            # Strongly learnable Markov stream: with p=0.85 the next token
            # is (prev + 1) mod V, else uniform — examples show the loss
            # dropping toward ~0.15 ln V + H(0.85) within a few hundred
            # steps instead of hovering at ln V.
            n = self.seq_len + 1
            toks = np.empty((self.batch, n), np.int64)
            toks[:, 0] = self._rng.integers(0, self.vocab, self.batch)
            follow = self._rng.random((self.batch, n)) < 0.85
            rand = self._rng.integers(0, self.vocab, (self.batch, n))
            for i in range(1, n):
                toks[:, i] = np.where(follow[:, i],
                                      (toks[:, i - 1] + 1) % self.vocab,
                                      rand[:, i])
            toks = toks.astype(np.int32)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ImageStream:
    """Endless labeled image batches (NHWC float32 in [0,1])."""

    def __init__(self, batch: int, height: int, width: int, n_classes: int,
                 channels: int = 3, seed: int = 0):
        self.batch, self.h, self.w, self.c = batch, height, width, channels
        self.n_classes = n_classes
        self._rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict]:
        while True:
            labels = self._rng.integers(0, self.n_classes, (self.batch,),
                                        np.int32)
            # Class-dependent mean so a classifier can actually learn.
            mean = (labels[:, None, None, None] % 8).astype(np.float32) / 8.0
            img = np.clip(
                mean + 0.25 * self._rng.standard_normal(
                    (self.batch, self.h, self.w, self.c)).astype(np.float32),
                0.0, 1.0)
            yield {"images": img, "labels": labels}


class DiffusionStream:
    """Endless (latents, timesteps, conditioning) batches for DiT/U-Net."""

    def __init__(self, batch: int, latent_res: int, channels: int,
                 n_classes: int = 1000, ctx_len: int = 0, ctx_dim: int = 0,
                 seed: int = 0):
        self.batch, self.res, self.c = batch, latent_res, channels
        self.n_classes, self.ctx_len, self.ctx_dim = n_classes, ctx_len, ctx_dim
        self._rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict]:
        while True:
            out = {
                "latents": self._rng.standard_normal(
                    (self.batch, self.res, self.res, self.c)).astype(np.float32),
                "timesteps": self._rng.integers(
                    0, 1000, (self.batch,), np.int32),
                "labels": self._rng.integers(
                    0, self.n_classes, (self.batch,), np.int32),
            }
            if self.ctx_len:
                out["context"] = self._rng.standard_normal(
                    (self.batch, self.ctx_len, self.ctx_dim)).astype(np.float32)
            yield out


def prefetch_to_device(it: Iterator, size: int = 2,
                       sharding: Optional[jax.sharding.Sharding] = None
                       ) -> Iterator:
    """Double-buffered host→device prefetch: generation and H2D transfer of
    batch k+1 overlap the compute of batch k."""
    q: "queue.Queue" = queue.Queue(maxsize=size)
    stop = object()

    def producer():
        try:
            for item in it:
                if sharding is not None:
                    item = jax.device_put(item, sharding)
                else:
                    item = jax.device_put(item)
                q.put(item)
        finally:
            q.put(stop)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
