"""Render results/dryrun/*.json into the EXPERIMENTS.md roofline table."""
import json
import os
import sys

RES = os.path.join(os.path.dirname(__file__), "dryrun")


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x >= 1000 or x < 0.001:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def main(mesh_filter="pod16x16", include_variants=False):
    rows = []
    for name in sorted(os.listdir(RES)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(RES, name)) as f:
            r = json.load(f)
        is_variant = "__" in r.get("mesh", "").replace(
            "pod2x16x16", "X").replace("pod16x16", "X")[1:]
        if r.get("mesh", "").startswith(mesh_filter):
            variant = r["mesh"][len(mesh_filter):].lstrip("_")
            if bool(variant) != include_variants:
                continue
            rows.append((r, variant))
    print("| arch | shape | status | compute_s | memory_s | coll_s | "
          "bottleneck | useful | MODEL_FLOPS | 6ND | peak GB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r, variant in rows:
        arch = r["arch"] + (f" **[{variant}]**" if variant else "")
        if r["status"] != "ok":
            reason = (r.get("reason") or r.get("error", ""))[:60]
            print(f"| {arch} | {r['shape']} | SKIP | | | | | | | | | {reason} |")
            continue
        useful = (r["model_flops"] / (r["hlo_flops_per_device"] * r["n_devices"])
                  if r["hlo_flops_per_device"] else float("nan"))
        six = fmt(r["six_nd"]) if r.get("six_nd") else "—"
        peak = (r["memory_analysis"]["peak_bytes"] or 0) / 1e9
        note = r.get("note", "")
        print(f"| {arch} | {r['shape']} | ok | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
              f"{r['bottleneck']} | {useful:.2f} | {fmt(r['model_flops'])} | "
              f"{six} | {peak:.1f} | {note[:40]} |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or ["pod16x16"]),
         include_variants="--variants" in sys.argv)
