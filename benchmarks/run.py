"""Benchmark harness: one module per paper table/figure + roofline view.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement).
Select subsets with ``python -m benchmarks.run table1 fig8``.
"""
import sys

from benchmarks import (fig6_flicker, fig8_atmolight, kernels_bench,
                        roofline_report, table1_throughput)

SUITES = {
    "table1": table1_throughput.rows,
    "fig6": fig6_flicker.rows,
    "fig8": fig8_atmolight.rows,
    "kernels": kernels_bench.rows,
    "roofline": roofline_report.rows,
    # Ramping-load subset of table1 (elastic lane ladder vs fixed-max
    # fleet + switch latency) — cheap enough for the CI smoke job.
    "autoscale": table1_throughput.autoscale_rows,
    # Fleet subset of table1 (1 vs 2 simulated hosts; asserts the >= 1.8x
    # aggregate-fps scaling bar + zero EMA migrations).
    "fleet": table1_throughput.fleet_rows,
    # Zero-copy tick I/O subset of table1 (overlapped vs blocking serve at
    # sparse occupancy; asserts fps(on) >= fps(off) + D2H byte reduction).
    "overlap": table1_throughput.overlap_rows,
}


def main() -> None:
    wanted = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    print("name,us_per_call,derived")
    for key in wanted:
        for name, us, derived in SUITES[key]():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
