"""Paper Fig. 8: atmospheric-light curves — raw per-frame estimation vs
the §3.3 update strategy, on four different synthetic videos x {DCP, CAP}.

Metric (the figure's visual claim, quantified): mean |frame-to-frame ΔA|
and the curve's std around its slow trend. Writes the full curves to
results/fig8_curves.csv for plotting.
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video

VIDEOS = [
    HazeVideoSpec(height=96, width=128, n_frames=48, seed=11, a_noise=0.0),
    HazeVideoSpec(height=96, width=128, n_frames=48, seed=12, a_noise=0.0,
                  a_base=(0.8, 0.82, 0.85)),
    HazeVideoSpec(height=96, width=128, n_frames=48, seed=13, a_noise=0.0,
                  motion=4.0),
    HazeVideoSpec(height=96, width=128, n_frames=48, seed=14, a_noise=0.0,
                  a_drift_amp=0.08),
]


def curves(algo: str, spec: HazeVideoSpec):
    vid = generate_haze_video(spec)
    frames = jnp.asarray(vid.hazy)
    ids = jnp.arange(spec.n_frames, dtype=jnp.int32)

    def run(period, lam):
        cfg = DehazeConfig(algorithm=algo, kernel_mode="ref", gf_radius=8,
                           update_period=period, lam=lam)
        out = jax.jit(make_dehaze_step(cfg))(frames, ids, init_atmo_state())
        return np.asarray(out.atmo_light)

    raw = run(1, 1.0)            # independent per-frame estimation
    ema = run(8, 0.05)           # paper §3.3 defaults
    return raw, ema, vid.A


def rows() -> List[Tuple[str, float, str]]:
    out = []
    os.makedirs("results", exist_ok=True)
    csv_rows = ["video,algo,frame,channel,raw,ema,true"]
    for algo in ("dcp", "cap"):
        for vi, spec in enumerate(VIDEOS):
            t0 = time.perf_counter()
            raw, ema, true = curves(algo, spec)
            dt = time.perf_counter() - t0
            osc_raw = float(np.abs(np.diff(raw, axis=0)).mean())
            osc_ema = float(np.abs(np.diff(ema, axis=0)).mean())
            out.append((f"fig8/{algo}/video{vi}", dt * 1e6 / len(raw),
                        f"osc_raw={osc_raw:.4f};osc_ema={osc_ema:.4f};"
                        f"ratio={osc_ema / max(osc_raw, 1e-12):.3f}"))
            for f in range(len(raw)):
                for c in range(3):
                    csv_rows.append(
                        f"{vi},{algo},{f},{c},{raw[f, c]:.5f},"
                        f"{ema[f, c]:.5f},{true[f, c]:.5f}")
    with open("results/fig8_curves.csv", "w") as fh:
        fh.write("\n".join(csv_rows))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
