"""Paper Fig. 6: output flicker — frame-to-frame luminance stability of
the dehazed stream, independent per-frame A vs the update strategy."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video


def luminance(frames: np.ndarray) -> np.ndarray:
    return (0.299 * frames[..., 0] + 0.587 * frames[..., 1]
            + 0.114 * frames[..., 2]).mean(axis=(1, 2))


def rows() -> List[Tuple[str, float, str]]:
    spec = HazeVideoSpec(height=96, width=128, n_frames=48, seed=2,
                         a_noise=0.0)
    vid = generate_haze_video(spec)
    frames = jnp.asarray(vid.hazy)
    ids = jnp.arange(spec.n_frames, dtype=jnp.int32)
    out = []
    for algo in ("dcp", "cap"):
        def run(period, lam):
            cfg = DehazeConfig(algorithm=algo, kernel_mode="ref",
                               gf_radius=8, update_period=period, lam=lam)
            o = jax.jit(make_dehaze_step(cfg))(frames, ids, init_atmo_state())
            return np.asarray(o.frames)

        t0 = time.perf_counter()
        raw = run(1, 1.0)
        ema = run(8, 0.05)
        dt = time.perf_counter() - t0
        fl_raw = float(np.abs(np.diff(luminance(raw))).std())
        fl_ema = float(np.abs(np.diff(luminance(ema))).std())
        fl_in = float(np.abs(np.diff(luminance(vid.hazy))).std())
        out.append((f"fig6/{algo}", dt * 1e6 / (2 * spec.n_frames),
                    f"flicker_in={fl_in:.5f};raw={fl_raw:.5f};"
                    f"ema={fl_ema:.5f};reduction={fl_raw / max(fl_ema, 1e-12):.2f}x"))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
