"""Kernel microbenchmarks: the dehazing hot spots, XLA(ref) path on CPU.

The Pallas kernels target TPU; interpret mode is a correctness harness,
not a performance path, so wall-clock here benches the XLA reference
implementations the runtime actually uses on CPU, plus the roofline-model
expectations for the TPU kernels (bytes-bound estimates at v5e HBM BW).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

HBM_BW = 819e9
SHAPES = [(8, 240, 320), (4, 480, 640), (2, 576, 1024)]


def _timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def rows() -> List[Tuple[str, float, str]]:
    out = []
    for b, h, w in SHAPES:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((b, h, w, 3), np.float32))
        tmap = jnp.asarray(r.random((b, h, w), np.float32))
        A = jnp.asarray(r.random((b, 3), np.float32))
        tag = f"{b}x{h}x{w}"

        dc = jax.jit(lambda x: ops.dark_channel(x, 7, "ref"))
        t = _timeit(dc, img)
        tpu_est = (img.nbytes + tmap.nbytes) / HBM_BW
        out.append((f"kernels/dark_channel/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))

        gf = jax.jit(lambda g, p: ops.guided_filter(g, p, 20, 1e-3, "ref"))
        t = _timeit(gf, tmap, tmap)
        tpu_est = 12 * tmap.nbytes / HBM_BW    # 5 box passes r+w + extras
        out.append((f"kernels/guided_filter/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))

        al = jax.jit(lambda i, tm: ops.atmospheric_light(i, tm, 1, "ref"))
        t = _timeit(al, img, tmap)
        out.append((f"kernels/atmolight/{tag}", t * 1e6, ""))

        rc = jax.jit(lambda i, tm, a: ops.recover(i, tm, a, mode="ref"))
        t = _timeit(rc, img, tmap, A)
        tpu_est = (2 * img.nbytes + tmap.nbytes) / HBM_BW
        out.append((f"kernels/recover/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
