"""Kernel microbenchmarks: the dehazing hot spots, XLA(ref) path on CPU.

The Pallas kernels target TPU; interpret mode is a correctness harness,
not a performance path, so wall-clock here benches the XLA reference
implementations the runtime actually uses on CPU, plus the roofline-model
expectations for the TPU kernels (bytes-bound estimates at v5e HBM BW).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as _env
from repro.kernels import ops
from repro.kernels import ref as kref

HBM_BW = 819e9
SHAPES = [(8, 240, 320), (4, 480, 640), (2, 576, 1024)]
if _env.bench_smoke():                         # tiny shapes for CI smoke
    SHAPES = [(2, 32, 40)]


def _dehaze_min_bytes(img: jnp.ndarray, out_dtype=jnp.float32) -> int:
    """Minimal HBM traffic of the fused dehaze op, parameterized by the io
    dtypes: read I at the *wire* dtype (uint8 = 1/4 the f32 bytes), write
    J (b,h,w,3) + t (b,h,w) at the output dtype."""
    n_px = int(np.prod(img.shape[:-1]))             # b*h*w
    o = jnp.dtype(out_dtype).itemsize
    return img.nbytes + n_px * 3 * o + n_px * o


def _timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def rows() -> List[Tuple[str, float, str]]:
    out = []
    for b, h, w in SHAPES:
        r = np.random.default_rng(0)
        img = jnp.asarray(r.random((b, h, w, 3), np.float32))
        tmap = jnp.asarray(r.random((b, h, w), np.float32))
        A = jnp.asarray(r.random((b, 3), np.float32))
        tag = f"{b}x{h}x{w}"

        dc = jax.jit(lambda x: ops.dark_channel(x, 7, "ref"))
        t = _timeit(dc, img)
        tpu_est = (img.nbytes + tmap.nbytes) / HBM_BW
        out.append((f"kernels/dark_channel/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))

        gf = jax.jit(lambda g, p: ops.guided_filter(g, p, 20, 1e-3, "ref"))
        t = _timeit(gf, tmap, tmap)
        tpu_est = 12 * tmap.nbytes / HBM_BW    # 5 box passes r+w + extras
        out.append((f"kernels/guided_filter/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))

        al = jax.jit(lambda i, tm: ops.atmospheric_light(i, tm, 1, "ref"))
        t = _timeit(al, img, tmap)
        out.append((f"kernels/atmolight/{tag}", t * 1e6, ""))

        rc = jax.jit(lambda i, tm, a: ops.recover(i, tm, a, mode="ref"))
        t = _timeit(rc, img, tmap, A)
        tpu_est = (2 * img.nbytes + tmap.nbytes) / HBM_BW
        out.append((f"kernels/recover/{tag}", t * 1e6,
                    f"tpu_roofline_us={tpu_est * 1e6:.1f}"))

        out.extend(_staged_vs_fused_rows(img, tag))
        out.extend(_fused_io_rows(img, tag))
        out.extend(_fused_topk_rows(img, tag))
        out.extend(_sharded_halo_rows(img, tag))
        out.extend(_sharded_halo_w_rows(img, tag))
    for n_lanes in (4, 16):
        out.extend(_multi_lane_rows(n_lanes))
    out.extend(_tuning_search_cost_rows())
    return out


def _tuning_search_cost_rows():
    """Autotuner cost: successive-halving timed runs vs the exhaustive
    ``candidates x iters`` product over the same joint space, on a
    deterministic virtual-clock timer (no kernels execute — this row
    measures the *search*, and must hold on any hardware)."""
    from repro.kernels import tuning

    rows = []
    # The real joint spaces: fused = fpb x depth, lanes = fpb x order x depth.
    for tag, n in (("fused_9c", 9), ("lanes_18c", 18)):
        costs = {i: 10.0 + ((i * 7) % n) for i in range(n)}
        clock = [0.0]

        def build(params, _costs=costs, _clock=clock):
            def run():
                _clock[0] += _costs[params["x"]]
            return run

        stats = tuning.TuneStats()
        t0 = time.perf_counter()
        best = tuning.measured_search(
            "fused_dcp", (2, 8, 8), [{"x": i} for i in range(n)], build,
            iters=3, persist=False, timer=lambda _c=clock: _c[0],
            stats=stats)
        wall = time.perf_counter() - t0
        assert stats.timed_runs < stats.exhaustive_runs, \
            (stats.timed_runs, stats.exhaustive_runs)
        saved = 100.0 * (1 - stats.timed_runs / stats.exhaustive_runs)
        rows.append((f"kernels/tuning_search_cost/{tag}", wall * 1e6,
                     f"runs_vs_exhaustive={stats.timed_runs}/"
                     f"{stats.exhaustive_runs};saved={saved:.0f}%"
                     f";winner_x={best['x']};rounds={stats.rounds}"))
    return rows


def _staged_vs_fused_rows(img: jnp.ndarray, tag: str):
    """The tentpole comparison: four per-stage launches (device sync between
    each, the pre-megakernel dispatch pattern) vs the single-pass fused op.
    GB/s is derived from the fused op's minimal HBM traffic (read I, write
    J + t) so the two rows are directly comparable.
    """
    b = img.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    kw = dict(radius=7, omega=0.95, refine=True, gf_radius=20, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=8, lam=0.05)
    min_bytes = _dehaze_min_bytes(img)                # I in, J + t out

    dc = jax.jit(lambda x: 1.0 - 0.95 * ops.dark_channel(x, 7, "ref"))
    al = jax.jit(lambda x, t: ops.atmospheric_light(x, t, 1, "ref"))
    from repro.kernels.ref import LUMA_WEIGHTS
    gf = jax.jit(lambda x, t: jnp.clip(ops.guided_filter(
        x @ jnp.asarray(LUMA_WEIGHTS, x.dtype), t, 20, 1e-3, "ref"),
        0.0, 1.0))
    rc = jax.jit(lambda x, t, a: ops.recover(x, t, a, mode="ref"))

    def staged():
        t_raw = jax.block_until_ready(dc(img))
        A = jax.block_until_ready(al(img, t_raw))
        t = jax.block_until_ready(gf(img, t_raw))
        return rc(img, t, A)

    fused = jax.jit(lambda x: ops.fused_dehaze(
        x, ids, A0, k0, init, mode="auto", **kw)[0])

    t_staged = _timeit(staged)
    t_fused = _timeit(fused, img)
    rows = [
        (f"kernels/dehaze_staged/{tag}", t_staged * 1e6 / b,
         f"gbps={min_bytes / t_staged / 1e9:.2f}"),
        (f"kernels/dehaze_fused/{tag}", t_fused * 1e6 / b,
         f"gbps={min_bytes / t_fused / 1e9:.2f}"
         f";speedup_vs_staged={t_staged / t_fused:.2f}x"),
    ]
    return rows


def _fused_io_rows(img: jnp.ndarray, tag: str):
    """The quantization-aware + double-buffered megakernel flavors.

    ``kernels/fused_u8``: the fused op ingesting uint8 wire frames
    (in-VMEM upcast) — the TPU roofline column shrinks with the input
    bytes, the point of the quantized ingest path (wall-clock here is the
    XLA substrate, which upcasts in-register just the same).

    ``kernels/fused_dbuf``: the double-buffered grid (buffer_depth=2).
    Wall-clock must be no worse than ``kernels/dehaze_fused`` (on CPU the
    XLA substrate ignores the depth), and the derived column asserts the
    overlap *structure* on the traced Pallas program: two ``dma_start``s
    (warm-up + next-block prefetch) against one ``dma_wait`` per grid
    step — copy of block n+1 in flight while block n computes. Tracing
    only, nothing executes (same device-independence as the launch
    counts in ``_multi_lane_rows``).
    """
    b = img.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    kw = dict(radius=7, omega=0.95, refine=True, gf_radius=20, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=8, lam=0.05)
    u8 = jnp.asarray(kref.quantize_frames(np.asarray(img), "uint8"))

    fused = jax.jit(lambda x: ops.fused_dehaze(
        x, ids, A0, k0, init, mode="auto", **kw)[0])
    dbuf = jax.jit(lambda x: ops.fused_dehaze(
        x, ids, A0, k0, init, buffer_depth=2, mode="auto", **kw)[0])
    t_f32 = _timeit(fused, img)
    t_u8 = _timeit(fused, u8)
    t_dbuf = _timeit(dbuf, img)

    u8_bytes = _dehaze_min_bytes(u8)
    dma = ops.dma_copy_count(
        lambda x: ops.fused_dehaze(x, ids, A0, k0, init, buffer_depth=2,
                                   mode="interpret", **kw)[0], img)
    return [
        (f"kernels/fused_u8/{tag}", t_u8 * 1e6 / b,
         f"gbps={u8_bytes / t_u8 / 1e9:.2f}"
         f";input_bytes_ratio_vs_f32={u8.nbytes / img.nbytes:.2f}"
         f";tpu_roofline_us={u8_bytes / HBM_BW * 1e6:.1f}"),
        (f"kernels/fused_dbuf/{tag}", t_dbuf * 1e6 / b,
         f"dma_starts={dma['starts']};dma_waits={dma['waits']}"
         f";wallclock_vs_fused={t_dbuf / t_f32:.2f}x"),
    ]


def _fused_topk_rows(img: jnp.ndarray, tag: str, k: int = 4):
    """Robust top-k (k > 1) atmospheric-light estimator inside the
    megakernel: the in-VMEM k-step running selection vs the argmin (k=1)
    kernel on the same frames. The derived column is the price of
    robustness — expected near 1.0x, since the selection is k tiny
    reductions against a full-frame stencil pipeline.
    """
    b = img.shape[0]
    ids = jnp.arange(b, dtype=jnp.int32)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    kw = dict(radius=7, omega=0.95, refine=True, gf_radius=20, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=8, lam=0.05)
    f1 = jax.jit(lambda x: ops.fused_dehaze(
        x, ids, A0, k0, init, mode="auto", **kw)[0])
    fk = jax.jit(lambda x: ops.fused_dehaze(
        x, ids, A0, k0, init, topk=k, mode="auto", **kw)[0])
    t1 = _timeit(f1, img)
    tk = _timeit(fk, img)
    return [(f"kernels/fused_topk/{tag}", tk * 1e6 / b,
             f"k={k};overhead_vs_k1={tk / t1:.2f}x")]


def _sharded_halo_rows(img: jnp.ndarray, tag: str, n_h: int = 2):
    """Height-sharded (n_h > 1) transmission stage: the masked per-stage
    chain vs the halo-aware fused op, on one shard's workload.

    Benches exactly what one mesh shard computes after the halo exchange —
    the halo-extended (pre-map, guide) planes plus a row-validity mask with
    an invalid (mesh-edge) top halo — so it runs on the single-device CI
    container. Launch boundaries in the staged chain are synced the same
    way as ``_staged_vs_fused_rows``.
    """
    from repro.core import spatial
    from repro.kernels.ref import luminance, premap

    b, h, w, _ = img.shape
    radius, gf_radius, gf_eps = 7, 20, 1e-3
    halo = radius + 2 * gf_radius
    h_loc = h // n_h
    img_loc = img[:, :h_loc]
    pre = premap(img, jnp.ones((3,), jnp.float32), "dcp")
    guide = luminance(img)
    # Shard 0 of n_h: top halo rows are off-mesh (validity-masked garbage);
    # bottom halo rows past the frame (smoke shapes) are masked too.
    n_avail = min(h, h_loc + halo)
    pad_top = jnp.zeros((b, halo, w), img.dtype)
    pad_bot = jnp.zeros((b, h_loc + halo - n_avail, w), img.dtype)
    pre_ext = jnp.concatenate([pad_top, pre[:, :n_avail], pad_bot], axis=1)
    guide_ext = jnp.concatenate([pad_top, guide[:, :n_avail], pad_bot],
                                axis=1)
    rows_i = jnp.arange(h_loc + 2 * halo)
    valid = (rows_i >= halo) & (rows_i < halo + n_avail)

    core = slice(halo, halo + h_loc)
    mmin = jax.jit(lambda p, v: 1.0 - 0.95 * spatial.masked_min_filter_2d(
        p, v, radius))
    mgf = jax.jit(lambda g, t, v: jnp.clip(spatial.masked_guided_filter(
        g, t, v, gf_radius, gf_eps)[:, core], 0.0, 1.0))

    @jax.jit
    def cands(i, t_raw_ext):
        # Per-frame argmin-t candidate (Eq. 6) — part of the production
        # stage, so both rows below pay for it.
        ft = t_raw_ext[:, core].reshape(i.shape[0], -1)
        j = jnp.argmin(ft, axis=-1)
        t_min = jnp.take_along_axis(ft, j[:, None], axis=-1)[:, 0]
        rgb = jnp.take_along_axis(i.reshape(i.shape[0], -1, 3),
                                  j[:, None, None], axis=1)[:, 0]
        return t_min, rgb

    def staged():
        t_raw_ext = jax.block_until_ready(mmin(pre_ext, valid))
        t = jax.block_until_ready(mgf(guide_ext, t_raw_ext, valid))
        return t, cands(img_loc, t_raw_ext)

    fused = jax.jit(lambda i, p, g, v: ops.fused_transmission_halo(
        i, p, g, v, algorithm="dcp", radius=radius, omega=0.95, refine=True,
        gf_radius=gf_radius, gf_eps=gf_eps, mode="auto"))

    t_staged = _timeit(staged)
    t_fused = _timeit(fused, img_loc, pre_ext, guide_ext, valid)
    return [
        (f"kernels/sharded_t_staged_nh{n_h}/{tag}", t_staged * 1e6 / b, ""),
        (f"kernels/sharded_t_fused_nh{n_h}/{tag}", t_fused * 1e6 / b,
         f"speedup_vs_staged={t_staged / t_fused:.2f}x"),
    ]


def _sharded_halo_w_rows(img: jnp.ndarray, tag: str, n_w: int = 2):
    """Width-sharded (n_w > 1) transmission stage: the 2-D-masked
    per-stage chain vs the halo-aware fused op on one shard's workload.

    The W analogue of ``_sharded_halo_rows``: shard 0 of an n_w-way width
    split, with an invalid (mesh-edge) left halo and the column-validity
    mask driving the in-kernel masking. All rows are valid — exactly the
    shape the 2-D mask machinery sees on a width-only mesh.
    """
    from repro.core import spatial
    from repro.kernels.ref import luminance, premap

    b, h, w, _ = img.shape
    radius, gf_radius, gf_eps = 7, 20, 1e-3
    halo = radius + 2 * gf_radius
    w_loc = w // n_w
    img_loc = img[:, :, :w_loc]
    pre = premap(img, jnp.ones((3,), jnp.float32), "dcp")
    guide = luminance(img)
    n_avail = min(w, w_loc + halo)
    pad_l = jnp.zeros((b, h, halo), img.dtype)
    pad_r = jnp.zeros((b, h, w_loc + halo - n_avail), img.dtype)
    pre_ext = jnp.concatenate([pad_l, pre[:, :, :n_avail], pad_r], axis=2)
    guide_ext = jnp.concatenate([pad_l, guide[:, :, :n_avail], pad_r],
                                axis=2)
    cols = jnp.arange(w_loc + 2 * halo)
    valid_w = (cols >= halo) & (cols < halo + n_avail)
    valid_h = jnp.ones((h,), bool)

    core_w = slice(halo, halo + w_loc)
    mmin = jax.jit(lambda p, vh, vw: 1.0 - 0.95 * spatial.masked_min_filter_2d(
        p, vh, radius, vw))
    mgf = jax.jit(lambda g, t, vh, vw: jnp.clip(spatial.masked_guided_filter(
        g, t, vh, gf_radius, gf_eps, vw)[:, :, core_w], 0.0, 1.0))

    @jax.jit
    def cands(i, t_raw_ext):
        ft = t_raw_ext[:, :, core_w].reshape(i.shape[0], -1)
        j = jnp.argmin(ft, axis=-1)
        t_min = jnp.take_along_axis(ft, j[:, None], axis=-1)[:, 0]
        rgb = jnp.take_along_axis(i.reshape(i.shape[0], -1, 3),
                                  j[:, None, None], axis=1)[:, 0]
        return t_min, rgb

    def staged():
        t_raw_ext = jax.block_until_ready(mmin(pre_ext, valid_h, valid_w))
        t = jax.block_until_ready(mgf(guide_ext, t_raw_ext, valid_h, valid_w))
        return t, cands(img_loc, t_raw_ext)

    fused = jax.jit(lambda i, p, g, vh, vw: ops.fused_transmission_halo(
        i, p, g, vh, vw, algorithm="dcp", radius=radius, omega=0.95,
        refine=True, gf_radius=gf_radius, gf_eps=gf_eps, mode="auto"))

    t_staged = _timeit(staged)
    t_fused = _timeit(fused, img_loc, pre_ext, guide_ext, valid_h, valid_w)
    return [
        (f"kernels/sharded_t_staged_nw{n_w}/{tag}", t_staged * 1e6 / b, ""),
        (f"kernels/sharded_t_fused_nw{n_w}/{tag}", t_fused * 1e6 / b,
         f"speedup_vs_staged={t_staged / t_fused:.2f}x"),
    ]


def _multi_lane_rows(n_lanes: int):
    """Multi-stream tick: L lanes through the staged-vmapped chain, the
    vmapped fused megakernel, and the lane-native megakernel (the lane
    axis folded into the pallas grid).

    µs are per real frame per tick (one lane is all-padding, the typical
    partially occupied fleet). The lane-native row's derived column also
    reports the per-tick ``pallas_call`` launch count from the traced
    program — 1, vs L for per-lane kernel dispatch — the launch-amortization
    the refactor exists for (wall-clock on this CPU runner measures the
    XLA substrate; the launch counts are substrate-independent).
    """
    from repro.core import (DehazeConfig, init_atmo_state_lanes, lane_carry,
                            make_multi_stream_step)
    from repro.kernels import ops

    b, h, w = (2, 32, 40) if _env.bench_smoke() else (2, 120, 160)
    tag = f"{n_lanes}x{b}x{h}x{w}"
    r = np.random.default_rng(0)
    frames = jnp.asarray(r.random((n_lanes, b, h, w, 3), np.float32))
    ids = jnp.stack([jnp.arange(b, dtype=jnp.int32)] * (n_lanes - 1)
                    + [jnp.full((b,), -1, jnp.int32)])
    packed = init_atmo_state_lanes(n_lanes)
    n_real = (n_lanes - 1) * b

    staged_cfg = DehazeConfig(kernel_mode="ref", update_period=8)
    fused_cfg = DehazeConfig(kernel_mode="fused", update_period=8)
    staged = jax.jit(make_multi_stream_step(staged_cfg, lane_native=False))
    vmapped = jax.jit(make_multi_stream_step(fused_cfg, lane_native=False))
    lane_native = jax.jit(make_multi_stream_step(fused_cfg, lane_native=True))

    def timed(step):
        return _timeit(lambda f: step(f, ids, packed).frames, frames)

    t_staged = timed(staged)
    t_vmap = timed(vmapped)
    t_lane = timed(lane_native)

    # Launch counts are counted on the traced program with the kernels
    # forced to the (interpretable) Pallas substrate, per-lane dispatch vs
    # the lane-native grid — tracing only, nothing executes.
    kw = dict(radius=7, omega=0.95, refine=True, gf_radius=20, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=8, lam=0.05)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    carry_f, carry_i = lane_carry(packed)
    n_per_lane = ops.pallas_launch_count(
        lambda f: [ops.fused_dehaze(f[l], ids[l], A0, k0, init,
                                    mode="interpret", **kw)[0]
                   for l in range(n_lanes)], frames)
    n_lane_native = ops.pallas_launch_count(
        lambda f: ops.fused_dehaze_lanes(f, ids, carry_f, carry_i,
                                         mode="interpret", **kw)[0], frames)
    return [
        (f"kernels/multi_staged_L{n_lanes}/{tag}", t_staged * 1e6 / n_real,
         ""),
        (f"kernels/multi_fused_vmap_L{n_lanes}/{tag}", t_vmap * 1e6 / n_real,
         f"speedup_vs_staged={t_staged / t_vmap:.2f}x"),
        (f"kernels/fused_lanes_L{n_lanes}/{tag}", t_lane * 1e6 / n_real,
         f"speedup_vs_staged={t_staged / t_lane:.2f}x"
         f";launches_per_tick={n_lane_native}"
         f";per_lane_dispatch_launches={n_per_lane}"),
    ]


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
