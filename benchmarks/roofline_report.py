"""Roofline report: reads the dry-run JSON records (results/dryrun/) and
emits one row per (arch x shape x mesh) with the three roofline terms,
dominant bottleneck, and the useful-FLOPs ratio. This is the bench view
of deliverable (g); EXPERIMENTS.md carries the narrative."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def rows() -> List[Tuple[str, float, str]]:
    out = []
    if not os.path.isdir(RESULTS_DIR):
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    for name in sorted(os.listdir(RESULTS_DIR)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(RESULTS_DIR, name)) as f:
            r = json.load(f)
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            out.append((tag, 0.0, r["status"]))
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        useful = (r["model_flops"] / (r["hlo_flops_per_device"]
                                      * r["n_devices"])
                  if r["hlo_flops_per_device"] else float("nan"))
        out.append((tag, dom * 1e6,
                    f"bottleneck={r['bottleneck']};"
                    f"compute_s={r['compute_s']:.4g};"
                    f"memory_s={r['memory_s']:.4g};"
                    f"collective_s={r['collective_s']:.4g};"
                    f"useful={useful:.3f};"
                    f"peakGB={(r['memory_analysis']['peak_bytes'] or 0) / 1e9:.2f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
