"""Roofline report: reads the dry-run JSON records (results/dryrun/) and
emits one row per (arch x shape x mesh) with the three roofline terms,
dominant bottleneck, and the useful-FLOPs ratio. This is the bench view
of deliverable (g); EXPERIMENTS.md carries the narrative.

Also emits ``roofline/fused_io/*`` rows: the fused megakernel's
*measured* per-frame HBM byte footprint per ingest dtype, summed from the
traced ``pallas_call`` operand/result avals — so a uint8 stream is
verified to hit the ~1·I_u8 + out target (the kernel reads wire bytes; no
hidden XLA upcast copy in front of it). The uint8 row carries an ``ok``
flag gating input bytes <= 30% of the f32 baseline; ``main`` exits
nonzero when it fails."""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")

U8_INPUT_RATIO_TARGET = 0.30


def _pallas_io_bytes(fn, *args) -> Tuple[int, int]:
    """(input bytes, output bytes) summed over every ``pallas_call`` in
    ``fn``'s traced program — the kernel-boundary HBM traffic, at the
    dtypes the kernel actually reads/writes. Tracing only."""
    import jax
    import numpy as np
    from repro.kernels.ops import _iter_jaxprs

    calls = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                calls.append(eqn)
            for v in eqn.params.values():
                for sub in _iter_jaxprs(v):
                    walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)

    def nbytes(atoms):
        return sum(int(np.prod(a.aval.shape)) * a.aval.dtype.itemsize
                   for a in atoms)

    return (sum(nbytes(e.invars) for e in calls),
            sum(nbytes(e.outvars) for e in calls))


def _fused_io_rows() -> List[Tuple[str, float, str]]:
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops
    from repro.kernels import ref as kref

    b, h, w = 2, 32, 40
    base = np.random.default_rng(0).random((b, h, w, 3), np.float32)
    ids = jnp.arange(b, dtype=jnp.int32)
    A0 = jnp.ones((3,), jnp.float32)
    k0 = jnp.asarray(-(2 ** 30), jnp.int32)
    init = jnp.asarray(False)
    kw = dict(radius=3, omega=0.95, refine=True, gf_radius=4, gf_eps=1e-3,
              t0=0.1, gamma=1.0, period=8, lam=0.05)

    def measure(io_dtype):
        img = jnp.asarray(kref.quantize_frames(base, io_dtype))
        in_b, out_b = _pallas_io_bytes(
            lambda x: ops.fused_dehaze(x, ids, A0, k0, init,
                                       mode="interpret", **kw)[:2], img)
        return img, in_b, out_b

    out = []
    _, f32_in, f32_out = measure("float32")
    out.append(("roofline/fused_io/float32", (f32_in + f32_out) / b,
                f"in_bytes_per_frame={f32_in / b:.0f};"
                f"out_bytes_per_frame={f32_out / b:.0f}"))
    for io_dtype in ("uint8", "bfloat16"):
        img, in_b, out_b = measure(io_dtype)
        ratio = in_b / f32_in
        detail = (f"in_bytes_per_frame={in_b / b:.0f};"
                  f"out_bytes_per_frame={out_b / b:.0f};"
                  f"input_ratio_vs_f32={ratio:.2f}")
        if io_dtype == "uint8":
            ok = ratio <= U8_INPUT_RATIO_TARGET
            detail += (f";target<={U8_INPUT_RATIO_TARGET:.2f};"
                       f"ok={'yes' if ok else 'NO'}")
        out.append((f"roofline/fused_io/{io_dtype}", (in_b + out_b) / b,
                    detail))
    return out


def rows() -> List[Tuple[str, float, str]]:
    out = _fused_io_rows()
    if not os.path.isdir(RESULTS_DIR):
        return out + [("roofline/missing", 0.0,
                       "run repro.launch.dryrun first")]
    for name in sorted(os.listdir(RESULTS_DIR)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(RESULTS_DIR, name)) as f:
            r = json.load(f)
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            out.append((tag, 0.0, r["status"]))
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        useful = (r["model_flops"] / (r["hlo_flops_per_device"]
                                      * r["n_devices"])
                  if r["hlo_flops_per_device"] else float("nan"))
        out.append((tag, dom * 1e6,
                    f"bottleneck={r['bottleneck']};"
                    f"compute_s={r['compute_s']:.4g};"
                    f"memory_s={r['memory_s']:.4g};"
                    f"collective_s={r['collective_s']:.4g};"
                    f"useful={useful:.3f};"
                    f"peakGB={(r['memory_analysis']['peak_bytes'] or 0) / 1e9:.2f}"))
    return out


if __name__ == "__main__":
    bad = False
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
        bad = bad or "ok=NO" in derived
    if bad:
        print("FAIL: fused_io uint8 input bytes exceed the roofline target",
              file=sys.stderr)
        sys.exit(1)
