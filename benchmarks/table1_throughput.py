"""Paper Table 1: frames/s by algorithm x resolution x worker count.

Reproduces the table's structure on this container (single CPU core — the
absolute numbers are CPU numbers; the relative effects the table claims
are what we validate: (a) the framework beats one-frame-at-a-time
processing, (b) throughput scales with frame-batch parallelism, which on
a pod maps to the data axis; the modeled pod-scale numbers come from the
roofline table in EXPERIMENTS.md).

Rows: baseline (frame-by-frame, the paper's "DCP [13]"/"CAP [23]" rows)
vs framework with 1/2/3 workers (paper's 1N/2N/3N rows).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.data import HazeVideoSpec, generate_haze_video
from repro.stream import ElasticServer

RESOLUTIONS = {"320x240": (240, 320), "640x480": (480, 640),
               "1024x576": (576, 1024)}


def bench_baseline(algo: str, h: int, w: int, n_frames: int = 12) -> float:
    """Frame-by-frame (batch=1) single-worker processing."""
    vid = generate_haze_video(HazeVideoSpec(height=h, width=w,
                                            n_frames=n_frames, a_noise=0.0))
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    step = jax.jit(make_dehaze_step(cfg))
    state = init_atmo_state()
    # warmup/compile
    out = step(jnp.asarray(vid.hazy[:1]), jnp.arange(1, dtype=jnp.int32), state)
    jax.block_until_ready(out.frames)
    t0 = time.perf_counter()
    for i in range(n_frames):
        out = step(jnp.asarray(vid.hazy[i:i + 1]),
                   jnp.asarray([i], jnp.int32), state)
        state = out.state
        np.asarray(out.frames)
    return n_frames / (time.perf_counter() - t0)


def bench_framework(algo: str, h: int, w: int, workers: int,
                    n_frames: int = 24, batch: int = 4) -> float:
    vid = generate_haze_video(HazeVideoSpec(height=h, width=w,
                                            n_frames=n_frames, a_noise=0.0))
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, n_workers=workers, batch=batch, timeout_s=1.0)
    srv.serve(iter(vid.hazy[:batch]))          # warmup/compile
    rep = srv.serve(iter(vid.hazy))
    return rep.fps


def rows() -> List[Tuple[str, float, str]]:
    out = []
    for algo in ("dcp", "cap"):
        for res_name, (h, w) in RESOLUTIONS.items():
            fps0 = bench_baseline(algo, h, w)
            out.append((f"table1/{algo}-baseline/{res_name}",
                        1e6 / fps0, f"{fps0:.2f}fps"))
            for nw in (1, 2, 3):
                fps = bench_framework(algo, h, w, nw)
                out.append((f"table1/{nw}N-{algo}/{res_name}",
                            1e6 / fps, f"{fps:.2f}fps"))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
