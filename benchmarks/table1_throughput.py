"""Paper Table 1: frames/s by algorithm x resolution x worker count.

Reproduces the table's structure on this container (single CPU core — the
absolute numbers are CPU numbers; the relative effects the table claims
are what we validate: (a) the framework beats one-frame-at-a-time
processing, (b) throughput scales with frame-batch parallelism, which on
a pod maps to the data axis; the modeled pod-scale numbers come from the
roofline table in EXPERIMENTS.md).

Rows: baseline (frame-by-frame, the paper's "DCP [13]"/"CAP [23]" rows)
vs framework with 1/2/3 workers (paper's 1N/2N/3N rows).

Multi-stream rows (beyond the paper — its §5 future work): aggregate fps
of L concurrent videos served by the lane-batched scheduler
(``ElasticServer.serve_many``) vs the same L videos served one after the
other by the single-stream path. One ``(L, B, ...)`` program per tick
amortizes the per-batch dispatch + host-loop cost the sequential path
pays L times, which is exactly the serving-layer win deployment papers
(e.g. Hazedefy) argue decides real-time dehazing value.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DehazeConfig, init_atmo_state, make_dehaze_step
from repro.core import env as _env
from repro.data import HazeVideoSpec, generate_haze_video
from repro.stream import ElasticServer, ScalePolicy, StreamRequest

RESOLUTIONS = {"320x240": (240, 320), "640x480": (480, 640),
               "1024x576": (576, 1024)}

# Multi-stream rows: small frames (many-camera grids run at modest
# per-camera resolution; this is also what keeps the row CPU-feasible).
MULTI_RESOLUTION = ("160x120", (120, 160))
MULTI_LANES = (1, 4, 16)


def _stream_videos(n: int, h: int, w: int, n_frames: int):
    return [generate_haze_video(HazeVideoSpec(
        height=h, width=w, n_frames=n_frames, seed=50 + i, a_noise=0.0))
        for i in range(n)]


def bench_baseline(algo: str, h: int, w: int, n_frames: int = 12) -> float:
    """Frame-by-frame (batch=1) single-worker processing."""
    vid = generate_haze_video(HazeVideoSpec(height=h, width=w,
                                            n_frames=n_frames, a_noise=0.0))
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    step = jax.jit(make_dehaze_step(cfg))
    state = init_atmo_state()
    # warmup/compile
    out = step(jnp.asarray(vid.hazy[:1]), jnp.arange(1, dtype=jnp.int32), state)
    jax.block_until_ready(out.frames)
    t0 = time.perf_counter()
    for i in range(n_frames):
        out = step(jnp.asarray(vid.hazy[i:i + 1]),
                   jnp.asarray([i], jnp.int32), state)
        state = out.state
        np.asarray(out.frames)
    return n_frames / (time.perf_counter() - t0)


def bench_framework(algo: str, h: int, w: int, workers: int,
                    n_frames: int = 24, batch: int = 4) -> float:
    vid = generate_haze_video(HazeVideoSpec(height=h, width=w,
                                            n_frames=n_frames, a_noise=0.0))
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, n_workers=workers, batch=batch, timeout_s=1.0)
    srv.serve(iter(vid.hazy[:batch]))          # warmup/compile
    rep = srv.serve(iter(vid.hazy))
    return rep.fps


def bench_sequential_streams(algo: str, h: int, w: int, n_streams: int,
                             n_frames: int = 24, batch: int = 8) -> float:
    """L videos served back-to-back through the single-stream path:
    the baseline the lane-batched scheduler must beat. Aggregate fps =
    total frames / total wall (includes the per-stream session turnover —
    device drain, monitor teardown/setup — the sequential path pays L
    times and continuous batching hides)."""
    vids = _stream_videos(n_streams, h, w, n_frames)
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, n_workers=1, batch=batch, timeout_s=5.0)
    srv.serve(iter(vids[0].hazy[:batch]), stream_id="warmup")  # compile
    t0 = time.perf_counter()
    total = 0
    for i, vid in enumerate(vids):
        rep = srv.serve(iter(vid.hazy), stream_id=f"seq{i}")
        total += rep.frames
    return total / (time.perf_counter() - t0)


def bench_multi_stream(algo: str, h: int, w: int, n_streams: int,
                       n_frames: int = 24, batch: int = 8) -> float:
    """L videos multiplexed onto L lanes of one device batch per tick.

    On this 2-core CPU container the vmapped (L, B, ...) program is still
    compute-bound, so the measured gain is mostly dispatch/turnover
    amortization (~1.2-1.4x at L=4); on an accelerator where one stream
    cannot saturate the chip, lane batching is the difference between
    1/L utilization and full utilization — that regime is what the row's
    shape models."""
    vids = _stream_videos(n_streams, h, w, n_frames)
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, batch=batch, timeout_s=5.0)
    srv.serve_many([StreamRequest(f"warm{i}", iter(v.hazy[:batch]))
                    for i, v in enumerate(vids)])              # compile
    rep = srv.serve_many([StreamRequest(f"cam{i}", iter(v.hazy))
                          for i, v in enumerate(vids)])
    return rep.aggregate_fps


def multi_stream_rows(algo: str = "dcp") -> List[Tuple[str, float, str]]:
    """Aggregate fps at L=1/4/16 concurrent streams vs L sequential serves.

    The derived column reports ``<multi fps>(<multi/seq ratio>x)``."""
    res_name, (h, w) = MULTI_RESOLUTION
    smoke = _env.bench_smoke()
    n_frames = 16 if smoke else 24
    out = []
    for n_streams in MULTI_LANES:
        if smoke and n_streams > 4:
            continue
        fps_seq = bench_sequential_streams(algo, h, w, n_streams,
                                           n_frames=n_frames)
        fps_multi = bench_multi_stream(algo, h, w, n_streams,
                                       n_frames=n_frames)
        out.append((f"table1/seq-L{n_streams}-{algo}/{res_name}",
                    1e6 / fps_seq, f"{fps_seq:.2f}fps"))
        out.append((f"table1/multi-L{n_streams}-{algo}/{res_name}",
                    1e6 / fps_multi,
                    f"{fps_multi:.2f}fps({fps_multi / fps_seq:.2f}x)"))
    return out


def overlap_rows(algo: str = "dcp") -> List[Tuple[str, float, str]]:
    """Zero-copy tick I/O (README §Tick I/O & overlap): the same sparse
    lane occupancy served on the blocking oracle path vs the overlapped
    path (device-resident lane buffers, donated state, valid-only D2H).

    Sparse occupancy (half the lanes live) is where the tentpole's D2H
    win is structural, not just overlap jitter: the blocking path fetches
    every padding lane's batch each tick, the overlapped path fetches only
    valid frames. Rows (best of 2 runs each, to damp host scheduling
    noise on this container):

      overlap-off  blocking aggregate fps + whole-batch D2H bytes
      overlap-on   overlapped aggregate fps; the derived column appends
                   the fps ratio and the D2H byte reduction. The row
                   asserts fps(on) >= fps(off) and D2H(on) < D2H(off) —
                   an overlap path slower than the path it replaces is a
                   regression, not a shrug.
    """
    from repro.stream import donation_supported

    res_name, (h, w) = MULTI_RESOLUTION
    smoke = _env.bench_smoke()
    n_frames = 16 if smoke else 32
    lanes, n_streams, batch = 8, 4, 8     # sparse: half the lanes padding
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, batch=batch, timeout_s=5.0)

    def serve(tick_overlap: bool, seed0: int):
        vids = [generate_haze_video(HazeVideoSpec(
            height=h, width=w, n_frames=n_frames, seed=seed0 + i,
            a_noise=0.0)) for i in range(n_streams)]
        best = None
        for _ in range(2):                # best-of-2
            rep = srv.serve_many(
                [StreamRequest(f"cam{seed0 + i}", iter(v.hazy))
                 for i, v in enumerate(vids)],
                n_lanes=lanes, tick_overlap=tick_overlap)
            if best is None or rep.aggregate_fps > best.aggregate_fps:
                best = rep
        return best

    # Warm both step variants so neither mode's first run eats a compile.
    warm = _stream_videos(1, h, w, batch)[0]
    for ov in (False, True):
        srv.serve_many([StreamRequest(f"warmov{ov}", iter(warm.hazy))],
                       n_lanes=lanes, tick_overlap=ov)

    rep_off = serve(False, 700)
    rep_on = serve(True, 800)
    if donation_supported():
        assert rep_on.overlap_ticks == rep_on.ticks, (
            f"overlap bench fell back to blocking: "
            f"{rep_on.overlap_ticks}/{rep_on.ticks} ticks overlapped")
        assert rep_on.d2h_bytes < rep_off.d2h_bytes, (
            f"valid-only D2H fetched no fewer bytes than whole-batch: "
            f"{rep_on.d2h_bytes} >= {rep_off.d2h_bytes}")
        assert rep_on.aggregate_fps >= rep_off.aggregate_fps, (
            f"overlapped path slower than blocking: "
            f"{rep_on.aggregate_fps:.2f} < {rep_off.aggregate_fps:.2f} fps")
    ratio = rep_on.aggregate_fps / rep_off.aggregate_fps
    d2h_cut = 1.0 - rep_on.d2h_bytes / max(1, rep_off.d2h_bytes)
    return [
        (f"table1/overlap-off-{algo}/{res_name}",
         1e6 / rep_off.aggregate_fps,
         f"{rep_off.aggregate_fps:.2f}fps({rep_off.d2h_bytes}B)"),
        (f"table1/overlap-on-{algo}/{res_name}",
         1e6 / rep_on.aggregate_fps,
         f"{rep_on.aggregate_fps:.2f}fps({ratio:.2f}x,"
         f"-{d2h_cut:.0%}d2h)"),
    ]


def autoscale_rows(algo: str = "dcp") -> List[Tuple[str, float, str]]:
    """Ramping load through the elastic lane ladder vs a fixed-max fleet.

    The workload is a burst of short clips (forces a grow: every lane
    full, queue deep) followed by a long-clip tail (forces a shrink: queue
    empty, occupancy below the rung). Rows:

      autoscale-ramp  aggregate fps under the ladder; the derived column
                      appends the committed switch count, which the
                      serve-smoke CI leg asserts is >= 2 (one grow + one
                      shrink).
      fixedmax-ramp   the same streams at a fixed max-lane fleet — the
                      throughput ceiling autoscaling should track while
                      using fewer padded lanes on the tail.
      switch-latency  mean serve-thread stall per committed rung switch
                      (state repack + step swap; never a trace — the
                      ladder is pre-warmed off-thread).
    """
    from repro.stream import ladder_rungs

    res_name, (h, w) = MULTI_RESOLUTION
    smoke = _env.bench_smoke()
    cap = 4 if smoke else 8
    short, long_ = (8, 32) if smoke else (16, 64)
    lengths = [short] * (cap + 2) + [long_] * 2
    pol = ScalePolicy(rungs=(2, 4, 8), grow_pending=1, dwell_up=1,
                      dwell_down=2, evict_tardy_after=None)
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")
    srv = ElasticServer(cfg, batch=8, timeout_s=5.0)

    def ramp(prefix: str, seed0: int):
        vids = [generate_haze_video(HazeVideoSpec(
            height=h, width=w, n_frames=n, seed=seed0 + i, a_noise=0.0))
            for i, n in enumerate(lengths)]
        return [StreamRequest(f"{prefix}{i}", iter(v.hazy))
                for i, v in enumerate(vids)]

    # Prime every rung's executable so the rows time steady-state serving,
    # not first-call compiles (the ladder warm thread then cache-hits).
    warm = generate_haze_video(HazeVideoSpec(
        height=h, width=w, n_frames=8, seed=49, a_noise=0.0))
    for r in ladder_rungs(pol.rungs, cap):
        srv.serve_many([StreamRequest(f"warm{r}", iter(warm.hazy))],
                       n_lanes=r)

    rep_auto = srv.serve_many(ramp("a", 100), n_lanes=cap, autoscale=True,
                              policy=pol)
    rep_fix = srv.serve_many(ramp("f", 300), n_lanes=cap)
    out = [
        (f"table1/autoscale-ramp-{algo}/{res_name}",
         1e6 / rep_auto.aggregate_fps,
         f"{rep_auto.aggregate_fps:.2f}fps({rep_auto.ladder_switches}sw)"),
        (f"table1/fixedmax-ramp-{algo}/{res_name}",
         1e6 / rep_fix.aggregate_fps, f"{rep_fix.aggregate_fps:.2f}fps"),
    ]
    if rep_auto.ladder_switches:
        mean_s = rep_auto.switch_wall_s / rep_auto.ladder_switches
        out.append((f"table1/switch-latency-{algo}/{res_name}",
                    mean_s * 1e6, f"{mean_s * 1e3:.2f}ms/switch"))
    return out


def fleet_rows(algo: str = "dcp") -> List[Tuple[str, float, str]]:
    """Fleet tier: the same 8 streams behind 1 vs 2 simulated hosts of 4
    lanes each (paper §4's headline, three PCs beating one box, in its
    serving-tier form).

    "Hosts" on this container are serve threads over one XLA device, so
    raw compute alone would not split cleanly across them; each tick
    instead carries a fixed simulated device service time
    (``host_delay_s``), which makes every host device-bound the way a real
    per-host accelerator is. Two hosts then drain the shared global-EDF
    queue in about half the ticks per host, and the aggregate-fps ratio in
    the derived column is the fleet's scaling headline — asserted >= 1.8x
    (sleep-dominated ticks make this deterministic), with the spillover
    count riding along (first-fit placement overflows host 0 onto host 1).
    """
    res_name, (h, w) = "64x48", (48, 64)
    smoke = _env.bench_smoke()
    n_frames = 16 if smoke else 32
    delay = 0.2 if smoke else 0.25
    lanes, n_streams, batch = 4, 8, 8
    cfg = DehazeConfig(algorithm=algo, kernel_mode="ref")

    def serve(n_hosts: int, seed0: int):
        vids = _stream_videos(n_streams, h, w, n_frames)
        srv = ElasticServer(cfg, batch=batch, timeout_s=5.0)
        srv.serve_many([StreamRequest("warm", iter(vids[0].hazy[:batch]))],
                       n_lanes=lanes)                  # compile (no delay)
        return srv.serve_many(
            [StreamRequest(f"cam{i}", iter(v.hazy))
             for i, v in enumerate(vids)],
            n_lanes=lanes, n_hosts=n_hosts, host_delay_s=delay)

    rep1 = serve(1, 500)
    rep2 = serve(2, 600)
    assert rep2.migrations == 0, "sticky placement violated in bench"
    ratio = rep2.aggregate_fps / rep1.aggregate_fps
    assert ratio >= 1.8, (
        f"fleet scaling below bar: 2-host/1-host aggregate fps ratio "
        f"{ratio:.2f} < 1.8 (wall {rep1.wall_s:.2f}s -> {rep2.wall_s:.2f}s)")
    return [
        (f"table1/fleet-1host-{algo}/{res_name}", 1e6 / rep1.aggregate_fps,
         f"{rep1.aggregate_fps:.2f}fps"),
        (f"table1/fleet-2host-{algo}/{res_name}", 1e6 / rep2.aggregate_fps,
         f"{rep2.aggregate_fps:.2f}fps({ratio:.2f}x,"
         f"{rep2.spillovers}spill)"),
    ]


def rows() -> List[Tuple[str, float, str]]:
    out = []
    for algo in ("dcp", "cap"):
        for res_name, (h, w) in RESOLUTIONS.items():
            fps0 = bench_baseline(algo, h, w)
            out.append((f"table1/{algo}-baseline/{res_name}",
                        1e6 / fps0, f"{fps0:.2f}fps"))
            for nw in (1, 2, 3):
                fps = bench_framework(algo, h, w, nw)
                out.append((f"table1/{nw}N-{algo}/{res_name}",
                            1e6 / fps, f"{fps:.2f}fps"))
    out.extend(multi_stream_rows())
    out.extend(overlap_rows())
    out.extend(autoscale_rows())
    out.extend(fleet_rows())
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
